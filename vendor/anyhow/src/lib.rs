//! Minimal, offline stand-in for the `anyhow` crate.
//!
//! The build runs with no network access, so the real `anyhow` cannot be
//! fetched from crates.io. This vendored crate reimplements exactly the
//! surface `bbans` uses:
//!
//! * [`Error`] — a context-chain error type (`Display` prints the
//!   outermost message, `{:#}` prints the whole `outer: ...: root` chain,
//!   `Debug` prints a `Caused by:` list);
//! * [`Result<T>`] — alias with `Error` as the default error type;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros;
//! * `From<E>` for every `E: std::error::Error + Send + Sync + 'static`,
//!   so `?` works on `io::Error`, parse errors, etc.
//!
//! Downcasting and backtraces are intentionally omitted — nothing in the
//! workspace uses them.

use std::fmt::{self, Display};

/// A context-chain error. `chain[0]` is the outermost message; the last
/// element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a plain message (what `anyhow!` / `bail!` produce).
    pub fn msg(message: impl Into<String>) -> Self {
        Self {
            chain: vec![message.into()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost → root messages.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root-cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent with `From<T> for T`
// (the same trick the real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: Display>(self, context: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "Condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e).context("reading file")
    }

    #[test]
    fn context_chain_and_display() {
        let err = io_fail().unwrap_err();
        assert_eq!(format!("{err}"), "reading file");
        assert_eq!(format!("{err:#}"), "reading file: gone");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn macros_work() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(inner(5).unwrap_err().to_string(), "five is right out");
        assert!(inner(11).unwrap_err().to_string().contains("11"));
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn option_context_and_question_mark() {
        fn grab(v: Option<u32>) -> Result<u32> {
            let x = v.context("missing value")?;
            let s: u32 = "12".parse()?; // From<ParseIntError>
            Ok(x + s)
        }
        assert_eq!(grab(Some(1)).unwrap(), 13);
        assert_eq!(grab(None).unwrap_err().to_string(), "missing value");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, std::num::ParseIntError> = "4".parse();
        let mut called = false;
        let got = ok.with_context(|| {
            called = true;
            "not called on Ok"
        });
        assert_eq!(got.unwrap(), 4);
        assert!(!called, "with_context must not build context on Ok");
        let bad: Result<u32, std::num::ParseIntError> = "x".parse();
        let err = bad.with_context(|| format!("parsing {}", "x")).unwrap_err();
        assert_eq!(format!("{err}"), "parsing x");
    }
}
