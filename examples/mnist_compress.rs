//! End-to-end driver for the paper's headline experiment (Table 2):
//! compress the full test set of both datasets with BB-ANS and all
//! baselines, printing the paper's table next to our measurements.
//!
//! ```sh
//! make artifacts && cargo run --release --example mnist_compress [N]
//! ```
//!
//! This is the system's end-to-end validation (see EXPERIMENTS.md): all
//! three layers compose — the L1 kernels inside the L2-trained model's
//! graphs produced the artifacts; the L3 codec turns them into bits.

use bbans::baselines::standard_suite;
use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::data::{load_split, synth};
use bbans::model::vae::{load_native, NativeVae};
use bbans::model::{Backend, Likelihood, ModelMeta};
use bbans::runtime::{artifacts_available, default_artifact_dir};
use bbans::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    // Without an artifact bundle the pipeline still runs end to end on
    // seeded random models + synthetic digits (CI's example-smoke job):
    // the lossless checks are as strict, only the rates are illustrative.
    let synthetic = !artifacts_available(&dir);
    let mut n: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(10_000);
    if synthetic {
        n = n.min(512);
        eprintln!(
            "artifacts not found — using seeded random models on {n} synthetic digits \
             (rates are illustrative, lossless checks are real)"
        );
    }

    println!("=== Table 2 reproduction: compression rates in bits/dim (n = {n}) ===\n");

    // Paper numbers (real MNIST, their trained VAEs) for side-by-side.
    let paper: &[(&str, f64, f64)] = &[
        ("VAE test ELBO", 0.19, 1.39),
        ("BB-ANS", 0.19, 1.41),
        ("bz2", 0.25, 1.42),
        ("gzip", 0.33, 1.64),
        ("PNG", 0.78, 2.79),
        ("WebP", 0.44, 2.10),
    ];

    let mut ours: Vec<(String, f64, f64)> = Vec::new();

    for (row, (model, binarized, pixel_prec)) in
        [("bin", true, 16u32), ("full", false, 18u32)].iter().enumerate()
    {
        let ds = if synthetic {
            let base = synth::digits(n, 33);
            if *binarized {
                synth::binarize(&base, 34)
            } else {
                base
            }
        } else {
            load_split(&dir, "test", *binarized)?.subset(n)
        };
        let images = ds.images.clone();
        let backend = if synthetic {
            NativeVae::random(
                ModelMeta {
                    name: model.to_string(),
                    pixels: 784,
                    latent_dim: 20,
                    hidden: 50,
                    likelihood: if *binarized {
                        Likelihood::Bernoulli
                    } else {
                        Likelihood::BetaBinomial
                    },
                    test_elbo_bpd: f64::NAN,
                },
                40 + row as u64,
            )
        } else {
            load_native(&dir, model)?
        };
        let cfg = BbAnsConfig {
            pixel_prec: *pixel_prec,
            ..Default::default()
        };
        let codec = VaeCodec::new(&backend, cfg)?;

        let t = Timer::start();
        let (mut ans, _) = codec.encode_dataset(&images)?;
        let enc_s = t.elapsed_secs();
        let bpd = ans.frac_bit_len() / (images.len() as f64 * 784.0);

        let t = Timer::start();
        let decoded = codec.decode_dataset(&mut ans, images.len())?;
        let dec_s = t.elapsed_secs();
        assert_eq!(decoded, images, "lossless check failed!");

        eprintln!(
            "[{model}] BB-ANS {bpd:.4} bits/dim | encode {:.1} img/s | decode {:.1} img/s | lossless ✓",
            images.len() as f64 / enc_s,
            images.len() as f64 / dec_s
        );

        if row == 0 {
            ours.push((
                "VAE test ELBO".into(),
                backend.meta().test_elbo_bpd,
                f64::NAN,
            ));
            ours.push(("BB-ANS".into(), bpd, f64::NAN));
        } else {
            ours[0].2 = backend.meta().test_elbo_bpd;
            ours[1].2 = bpd;
        }

        for codec in standard_suite(*binarized) {
            let rate = codec.bits_per_dim(&ds)?;
            let name = match codec.name() {
                "bz2-style" => "bz2",
                "webp-style" => "WebP",
                "png" => "PNG",
                other => other,
            };
            if row == 0 {
                ours.push((name.to_string(), rate, f64::NAN));
            } else if let Some(e) = ours.iter_mut().find(|e| e.0 == name) {
                e.2 = rate;
            }
        }
    }

    println!("\n{:<16}  {:>16}  {:>16}", "", "Binarized MNIST", "Full MNIST");
    println!("{:<16}  {:>7} {:>8}  {:>7} {:>8}", "scheme", "paper", "ours", "paper", "ours");
    println!("{}", "-".repeat(62));
    println!(
        "{:<16}  {:>7} {:>8}  {:>7} {:>8}",
        "Raw data", "1", "1", "8", "8"
    );
    for (name, pb, pf) in paper {
        let (ob, of) = ours
            .iter()
            .find(|e| e.0.eq_ignore_ascii_case(name) || name.starts_with(&e.0))
            .map(|e| (e.1, e.2))
            .unwrap_or((f64::NAN, f64::NAN));
        println!("{name:<16}  {pb:>7.2} {ob:>8.3}  {pf:>7.2} {of:>8.3}");
    }
    if synthetic {
        println!(
            "\n(untrained random models: the rate columns are illustrative only;\n\
             every stream above decoded losslessly.)"
        );
    } else {
        println!(
            "\nShape check: BB-ANS beats every baseline on both datasets, and its\n\
             rate sits within ~1% of the trained model's negative test ELBO —\n\
             the paper's two headline claims."
        );
    }
    Ok(())
}
