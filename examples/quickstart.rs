//! Quickstart: compress and decompress a handful of images with BB-ANS.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the core public API: load a backend, build a [`VaeCodec`],
//! chain-encode a dataset, serialize the container, decode it back.
//!
//! Runs without artifacts too (CI's example-smoke job relies on this): a
//! seeded random model over synthetic digits stands in — same API, same
//! lossless guarantee, illustrative rates only.

use bbans::bbans::{container::Container, BbAnsConfig, VaeCodec};
use bbans::data::{load_split, synth};
use bbans::model::vae::{load_native, NativeVae};
use bbans::model::{Backend, Likelihood, ModelMeta};
use bbans::runtime::{artifacts_available, default_artifact_dir};

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();

    // 1. A trained VAE backend (pure-Rust forward pass; swap in
    //    `PjrtVae::from_config` for the PJRT/XLA path), plus some
    //    binarized test images — or a deterministic stand-in when no
    //    artifact bundle is around.
    let (backend, images) = if artifacts_available(&dir) {
        let backend = load_native(&dir, "bin")?;
        let ds = load_split(&dir, "test", true)?;
        let images: Vec<Vec<u8>> = ds.images.iter().take(100).cloned().collect();
        (backend, images)
    } else {
        eprintln!("artifacts not found — using a seeded random model on synthetic digits");
        let meta = ModelMeta {
            name: "bin".into(),
            pixels: 784,
            latent_dim: 20,
            hidden: 50,
            likelihood: Likelihood::Bernoulli,
            test_elbo_bpd: f64::NAN,
        };
        let backend = NativeVae::random(meta, 7);
        (backend, synth::binarize(&synth::digits(100, 1), 2).images)
    };
    println!(
        "model 'bin': {} pixels, {}-dim latent, test ELBO {:.4} bits/dim",
        backend.meta().pixels,
        backend.meta().latent_dim,
        backend.meta().test_elbo_bpd
    );

    // 2. The BB-ANS codec.
    let codec = VaeCodec::new(&backend, BbAnsConfig::default())?;
    let raw_bits = images.len() * 784;

    // 3. Chain-encode.
    let (ans, stats) = codec.encode_dataset(&images)?;
    println!(
        "clean bits used to start the chain: {}",
        ans.clean_bits_used()
    );
    let container = Container {
        model: "bin".into(),
        backend_id: backend.backend_id(),
        cfg: codec.cfg,
        num_images: images.len() as u32,
        pixels: 784,
        message: ans.into_message(),
    };
    let bytes = container.to_bytes();
    println!(
        "compressed {} images: {} raw bits -> {} bytes  ({:.4} bits/dim, ELBO predicts {:.4})",
        images.len(),
        raw_bits,
        bytes.len(),
        container.bits_per_dim(),
        backend.meta().test_elbo_bpd,
    );
    let mean_net: f64 = stats.iter().map(|s| s.net_bits).sum::<f64>() / raw_bits as f64;
    println!("mean net cost per pixel (amortized): {mean_net:.4} bits");

    // 4. Decode from the serialized container and verify.
    let parsed = Container::from_bytes(&bytes)?;
    let mut ans = bbans::ans::Ans::from_message(&parsed.message, parsed.cfg.clean_seed);
    let decoded = codec.decode_dataset(&mut ans, parsed.num_images as usize)?;
    assert_eq!(decoded, images, "lossless roundtrip");
    println!("roundtrip OK — all {} images identical", images.len());
    Ok(())
}
