//! Quickstart: compress and decompress a handful of images with BB-ANS.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the core public API: load a backend, build a [`VaeCodec`],
//! chain-encode a dataset, serialize the container, decode it back.

use bbans::bbans::{container::Container, BbAnsConfig, VaeCodec};
use bbans::data::load_split;
use bbans::model::vae::load_native;
use bbans::model::Backend;
use bbans::runtime::{artifacts_available, default_artifact_dir};

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("artifacts not found — run `make artifacts` first");
        std::process::exit(1);
    }

    // 1. A trained VAE backend (pure-Rust forward pass; swap in
    //    `PjrtVae::from_config` for the PJRT/XLA path).
    let backend = load_native(&dir, "bin")?;
    println!(
        "model 'bin': {} pixels, {}-dim latent, test ELBO {:.4} bits/dim",
        backend.meta().pixels,
        backend.meta().latent_dim,
        backend.meta().test_elbo_bpd
    );

    // 2. The BB-ANS codec.
    let codec = VaeCodec::new(&backend, BbAnsConfig::default())?;

    // 3. Some binarized test images.
    let ds = load_split(&dir, "test", true)?;
    let images: Vec<Vec<u8>> = ds.images.iter().take(100).cloned().collect();
    let raw_bits = images.len() * 784;

    // 4. Chain-encode.
    let (ans, stats) = codec.encode_dataset(&images)?;
    println!(
        "clean bits used to start the chain: {}",
        ans.clean_bits_used()
    );
    let container = Container {
        model: "bin".into(),
        backend_id: backend.backend_id(),
        cfg: codec.cfg,
        num_images: images.len() as u32,
        pixels: 784,
        message: ans.into_message(),
    };
    let bytes = container.to_bytes();
    println!(
        "compressed {} images: {} raw bits -> {} bytes  ({:.4} bits/dim, ELBO predicts {:.4})",
        images.len(),
        raw_bits,
        bytes.len(),
        container.bits_per_dim(),
        backend.meta().test_elbo_bpd,
    );
    let mean_net: f64 = stats.iter().map(|s| s.net_bits).sum::<f64>() / raw_bits as f64;
    println!("mean net cost per pixel (amortized): {mean_net:.4} bits");

    // 5. Decode from the serialized container and verify.
    let parsed = Container::from_bytes(&bytes)?;
    let mut ans = bbans::ans::Ans::from_message(&parsed.message, parsed.cfg.clean_seed);
    let decoded = codec.decode_dataset(&mut ans, parsed.num_images as usize)?;
    assert_eq!(decoded, images, "lossless roundtrip");
    println!("roundtrip OK — all {} images identical", images.len());
    Ok(())
}
