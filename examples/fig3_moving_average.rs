//! Figure 3 reproduction: a 2000-point moving average of the BB-ANS
//! compression rate while compressing a concatenation of three shuffled
//! copies of the test set.
//!
//! ```sh
//! cargo run --release --example fig3_moving_average [N_PER_COPY]
//! ```
//!
//! Writes `artifacts/fig3.csv` (image index, net bits/dim, moving
//! average) and prints an ASCII rendering of the curve plus the ELBO
//! reference line.

use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::data::load_split;
use bbans::model::vae::load_native;
use bbans::model::Backend;
use bbans::runtime::{artifacts_available, default_artifact_dir};
use bbans::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("artifacts not found — run `make artifacts` first");
        std::process::exit(1);
    }
    let n_per_copy: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(10_000);

    let ds = load_split(&dir, "test", true)?;
    let mut rng = Rng::new(303);
    let mut images: Vec<Vec<u8>> = Vec::with_capacity(3 * n_per_copy);
    for _ in 0..3 {
        let mut idx: Vec<usize> = (0..ds.len().min(n_per_copy)).collect();
        rng.shuffle(&mut idx);
        images.extend(idx.into_iter().map(|i| ds.images[i].clone()));
    }

    let backend = load_native(&dir, "bin")?;
    let elbo = backend.meta().test_elbo_bpd;
    let codec = VaeCodec::new(&backend, BbAnsConfig::default())?;
    let (_, stats) = codec.encode_dataset(&images)?;

    // Per-image bits/dim and the 2000-point moving average.
    let rates: Vec<f64> = stats.iter().map(|s| s.net_bits / 784.0).collect();
    let window = 2000usize.min(rates.len());
    let mut csv = String::from("index,net_bits_per_dim,moving_average\n");
    let mut avg = Vec::with_capacity(rates.len());
    let mut acc = 0.0;
    for (i, &r) in rates.iter().enumerate() {
        acc += r;
        if i >= window {
            acc -= rates[i - window];
        }
        let m = acc / window.min(i + 1) as f64;
        avg.push(m);
        csv.push_str(&format!("{i},{r:.6},{m:.6}\n"));
    }
    std::fs::write(dir.join("fig3.csv"), &csv)?;

    // ASCII plot of the moving average (after warmup).
    let plot: Vec<f64> = avg.iter().copied().skip(window / 2).collect();
    let (h, w) = (16usize, 78usize);
    let lo = plot.iter().cloned().fold(f64::INFINITY, f64::min).min(elbo) - 0.002;
    let hi = plot.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(elbo) + 0.002;
    println!(
        "BB-ANS rate, 2000-image moving average over {} images (3 shuffled copies):\n",
        images.len()
    );
    let mut grid = vec![vec![' '; w]; h];
    for col in 0..w {
        let i = col * plot.len().saturating_sub(1) / (w - 1).max(1);
        let v = plot[i.min(plot.len() - 1)];
        let row = ((hi - v) / (hi - lo) * (h - 1) as f64).round() as usize;
        grid[row.min(h - 1)][col] = '●';
    }
    let elbo_row = (((hi - elbo) / (hi - lo)) * (h - 1) as f64).round() as usize;
    for col in 0..w {
        if grid[elbo_row.min(h - 1)][col] == ' ' {
            grid[elbo_row.min(h - 1)][col] = '·';
        }
    }
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{hi:.3}")
        } else if r == h - 1 {
            format!("{lo:.3}")
        } else if r == elbo_row {
            format!("{elbo:.3}")
        } else {
            String::new()
        };
        println!("{label:>7} |{}", row.iter().collect::<String>());
    }
    println!("{:>7} +{}", "", "-".repeat(w));
    println!(
        "{:>7}  dotted line = negative test ELBO ({elbo:.4}); final average {:.4} bits/dim",
        "",
        avg.last().unwrap()
    );
    println!("CSV written to {}", dir.join("fig3.csv").display());
    Ok(())
}
