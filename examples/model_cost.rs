//! Paper §4.3 ("Communicating the model"), measured: BB-ANS needs the
//! receiver to hold the VAE weights, so the one-time cost of shipping
//! them must amortize over the data. This example computes the break-even
//! dataset size against each baseline codec.
//!
//! ```sh
//! cargo run --release --example model_cost
//! ```

use bbans::baselines::standard_suite;
use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::data::load_split;
use bbans::model::vae::load_native;
use bbans::runtime::{artifacts_available, default_artifact_dir};

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("artifacts not found — run `make artifacts`");
        std::process::exit(1);
    }
    println!("=== §4.3: amortizing the cost of communicating the model ===\n");

    for (model, binarized, weights_file, pixel_prec) in [
        ("bin", true, "weights_bin.bbwt", 16u32),
        ("full", false, "weights_full.bbwt", 18u32),
    ] {
        let raw_weights = std::fs::metadata(dir.join(weights_file))?.len() as f64;
        // The weights themselves compress (f32 tensors, gzip as a simple
        // proxy for the quantization literature the paper cites).
        let gz_weights =
            bbans::baselines::gzip::gzip_compress(&std::fs::read(dir.join(weights_file))?, 64)
                .len() as f64;

        let ds = load_split(&dir, "test", binarized)?.subset(2000);
        let backend = load_native(&dir, model)?;
        let codec = VaeCodec::new(
            &backend,
            BbAnsConfig {
                pixel_prec,
                ..Default::default()
            },
        )?;
        let (ans, _) = codec.encode_dataset(&ds.images)?;
        let bbans_bpd = ans.frac_bit_len() / (ds.len() as f64 * 784.0);

        println!(
            "model '{model}': weights {:.0} kB raw / {:.0} kB gzipped; BB-ANS {bbans_bpd:.4} bits/dim",
            raw_weights / 1000.0,
            gz_weights / 1000.0
        );
        for bcodec in standard_suite(binarized) {
            let base_bpd = bcodec.bits_per_dim(&ds)?;
            let margin = base_bpd - bbans_bpd; // bits/dim saved by BB-ANS
            if margin <= 0.0 {
                println!("  vs {:<11} never amortizes (baseline wins)", bcodec.name());
                continue;
            }
            let break_even = (gz_weights * 8.0) / (margin * 784.0);
            println!(
                "  vs {:<11} saves {margin:.3} bits/dim -> model cost amortized after {:>7.0} images",
                bcodec.name(),
                break_even.ceil()
            );
        }
        println!();
    }
    println!(
        "With ~10k-image datasets the model cost is recovered well before the\n\
         test set ends — the paper's argument that a broadly-trained model\n\
         amortizes (§4.3), quantified on this testbed."
    );
    Ok(())
}
