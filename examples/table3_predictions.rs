//! Table 3 reproduction: predicted BB-ANS rates with PixelVAE vs measured
//! benchmark codecs.
//!
//! The paper *predicts* the BB-ANS column from PixelVAE's reported ELBOs
//! (no PixelVAE is trained — §4.1); the benchmark columns are measured.
//! We do the same: the PixelVAE ELBOs are the paper's constants, and the
//! benchmarks run on (a) our binarized test set and (b) synthetic 64×64
//! "natural" images standing in for ImageNet64 (DESIGN.md §5).
//!
//! ```sh
//! cargo run --release --example table3_predictions
//! ```

use bbans::baselines::standard_suite;
use bbans::data::{load_split, synth};
use bbans::runtime::{artifacts_available, default_artifact_dir};

fn main() -> anyhow::Result<()> {
    // Paper-reported constants.
    let pixelvae_bin_mnist = 0.15; // bits/dim, PixelVAE ELBO on binarized MNIST
    let pixelvae_imagenet64 = 3.66; // bits/dim on ImageNet 64x64
    let paper_bench_bin = [("bz2", 0.25), ("gzip", 0.33), ("PNG", 0.78), ("WebP", 0.44)];
    let paper_bench_in64 = [("bz2", 6.72), ("gzip", 6.95), ("PNG", 5.71), ("WebP", 4.64)];

    // Row 1: binarized MNIST (ours where artifacts exist).
    println!("=== Table 3: predicted BB-ANS (PixelVAE ELBO) vs measured benchmarks ===\n");
    println!("Binarized MNIST (raw 1 bit/dim):");
    println!(
        "  BB-ANS w/ PixelVAE (predicted, paper constant): {pixelvae_bin_mnist:.2} bits/dim"
    );
    let dir = default_artifact_dir();
    if artifacts_available(&dir) {
        let ds = load_split(&dir, "test", true)?.subset(2000);
        for codec in standard_suite(true) {
            let rate = codec.bits_per_dim(&ds)?;
            let paper = paper_bench_bin
                .iter()
                .find(|(n, _)| codec.name().to_lowercase().contains(&n.to_lowercase()))
                .map(|(_, v)| *v)
                .unwrap_or(f64::NAN);
            println!(
                "  {:<12} measured {rate:>6.3}   (paper: {paper:.2})",
                codec.name()
            );
        }
    } else {
        println!("  (run `make artifacts` for measured benchmark rates)");
    }

    // Row 2: ImageNet64 stand-in.
    println!("\nImageNet 64x64 stand-in: synthetic natural images (raw 8 bits/dim):");
    println!(
        "  BB-ANS w/ PixelVAE (predicted, paper constant): {pixelvae_imagenet64:.2} bits/dim"
    );
    let nat = synth::natural(64, 64, 4242);
    for codec in standard_suite(false) {
        let rate = codec.bits_per_dim(&nat)?;
        let paper = paper_bench_in64
            .iter()
            .find(|(n, _)| codec.name().to_lowercase().contains(&n.to_lowercase()))
            .map(|(_, v)| *v)
            .unwrap_or(f64::NAN);
        println!(
            "  {:<12} measured {rate:>6.3}   (paper on real ImageNet64: {paper:.2})",
            codec.name()
        );
    }

    println!(
        "\nShape check (as in the paper): the predicted BB-ANS rate undercuts every\n\
         generic codec by a wide margin on both datasets; generic codecs sit in\n\
         the 4-8 bits/dim band on natural images vs PixelVAE's {pixelvae_imagenet64:.2}."
    );
    Ok(())
}
