//! Figure 1 reproduction: 30 binarized MNIST images vs the bitstream
//! sizes of PNG, bz2 and BB-ANS — rendered as ASCII (the paper shows the
//! raw bitstreams as ink; we show per-codec byte counts and scale bars).
//!
//! ```sh
//! cargo run --release --example fig1_bitstream
//! ```

use bbans::baselines::{BzCodec, ImageCodec, PngCodec};
use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::data::load_split;
use bbans::model::vae::load_native;
use bbans::runtime::{artifacts_available, default_artifact_dir};

fn bar(bytes: usize, per_char: f64) -> String {
    "█".repeat(((bytes as f64) / per_char).round().max(1.0) as usize)
}

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("artifacts not found — run `make artifacts` first");
        std::process::exit(1);
    }
    let ds = load_split(&dir, "test", true)?.subset(30);

    // Show the 30 images, 6 rows of 5, downsampled to character cells.
    println!("30 binarized test images:");
    for block in ds.images.chunks(5) {
        for y in (0..28).step_by(2) {
            let mut line = String::new();
            for img in block {
                for x in (0..28).step_by(1) {
                    let a = img[y * 28 + x];
                    let b = img[(y + 1).min(27) * 28 + x];
                    line.push(match (a, b) {
                        (0, 0) => ' ',
                        (0, _) => '▄',
                        (_, 0) => '▀',
                        _ => '█',
                    });
                }
                line.push_str("  ");
            }
            println!("{line}");
        }
        println!();
    }

    let raw = ds.raw_bytes(); // 30*784 bytes of {0,1} = 2940 bits raw
    let png = PngCodec { bit_depth: 1 }
        .compress_dataset(&ds)?
        .iter()
        .map(|b| b.len())
        .sum::<usize>();
    let bz = BzCodec {
        block_size: 256 * 1024,
    }
    .compress_dataset(&ds)?[0]
        .len();

    let backend = load_native(&dir, "bin")?;
    let codec = VaeCodec::new(&backend, BbAnsConfig::default())?;
    let (ans, _) = codec.encode_dataset(&ds.images)?;
    let bbans_bytes = ans.to_message().to_bytes().len();

    let raw_bits_per_dim = 1.0; // binarized
    println!("bitstream sizes for the 30 images ({raw} raw bytes, 1 bit/dim raw):\n");
    let per_char = (png as f64) / 60.0;
    for (name, bytes) in [("PNG", png), ("bz2", bz), ("BB-ANS", bbans_bytes)] {
        println!(
            "{name:>7}: {bytes:>6} B  {:>6.3} bits/dim  {}",
            bytes as f64 * 8.0 / (30.0 * 784.0),
            bar(bytes, per_char)
        );
    }
    println!(
        "\n(raw = {:.0} B at {raw_bits_per_dim} bit/dim; smaller bars = better, \
         matching the paper's visual)",
        30.0 * 784.0 / 8.0
    );
    Ok(())
}
