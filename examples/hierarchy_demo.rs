//! Hierarchical latents demo: naive BB-ANS vs the Bit-Swap schedule.
//!
//! ```sh
//! cargo run --release --example hierarchy_demo [N]
//! ```
//!
//! Fully artifact-free: the L-layer VAE is derived deterministically from
//! a seed, the `BBC3` container records that seed plus the model geometry,
//! and the decode side rebuilds the exact backend from the header alone.
//! The table shows the subsystem's point — the **initial bits** a fresh
//! chain borrows stay flat under Bit-Swap while the naive schedule's grow
//! with depth.

use bbans::bbans::container::HierContainer;
use bbans::bbans::hierarchy::{HierCodec, Schedule};
use bbans::bbans::BbAnsConfig;
use bbans::data::synth;
use bbans::model::hierarchy::{HierMeta, HierVae};
use bbans::model::Likelihood;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    let images = synth::binarize(&synth::digits(n, 5), 6).images;

    println!("=== hierarchical bits-back: naive vs Bit-Swap over {n} synthetic digits ===\n");
    println!(
        "{:<4} {:<9} {:>10} {:>14} {:>12}",
        "L", "schedule", "bits/dim", "initial bits", "bytes"
    );
    println!("{}", "-".repeat(54));

    for layers in 1..=3usize {
        let dims: Vec<usize> = (0..layers).map(|l| 32usize >> l).collect();
        let meta = HierMeta {
            name: format!("hier{layers}"),
            pixels: 784,
            dims,
            hidden: 64,
            likelihood: Likelihood::Bernoulli,
        };
        let backend = HierVae::random(meta, 0xB17);
        for schedule in [Schedule::Naive, Schedule::BitSwap] {
            let codec = HierCodec::new(&backend, BbAnsConfig::default(), schedule)?;
            let initial = codec.initial_bits(&images[0])?;
            let container = HierContainer::encode_with(&codec, &images, 2)?;
            let bytes = container.to_bytes();

            // Round-trip through the serialized bytes and a backend
            // rebuilt purely from the header.
            let parsed = HierContainer::from_bytes(&bytes)?;
            let rebuilt = parsed.build_backend()?;
            let codec2 = HierCodec::new(&rebuilt, parsed.cfg, parsed.schedule)?;
            anyhow::ensure!(parsed.decode_lockstep(&codec2)? == images, "lossless roundtrip");

            println!(
                "{:<4} {:<9} {:>10.4} {:>14} {:>12}",
                layers,
                schedule.name(),
                container.payload_bits_per_dim(),
                initial,
                bytes.len()
            );
        }
    }
    println!(
        "\nAll streams decoded losslessly via header-rebuilt models. Bit-Swap's\n\
         initial-bits cost stays ~flat as L grows; the naive schedule pays the\n\
         sum of every layer's posterior entropy before its first push."
    );
    Ok(())
}
