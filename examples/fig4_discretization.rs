//! Figure 4 / Appendix B reproduction: max-entropy discretization of the
//! standard Gaussian prior into 16 equal-mass buckets.
//!
//! ```sh
//! cargo run --release --example fig4_discretization [BITS]
//! ```

use bbans::codecs::gaussian::MaxEntropyBuckets;
use bbans::util::math::normal_pdf;

fn main() {
    let bits: u32 = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(4); // 16 buckets, like the paper's figure

    let b = MaxEntropyBuckets::new(bits);
    let n = b.num_buckets();
    println!("max-entropy discretization of N(0,1): {n} equal-mass buckets\n");

    // ASCII density with bucket edges marked.
    let (h, w) = (14usize, 72usize);
    let x_lo = -3.2f64;
    let x_hi = 3.2f64;
    let y_hi = normal_pdf(0.0) * 1.05;
    let col_x = |c: usize| x_lo + (x_hi - x_lo) * c as f64 / (w - 1) as f64;
    for row in 0..h {
        let y = y_hi * (h - row) as f64 / h as f64;
        let mut line = String::new();
        for c in 0..w {
            let x = col_x(c);
            let pdf = normal_pdf(x);
            let is_edge = (1..n).any(|i| {
                let e = b.edge(i);
                (x - e).abs() < (x_hi - x_lo) / w as f64 / 1.9 && pdf >= y
            });
            line.push(if is_edge {
                '|'
            } else if pdf >= y {
                '░'
            } else {
                ' '
            });
        }
        println!("  {line}");
    }
    println!("  {}", "-".repeat(w));

    if n <= 16 {
        println!("\n{:>6} {:>12} {:>12} {:>12} {:>10}", "bucket", "left edge", "centre", "right edge", "prior mass");
        for i in 0..n {
            println!(
                "{:>6} {:>12.4} {:>12.4} {:>12.4} {:>10.5}",
                i,
                b.edge(i),
                b.centre(i),
                b.edge(i + 1),
                1.0 / n as f64
            );
        }
    }
    println!(
        "\nEvery bucket holds prior mass exactly 1/{n}, so coding a latent under\n\
         the prior is a plain {bits}-bit uniform symbol — zero quantization loss\n\
         on the prior side (paper §2.5.1 / Appendix B)."
    );
}
