//! Serving demo: start the coordinator + TCP server in-process, hit it
//! with concurrent clients, and report throughput / latency / batching
//! metrics — the paper §4.2 parallelization argument, measured.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_demo [CLIENTS] [IMGS_PER_REQ]
//! ```
//!
//! Uses the PJRT backend (the real artifact path). Pass `native` as the
//! third arg to use the pure-Rust backend instead.

use std::time::{Duration, Instant};

use bbans::coordinator::{Client, ModelService, Server, ServiceParams};
use bbans::data::load_split;
use bbans::runtime::{artifacts_available, default_artifact_dir};

fn main() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("artifacts not found — run `make artifacts` first");
        std::process::exit(1);
    }
    let args: Vec<String> = std::env::args().collect();
    let n_clients: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(8);
    let per_req: usize = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(16);
    let use_pjrt = args.get(3).map(|s| s != "native").unwrap_or(true);

    let params = ServiceParams {
        max_jobs: 16,
        max_batch_delay: Duration::from_millis(3),
        ..Default::default()
    };
    let svc = ModelService::spawn(dir.clone(), use_pjrt, params);
    let server = Server::start("127.0.0.1:0", svc.handle())?;
    println!(
        "server on {} ({} backend); {n_clients} clients x {per_req} images",
        server.addr,
        if use_pjrt { "pjrt" } else { "native" }
    );

    let ds = load_split(&dir, "test", true)?;
    let addr = server.addr;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let images: Vec<Vec<u8>> = ds
            .images
            .iter()
            .skip(c * per_req)
            .take(per_req)
            .cloned()
            .collect();
        handles.push(std::thread::spawn(move || -> anyhow::Result<(f64, f64)> {
            let mut client = Client::connect(addr)?;
            let t = Instant::now();
            let container = client.compress("bin", 784, images.clone())?;
            let enc = t.elapsed().as_secs_f64();
            let t = Instant::now();
            let out = client.decompress(container)?;
            let dec = t.elapsed().as_secs_f64();
            anyhow::ensure!(out == images, "roundtrip mismatch");
            Ok((enc, dec))
        }));
    }
    let mut enc_lat = Vec::new();
    let mut dec_lat = Vec::new();
    for h in handles {
        let (e, d) = h.join().unwrap()?;
        enc_lat.push(e);
        dec_lat.push(d);
    }
    let wall = t0.elapsed().as_secs_f64();
    let total_imgs = (n_clients * per_req) as f64;

    enc_lat.sort_by(f64::total_cmp);
    dec_lat.sort_by(f64::total_cmp);
    println!("\nall {} roundtrips lossless ✓", n_clients);
    println!(
        "wall time {wall:.2}s  |  end-to-end throughput {:.1} img/s (enc+dec)",
        2.0 * total_imgs / wall
    );
    println!(
        "compress latency  p50 {:.0} ms   max {:.0} ms",
        enc_lat[n_clients / 2] * 1e3,
        enc_lat[n_clients - 1] * 1e3
    );
    println!(
        "decompress latency p50 {:.0} ms   max {:.0} ms",
        dec_lat[n_clients / 2] * 1e3,
        dec_lat[n_clients - 1] * 1e3
    );
    println!(
        "mean NN batch size {:.2} images/dispatch (1.0 would mean no cross-stream batching)",
        svc.metrics.mean_batch_size()
    );
    let mut client = Client::connect(addr)?;
    println!("\nserver metrics: {}", client.stats()?);

    server.stop();
    svc.shutdown();
    Ok(())
}
