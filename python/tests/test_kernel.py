"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; assert_allclose against ref.py.
This is the CORE correctness signal for the kernels that end up inside the
AOT artifacts Rust executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bbpmf as BK
from compile.kernels import dense as DK
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


@st.composite
def dense_case(draw):
    b = draw(st.integers(1, 9))
    k = draw(st.integers(1, 64))
    n = draw(st.integers(1, 48))
    seed = draw(st.integers(0, 2**31 - 1))
    act = draw(st.sampled_from(["none", "relu"]))
    return b, k, n, seed, act


@settings(max_examples=25, deadline=None)
@given(dense_case())
def test_dense_matches_ref(case):
    b, k, n, seed, act = case
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, k)).astype(np.float32)
    w = (rng.normal(size=(k, n)) * 0.1).astype(np.float32)
    bias = rng.normal(size=(n,)).astype(np.float32)
    got = DK.dense(x, w, bias, activation=act)
    want = R.dense_ref(x, w, bias, activation=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_dense_blocks_divide_irregular_shapes():
    # Odd shapes exercise the _block divisor search.
    rng = np.random.default_rng(0)
    x = rng.normal(size=(7, 13)).astype(np.float32)
    w = rng.normal(size=(13, 17)).astype(np.float32)
    b = rng.normal(size=(17,)).astype(np.float32)
    got = DK.dense(x, w, b, bm=4, bn=4)
    want = R.dense_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_dense_relu_clamps():
    x = -np.ones((2, 4), np.float32)
    w = np.eye(4, dtype=np.float32)
    b = np.zeros(4, np.float32)
    out = np.asarray(DK.dense(x, w, b, activation="relu"))
    assert (out == 0).all()


@st.composite
def bbpmf_case(draw):
    d = draw(st.integers(1, 64))
    seed = draw(st.integers(0, 2**31 - 1))
    lo = draw(st.floats(0.05, 1.0))
    hi = draw(st.floats(1.5, 40.0))
    return d, seed, lo, hi


@settings(max_examples=20, deadline=None)
@given(bbpmf_case())
def test_bbpmf_matches_ref(case):
    d, seed, lo, hi = case
    rng = np.random.default_rng(seed)
    a = rng.uniform(lo, hi, size=(d,)).astype(np.float32)
    b = rng.uniform(lo, hi, size=(d,)).astype(np.float32)
    got = BK.bbpmf(jnp.asarray(a), jnp.asarray(b))
    want = R.bbpmf_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-6)


def test_bbpmf_rows_are_pmfs():
    rng = np.random.default_rng(3)
    a = rng.uniform(0.3, 8.0, size=(784,)).astype(np.float32)
    b = rng.uniform(0.3, 8.0, size=(784,)).astype(np.float32)
    table = np.asarray(BK.bbpmf(jnp.asarray(a), jnp.asarray(b)))
    assert table.shape == (784, 256)
    assert (table >= 0).all()
    np.testing.assert_allclose(table.sum(-1), 1.0, atol=2e-3)


def test_bbpmf_batched_matches_loop():
    rng = np.random.default_rng(4)
    a = rng.uniform(0.5, 5.0, size=(3, 16)).astype(np.float32)
    b = rng.uniform(0.5, 5.0, size=(3, 16)).astype(np.float32)
    batched = np.asarray(BK.bbpmf(jnp.asarray(a), jnp.asarray(b)))
    for i in range(3):
        single = np.asarray(BK.bbpmf(jnp.asarray(a[i]), jnp.asarray(b[i])))
        np.testing.assert_allclose(batched[i], single, rtol=1e-6, atol=0)


def test_bbpmf_uniform_when_alpha_beta_one():
    ones = jnp.ones(8, jnp.float32)
    table = np.asarray(BK.bbpmf(ones, ones))
    # f32 lgamma at args up to ~260 carries ~1e-4 relative error.
    np.testing.assert_allclose(table, 1.0 / 256.0, rtol=5e-4)
