"""L2 correctness: VAE shapes, ELBO finiteness/improvement, and the
pallas-vs-ref forward equivalence on the export path."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile import train as T

jax.config.update("jax_platform_name", "cpu")


def _params(spec, seed=0):
    return M.init_params(spec, seed)


def test_encoder_shapes_and_sigma_positive():
    for name in ("bin", "full"):
        spec = M.SPECS[name]
        p = _params(spec)
        x = jnp.zeros((3, 784), jnp.float32)
        mu, sigma = M.encoder_apply(p, x)
        assert mu.shape == (3, spec["latent"])
        assert sigma.shape == (3, spec["latent"])
        assert (np.asarray(sigma) > 0).all()


def test_decoder_bin_outputs_probabilities():
    spec = M.SPECS["bin"]
    p = _params(spec)
    y = jnp.zeros((2, spec["latent"]), jnp.float32)
    probs = M.decoder_apply_bin(p, y)
    arr = np.asarray(probs)
    assert arr.shape == (2, 784)
    assert ((arr >= 0) & (arr <= 1)).all()


def test_decoder_full_outputs_positive_params_and_table():
    spec = M.SPECS["full"]
    p = _params(spec)
    y = jnp.zeros((2, spec["latent"]), jnp.float32)
    a, b = M.decoder_ab_full(p, y)
    assert (np.asarray(a) > 0).all() and (np.asarray(b) > 0).all()
    table = M.decoder_table_full(p, y)
    assert table.shape == (2, 784, 256)
    np.testing.assert_allclose(np.asarray(table).sum(-1), 1.0, atol=2e-3)


def test_elbo_finite_and_kl_nonnegative():
    rng = np.random.default_rng(1)
    for name in ("bin", "full"):
        spec = M.SPECS[name]
        p = _params(spec)
        levels = 2 if name == "bin" else 256
        x = rng.integers(0, levels, size=(4, 784)).astype(np.float32)
        eps = rng.normal(size=(4, spec["latent"])).astype(np.float32)
        e = M.elbo(p, spec, jnp.asarray(x), jnp.asarray(eps))
        assert np.isfinite(np.asarray(e)).all()
    mu = jnp.asarray(rng.normal(size=(5, 8)).astype(np.float32))
    sigma = jnp.asarray(rng.uniform(0.1, 3.0, size=(5, 8)).astype(np.float32))
    kl = M.gauss_kl(mu, sigma)
    assert (np.asarray(kl) >= 0).all()
    # KL of the prior with itself is zero.
    z = M.gauss_kl(jnp.zeros((1, 8)), jnp.ones((1, 8)))
    np.testing.assert_allclose(np.asarray(z), 0.0, atol=1e-6)


def test_pallas_and_ref_forward_agree():
    # The export path (pallas) must match the training path (ref).
    for name in ("bin", "full"):
        spec = M.SPECS[name]
        p = _params(spec, seed=7)
        x = jnp.asarray(np.random.default_rng(2).random((2, 784)).astype(np.float32))
        mu_r, sig_r = M.encoder_apply(p, x, kernel="ref")
        mu_p, sig_p = M.encoder_apply(p, x, kernel="pallas")
        np.testing.assert_allclose(np.asarray(mu_p), np.asarray(mu_r), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(sig_p), np.asarray(sig_r), rtol=1e-5, atol=1e-5)


def test_one_epoch_improves_elbo():
    spec = M.SPECS["bin"]
    rng = np.random.default_rng(3)
    # A tiny learnable dataset: two prototype patterns + noise.
    protos = (rng.random((2, 784)) < 0.2).astype(np.uint8)
    idx = rng.integers(0, 2, size=256)
    imgs = protos[idx]
    flips = rng.random(imgs.shape) < 0.02
    imgs = (imgs ^ flips).astype(np.uint8).reshape(256, 28, 28)
    params, bpd1 = T.train(spec, imgs, imgs[:64], epochs=1, batch=64, log=lambda *a, **k: None)
    params, bpd5 = T.train(spec, imgs, imgs[:64], epochs=5, batch=64, log=lambda *a, **k: None)
    assert bpd5 < bpd1, f"training should reduce -ELBO: {bpd1} -> {bpd5}"


def test_elbo_bits_per_dim_conversion():
    # -ELBO of exactly 784*ln2 nats == 1 bit/dim.
    e = jnp.asarray([-784.0 * np.log(2.0)])
    np.testing.assert_allclose(np.asarray(M.elbo_bits_per_dim(e)), 1.0, rtol=1e-6)
