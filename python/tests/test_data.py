"""Synthetic-MNIST generator and IDX I/O tests."""

import numpy as np

from compile import data as D


def test_split_deterministic_and_shaped():
    a_imgs, a_labels = D.make_split(12, 99)
    b_imgs, b_labels = D.make_split(12, 99)
    assert (a_imgs == b_imgs).all()
    assert (a_labels == b_labels).all()
    assert a_imgs.shape == (12, 28, 28)
    assert a_imgs.dtype == np.uint8
    assert set(np.unique(a_labels)) <= set(range(10))


def test_different_seeds_differ():
    a, _ = D.make_split(6, 1)
    b, _ = D.make_split(6, 2)
    assert (a != b).any()


def test_images_look_mnist_like():
    imgs, _ = D.make_split(50, 7)
    # Sparse foreground on exact-zero background.
    zero_frac = (imgs == 0).mean()
    assert 0.5 < zero_frac < 0.95, zero_frac
    # Strokes reach high intensity.
    assert (imgs.max(axis=(1, 2)) > 150).all()


def test_binarize_deterministic_and_bernoulli_like():
    imgs, _ = D.make_split(30, 3)
    b1 = D.binarize(imgs, 5)
    b2 = D.binarize(imgs, 5)
    assert (b1 == b2).all()
    assert set(np.unique(b1)) <= {0, 1}
    # Mean of binarized ≈ mean intensity / 255.
    assert abs(b1.mean() - imgs.mean() / 255.0) < 0.01


def test_idx_roundtrip(tmp_path):
    imgs, labels = D.make_split(5, 11)
    pi = tmp_path / "imgs.idx"
    pl = tmp_path / "labels.idx"
    D.write_idx_images(str(pi), imgs)
    D.write_idx_labels(str(pl), labels)
    assert (D.read_idx_images(str(pi)) == imgs).all()
    assert (D.read_idx_labels(str(pl)) == labels).all()


def test_ensure_dataset_is_idempotent(tmp_path, monkeypatch):
    # Shrink the dataset so the test is fast.
    monkeypatch.setattr(D, "TRAIN_N", 8)
    monkeypatch.setattr(D, "TEST_N", 4)
    d = str(tmp_path / "data")
    paths1 = D.ensure_dataset(d)
    mtimes = {k: __import__("os").path.getmtime(v) for k, v in paths1.items()}
    paths2 = D.ensure_dataset(d)
    assert paths1 == paths2
    for k, v in paths2.items():
        assert __import__("os").path.getmtime(v) == mtimes[k], "must not regenerate"
    imgs = D.read_idx_images(paths1["train_images"])
    assert imgs.shape == (8, 28, 28)
