"""AOT pipeline: data -> train -> lower to HLO text + weights + config.

This is the only Python entrypoint in the system; it runs once at
``make artifacts`` and produces everything the Rust runtime needs:

* ``artifacts/data/*``                 — synthetic MNIST in IDX format
* ``artifacts/params_{bin,full}.npz``  — trained parameters (cache)
* ``artifacts/{enc,dec}_{bin,full}_b{B}.hlo.txt`` — AOT-lowered graphs
  (weights baked in as constants; Pallas kernels inlined, interpret mode)
* ``artifacts/weights_{bin,full}.bbwt`` — raw weights for the native Rust
  backend (cross-checking + artifact-free operation)
* ``artifacts/model_config.json``      — dims, ELBOs, file index

HLO **text** is the interchange format (not serialized protos): jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as M
from . import train as train_mod

BATCH_SIZES = (1, 4, 16)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the trained weights are baked into the graph;
    # the default printer elides them as `constant({...})`, which the HLO
    # text parser on the Rust side cannot reconstruct.
    return comp.as_hlo_text(print_large_constants=True)


def write_bbwt(path: str, params: dict[str, jnp.ndarray]) -> None:
    """Weights binary for the Rust native backend.

    Layout (little-endian): magic b"BBWT", u32 version, u32 tensor count,
    then per tensor: u16 name_len, name bytes, u8 ndim, u32 dims...,
    f32 data.
    """
    with open(path, "wb") as f:
        f.write(b"BBWT")
        f.write(struct.pack("<II", 1, len(params)))
        for name in sorted(params):
            arr = np.asarray(params[name], dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def train_or_load(spec, paths, out_dir: str, epochs: int) -> tuple[dict, float]:
    cache = os.path.join(out_dir, f"params_{spec['name']}.npz")
    imgs_key = "train_images_bin" if spec["likelihood"] == "bernoulli" else "train_images"
    test_key = "test_images_bin" if spec["likelihood"] == "bernoulli" else "test_images"
    train_imgs = data_mod.read_idx_images(paths[imgs_key])
    test_imgs = data_mod.read_idx_images(paths[test_key])
    if os.path.exists(cache):
        print(f"[aot] loading cached params {cache}", flush=True)
        loaded = np.load(cache)
        params = {k: jnp.asarray(loaded[k]) for k in loaded.files if k != "__elbo__"}
        elbo_bpd = float(loaded["__elbo__"])
        return params, elbo_bpd
    params, elbo_bpd = train_mod.train(spec, train_imgs, test_imgs, epochs=epochs)
    np.savez(
        cache,
        __elbo__=np.float64(elbo_bpd),
        **{k: np.asarray(v) for k, v in params.items()},
    )
    return params, elbo_bpd


def export_model(spec, params, out_dir: str) -> dict:
    """Lower encoder/decoder at each batch size; return the file index."""
    name = spec["name"]
    enc_fn, dec_fn = M.export_fns(params, spec, kernel="pallas")
    index: dict = {"encoder_hlo": {}, "decoder_hlo": {}}
    for b in BATCH_SIZES:
        x_spec = jax.ShapeDtypeStruct((b, M.PIXELS), jnp.float32)
        y_spec = jax.ShapeDtypeStruct((b, spec["latent"]), jnp.float32)
        enc_path = f"enc_{name}_b{b}.hlo.txt"
        dec_path = f"dec_{name}_b{b}.hlo.txt"
        print(f"[aot] lowering {enc_path} ...", flush=True)
        enc_hlo = to_hlo_text(jax.jit(enc_fn).lower(x_spec))
        with open(os.path.join(out_dir, enc_path), "w") as f:
            f.write(enc_hlo)
        print(f"[aot] lowering {dec_path} ...", flush=True)
        dec_hlo = to_hlo_text(jax.jit(dec_fn).lower(y_spec))
        with open(os.path.join(out_dir, dec_path), "w") as f:
            f.write(dec_hlo)
        index["encoder_hlo"][str(b)] = enc_path
        index["decoder_hlo"][str(b)] = dec_path
    return index


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--skip-train", action="store_true", help="require cached params")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    paths = data_mod.ensure_dataset(os.path.join(out_dir, "data"))

    config: dict = {
        "version": 1,
        "pixels": M.PIXELS,
        "pixel_levels": M.PIXEL_LEVELS,
        "data": {k: os.path.join("data", data_mod.FILES[k]) for k in data_mod.FILES},
        "counts": {"train": data_mod.TRAIN_N, "test": data_mod.TEST_N},
        "models": {},
    }

    for spec_name in ("bin", "full"):
        spec = M.SPECS[spec_name]
        params, elbo_bpd = train_or_load(spec, paths, out_dir, args.epochs)
        weights_file = f"weights_{spec_name}.bbwt"
        write_bbwt(os.path.join(out_dir, weights_file), params)
        index = export_model(spec, params, out_dir)
        config["models"][spec_name] = {
            "latent_dim": spec["latent"],
            "hidden": spec["hidden"],
            "likelihood": spec["likelihood"],
            "test_elbo_bpd": elbo_bpd,
            "weights": weights_file,
            "logvar_clip": [M.LOGVAR_MIN, M.LOGVAR_MAX],
            **index,
        }

    with open(os.path.join(out_dir, "model_config.json"), "w") as f:
        json.dump(config, f, indent=2)
    print(f"[aot] wrote {out_dir}/model_config.json", flush=True)


if __name__ == "__main__":
    main()
