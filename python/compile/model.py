"""L2: the VAE models from the paper (§3.1-3.2), in pure JAX.

Two variants, exactly as evaluated in the paper:

* ``bin``  — binarized MNIST: 784-100 recognition/generative nets with a
  40-dim latent and a per-pixel **Bernoulli** likelihood.
* ``full`` — raw MNIST: 784-200 nets, 50-dim latent, per-pixel
  **beta-binomial** likelihood (two positive parameters per pixel).

Both use a standard Gaussian prior and diagonal-Gaussian approximate
posterior. The training objective is the ELBO, which (paper §2.2) equals
the negative expected BB-ANS message length — so the trained ELBO is the
compression-rate target the Rust codec must hit.

The forward passes are parameterized over the dense-layer implementation:
``kernel="ref"`` uses the pure-jnp oracle (fast under jit — used for
training), ``kernel="pallas"`` uses the L1 Pallas kernels (used for the
AOT-exported inference graphs that Rust executes).
"""

from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import bbpmf as bbpmf_mod
from .kernels import dense as dense_mod
from .kernels import ref as ref_mod

Params = dict[str, jnp.ndarray]

PIXELS = 784
PIXEL_LEVELS = 256

BIN_SPEC = dict(name="bin", in_dim=PIXELS, hidden=100, latent=40, likelihood="bernoulli")
FULL_SPEC = dict(name="full", in_dim=PIXELS, hidden=200, latent=50, likelihood="beta_binomial")

SPECS = {"bin": BIN_SPEC, "full": FULL_SPEC}

# Clamp for the posterior log-variance: keeps sigma in a range where the
# discretized-Gaussian codec is well-conditioned.
LOGVAR_MIN, LOGVAR_MAX = -10.0, 10.0
# Positivity floor for beta-binomial parameters.
AB_EPS = 1e-3


def _dense_fn(kernel: str) -> Callable[..., jnp.ndarray]:
    if kernel == "ref":
        return ref_mod.dense_ref
    if kernel == "pallas":
        return dense_mod.dense
    raise ValueError(f"unknown kernel impl {kernel!r}")


def _bbpmf_fn(kernel: str) -> Callable[..., jnp.ndarray]:
    if kernel == "ref":
        return ref_mod.bbpmf_ref
    if kernel == "pallas":
        return bbpmf_mod.bbpmf
    raise ValueError(f"unknown kernel impl {kernel!r}")


# ------------------------------------------------------------------ init


def init_params(spec: dict[str, Any], seed: int) -> Params:
    """Glorot-initialized parameters for both networks of one VAE."""
    rng = np.random.default_rng(seed)
    in_dim, hidden, latent = spec["in_dim"], spec["hidden"], spec["latent"]
    out_heads = 1 if spec["likelihood"] == "bernoulli" else 2

    def glorot(fan_in: int, fan_out: int) -> np.ndarray:
        s = math.sqrt(2.0 / (fan_in + fan_out))
        return rng.normal(0.0, s, size=(fan_in, fan_out)).astype(np.float32)

    p = {
        # Recognition (encoder) net.
        "enc_w1": glorot(in_dim, hidden),
        "enc_b1": np.zeros(hidden, np.float32),
        "enc_w_mu": glorot(hidden, latent),
        "enc_b_mu": np.zeros(latent, np.float32),
        "enc_w_lv": glorot(hidden, latent),
        "enc_b_lv": np.zeros(latent, np.float32),
        # Generative (decoder) net.
        "dec_w1": glorot(latent, hidden),
        "dec_b1": np.zeros(hidden, np.float32),
        "dec_w_out": glorot(hidden, in_dim * out_heads),
        "dec_b_out": np.zeros(in_dim * out_heads, np.float32),
    }
    return {k: jnp.asarray(v) for k, v in p.items()}


# -------------------------------------------------------------- forward


def encoder_apply(params: Params, x: jnp.ndarray, kernel: str = "ref") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Recognition net: x [B, 784] (already scaled) -> (mu, sigma) [B, L]."""
    dense = _dense_fn(kernel)
    h = dense(x, params["enc_w1"], params["enc_b1"], activation="relu")
    mu = dense(h, params["enc_w_mu"], params["enc_b_mu"], activation="none")
    logvar = dense(h, params["enc_w_lv"], params["enc_b_lv"], activation="none")
    logvar = jnp.clip(logvar, LOGVAR_MIN, LOGVAR_MAX)
    sigma = jnp.exp(0.5 * logvar)
    return mu, sigma


def decoder_apply_bin(params: Params, y: jnp.ndarray, kernel: str = "ref") -> jnp.ndarray:
    """Generative net (bin): y [B, L] -> Bernoulli probs [B, 784]."""
    dense = _dense_fn(kernel)
    h = dense(y, params["dec_w1"], params["dec_b1"], activation="relu")
    logits = dense(h, params["dec_w_out"], params["dec_b_out"], activation="none")
    return jax.nn.sigmoid(logits)


def decoder_logits_bin(params: Params, y: jnp.ndarray, kernel: str = "ref") -> jnp.ndarray:
    dense = _dense_fn(kernel)
    h = dense(y, params["dec_w1"], params["dec_b1"], activation="relu")
    return dense(h, params["dec_w_out"], params["dec_b_out"], activation="none")


def decoder_ab_full(params: Params, y: jnp.ndarray, kernel: str = "ref") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Generative net (full): y [B, L] -> beta-binomial (alpha, beta) [B, 784]."""
    dense = _dense_fn(kernel)
    h = dense(y, params["dec_w1"], params["dec_b1"], activation="relu")
    raw = dense(h, params["dec_w_out"], params["dec_b_out"], activation="none")
    raw_a, raw_b = raw[:, :PIXELS], raw[:, PIXELS:]
    alpha = jax.nn.softplus(raw_a) + AB_EPS
    beta = jax.nn.softplus(raw_b) + AB_EPS
    return alpha, beta


def decoder_table_full(params: Params, y: jnp.ndarray, kernel: str = "ref") -> jnp.ndarray:
    """Full decoder incl. L1 PMF-table kernel: y [B, L] -> [B, 784, 256]."""
    alpha, beta = decoder_ab_full(params, y, kernel)
    table = _bbpmf_fn(kernel)(alpha, beta)
    return table


# ----------------------------------------------------------------- ELBO


def gauss_kl(mu: jnp.ndarray, sigma: jnp.ndarray) -> jnp.ndarray:
    """KL(N(mu, sigma^2) || N(0, I)), summed over latent dims. [B]"""
    return 0.5 * jnp.sum(mu**2 + sigma**2 - 1.0 - 2.0 * jnp.log(sigma), axis=-1)


def bernoulli_loglik(logits: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Sum over pixels of log Bernoulli(x | sigmoid(logits)). [B]"""
    # log p = x * log(sig) + (1-x) * log(1-sig), numerically via softplus.
    return jnp.sum(x * logits - jax.nn.softplus(logits), axis=-1) - 0.0


def beta_binomial_loglik(alpha: jnp.ndarray, beta: jnp.ndarray, k: jnp.ndarray, n: int = 255) -> jnp.ndarray:
    """Sum over pixels of log BetaBin(k | n, alpha, beta). [B]"""
    from jax import lax

    nf = jnp.float32(n)
    log_binom = lax.lgamma(nf + 1.0) - lax.lgamma(k + 1.0) - lax.lgamma(nf - k + 1.0)
    num = lax.lgamma(k + alpha) + lax.lgamma(nf - k + beta) - lax.lgamma(nf + alpha + beta)
    den = lax.lgamma(alpha) + lax.lgamma(beta) - lax.lgamma(alpha + beta)
    return jnp.sum(log_binom + num - den, axis=-1)


def elbo(params: Params, spec: dict[str, Any], x_raw: jnp.ndarray, eps: jnp.ndarray, kernel: str = "ref") -> jnp.ndarray:
    """Single-sample ELBO (nats) per image. [B]

    ``x_raw`` is the observed symbol array: {0,1} for bin, {0..255} for
    full. ``eps`` is standard normal noise of shape [B, latent].
    """
    if spec["likelihood"] == "bernoulli":
        x_in = x_raw
    else:
        x_in = x_raw / 255.0
    mu, sigma = encoder_apply(params, x_in, kernel)
    y = mu + sigma * eps
    kl = gauss_kl(mu, sigma)
    if spec["likelihood"] == "bernoulli":
        logits = decoder_logits_bin(params, y, kernel)
        ll = bernoulli_loglik(logits, x_raw)
    else:
        alpha, beta = decoder_ab_full(params, y, kernel)
        ll = beta_binomial_loglik(alpha, beta, x_raw)
    return ll - kl


def elbo_bits_per_dim(elbo_nats: jnp.ndarray) -> jnp.ndarray:
    """Convert per-image ELBO (nats) to bits per pixel (paper Table 2)."""
    return -elbo_nats / (PIXELS * math.log(2.0))


# --------------------------------------------------------------- export


def export_fns(params: Params, spec: dict[str, Any], kernel: str = "pallas"):
    """The (encoder, decoder) inference functions that get AOT-lowered.

    Weights are closed over, so they appear as constants in the HLO and the
    artifacts are self-contained. Outputs are tuples (lowered with
    return_tuple=True; the Rust side unwraps).
    """

    def encoder(x):
        mu, sigma = encoder_apply(params, x, kernel)
        return (mu, sigma)

    if spec["likelihood"] == "bernoulli":

        def decoder(y):
            return (decoder_apply_bin(params, y, kernel),)

    else:

        def decoder(y):
            return (decoder_table_full(params, y, kernel),)

    return encoder, decoder
