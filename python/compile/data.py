"""Synthetic MNIST: a procedural stand-in for the MNIST dataset.

This environment has no network access, so the real MNIST files cannot be
downloaded. Per DESIGN.md §5 we substitute a *procedural digit renderer*
that produces 28x28 grayscale digit images with an intensity/sparsity
profile close to MNIST's: per-class stroke skeletons, random affine jitter,
stroke-thickness variation, blur and intensity noise. Everything is
deterministic in the seed.

Files are written in the original IDX format (big-endian magic + dims),
so real MNIST drops in unchanged if the files are placed in
``artifacts/data/`` with the same names.

The stochastic binarization of Salakhutdinov & Murray (2008) is also
materialized here (pixels sampled Bernoulli(intensity/255) once, with a
fixed seed) so that Rust and Python operate on the identical binary
dataset without needing bit-matched PRNGs across languages.
"""

from __future__ import annotations

import os
import struct

import numpy as np

IMG = 28

# Stroke skeletons per digit, in a [0,1]^2 coordinate frame (x right, y
# down). Each stroke is a polyline; rendering draws line segments.
_SKELETONS: dict[int, list[list[tuple[float, float]]]] = {
    0: [[(0.5, 0.12), (0.74, 0.2), (0.8, 0.5), (0.72, 0.8), (0.5, 0.88),
         (0.28, 0.8), (0.2, 0.5), (0.28, 0.2), (0.5, 0.12)]],
    1: [[(0.35, 0.28), (0.52, 0.14), (0.52, 0.86)],
        [(0.34, 0.86), (0.68, 0.86)]],
    2: [[(0.28, 0.3), (0.38, 0.15), (0.62, 0.14), (0.72, 0.3), (0.66, 0.48),
         (0.3, 0.82), (0.74, 0.84)]],
    3: [[(0.3, 0.2), (0.55, 0.13), (0.7, 0.27), (0.55, 0.45), (0.42, 0.48)],
        [(0.42, 0.48), (0.58, 0.5), (0.72, 0.68), (0.55, 0.86), (0.3, 0.8)]],
    4: [[(0.62, 0.86), (0.62, 0.14), (0.24, 0.62), (0.78, 0.62)]],
    5: [[(0.7, 0.15), (0.34, 0.15), (0.3, 0.45), (0.55, 0.42), (0.72, 0.56),
         (0.7, 0.76), (0.5, 0.88), (0.28, 0.8)]],
    6: [[(0.62, 0.12), (0.4, 0.3), (0.28, 0.55), (0.32, 0.78), (0.52, 0.88),
         (0.7, 0.76), (0.68, 0.56), (0.5, 0.48), (0.32, 0.56)]],
    7: [[(0.24, 0.16), (0.76, 0.16), (0.45, 0.86)],
        [(0.36, 0.52), (0.64, 0.52)]],
    8: [[(0.5, 0.14), (0.68, 0.25), (0.62, 0.44), (0.5, 0.5), (0.38, 0.44),
         (0.32, 0.25), (0.5, 0.14)],
        [(0.5, 0.5), (0.7, 0.6), (0.72, 0.78), (0.5, 0.88), (0.28, 0.78),
         (0.3, 0.6), (0.5, 0.5)]],
    9: [[(0.68, 0.44), (0.5, 0.52), (0.32, 0.42), (0.3, 0.24), (0.5, 0.12),
         (0.68, 0.22), (0.68, 0.44), (0.62, 0.7), (0.45, 0.88)]],
}


def _render_digit(digit: int, rng: np.random.Generator) -> np.ndarray:
    """Render one 28x28 uint8 image of `digit` with random jitter."""
    # Random affine: rotation, anisotropic scale, translation, shear.
    theta = rng.uniform(-0.22, 0.22)
    sx, sy = rng.uniform(0.82, 1.1, size=2)
    shear = rng.uniform(-0.15, 0.15)
    tx, ty = rng.uniform(-0.06, 0.06, size=2)
    ca, sa = np.cos(theta), np.sin(theta)
    mat = np.array([[ca * sx, -sa * sy + shear * ca], [sa * sx, ca * sy + shear * sa]])

    thickness = rng.uniform(0.9, 1.7)
    # Supersample on a 2x grid for cheap anti-aliasing.
    ss = 2
    size = IMG * ss
    img = np.zeros((size, size), dtype=np.float32)

    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    # Map pixel centres back to the unit frame.
    px = (xx + 0.5) / size
    py = (yy + 0.5) / size

    for stroke in _SKELETONS[digit]:
        pts = np.array(stroke, dtype=np.float64)
        # Per-stroke point jitter.
        pts = pts + rng.normal(0.0, 0.012, size=pts.shape)
        # Affine about the centre.
        pts = (pts - 0.5) @ mat.T + 0.5 + np.array([tx, ty])
        for (x0, y0), (x1, y1) in zip(pts[:-1], pts[1:]):
            # Distance from each pixel to the segment.
            dx, dy = x1 - x0, y1 - y0
            seg_len2 = dx * dx + dy * dy + 1e-12
            t = ((px - x0) * dx + (py - y0) * dy) / seg_len2
            t = np.clip(t, 0.0, 1.0)
            cx = x0 + t * dx
            cy = y0 + t * dy
            d = np.sqrt((px - cx) ** 2 + (py - cy) ** 2)
            # Pen profile: soft disc of radius ~thickness*0.032.
            r = 0.032 * thickness
            contrib = np.clip(1.0 - (d / r) ** 2, 0.0, 1.0)
            img = np.maximum(img, contrib)

    # Downsample 2x (box filter) back to 28x28.
    img = img.reshape(IMG, ss, IMG, ss).mean(axis=(1, 3))
    # Intensity variation + mild sensor noise, like MNIST's gray ramps.
    peak = rng.uniform(0.75, 1.0)
    img = img * peak
    img = img + rng.normal(0.0, 0.012, size=img.shape)
    # MNIST backgrounds are exactly zero; kill the faint sensor noise off
    # the strokes so the sparsity profile (and thus baseline codec
    # behaviour) matches the real dataset.
    img[img < 0.04] = 0.0
    img = np.clip(img, 0.0, 1.0)
    return (img * 255.0 + 0.5).astype(np.uint8)


def make_split(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` images + labels deterministically from `seed`."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.uint8)
    imgs = np.zeros((n, IMG, IMG), dtype=np.uint8)
    for i in range(n):
        imgs[i] = _render_digit(int(labels[i]), rng)
    return imgs, labels


def binarize(images: np.ndarray, seed: int) -> np.ndarray:
    """Stochastic binarization (Salakhutdinov & Murray 2008), fixed seed."""
    rng = np.random.default_rng(seed)
    p = images.astype(np.float32) / 255.0
    return (rng.random(size=images.shape) < p).astype(np.uint8)


# ---------------------------------------------------------------- IDX I/O


def write_idx_images(path: str, images: np.ndarray) -> None:
    assert images.ndim == 3 and images.dtype == np.uint8
    n, r, c = images.shape
    with open(path, "wb") as f:
        f.write(struct.pack(">IIII", 0x00000803, n, r, c))
        f.write(images.tobytes())


def write_idx_labels(path: str, labels: np.ndarray) -> None:
    assert labels.ndim == 1 and labels.dtype == np.uint8
    with open(path, "wb") as f:
        f.write(struct.pack(">II", 0x00000801, labels.shape[0]))
        f.write(labels.tobytes())


def read_idx_images(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        magic, n, r, c = struct.unpack(">IIII", f.read(16))
        assert magic == 0x00000803, f"bad magic {magic:#x}"
        data = np.frombuffer(f.read(n * r * c), dtype=np.uint8)
    return data.reshape(n, r, c)


def read_idx_labels(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        magic, n = struct.unpack(">II", f.read(8))
        assert magic == 0x00000801, f"bad magic {magic:#x}"
        return np.frombuffer(f.read(n), dtype=np.uint8)


# Default dataset spec. Smaller than the real 60k train split to keep
# `make artifacts` minutes-scale; the test split matches MNIST's 10k so the
# paper's Table 2 protocol ("compress the test set") is preserved.
TRAIN_N = 20_000
TEST_N = 10_000
TRAIN_SEED = 1001
TEST_SEED = 2002
BINARIZE_SEED = 3003

FILES = {
    "train_images": "train-images-idx3-ubyte",
    "train_labels": "train-labels-idx1-ubyte",
    "test_images": "t10k-images-idx3-ubyte",
    "test_labels": "t10k-labels-idx1-ubyte",
    "train_images_bin": "train-images-bin-idx3-ubyte",
    "test_images_bin": "t10k-images-bin-idx3-ubyte",
}


def ensure_dataset(data_dir: str) -> dict[str, str]:
    """Generate the dataset into `data_dir` unless already present.

    Returns a dict of absolute paths keyed as in FILES.
    """
    os.makedirs(data_dir, exist_ok=True)
    paths = {k: os.path.join(data_dir, v) for k, v in FILES.items()}
    if all(os.path.exists(p) for p in paths.values()):
        return paths

    print(f"[data] generating synthetic MNIST into {data_dir} ...", flush=True)
    train_imgs, train_labels = make_split(TRAIN_N, TRAIN_SEED)
    test_imgs, test_labels = make_split(TEST_N, TEST_SEED)
    write_idx_images(paths["train_images"], train_imgs)
    write_idx_labels(paths["train_labels"], train_labels)
    write_idx_images(paths["test_images"], test_imgs)
    write_idx_labels(paths["test_labels"], test_labels)
    write_idx_images(paths["train_images_bin"], binarize(train_imgs, BINARIZE_SEED))
    write_idx_images(paths["test_images_bin"], binarize(test_imgs, BINARIZE_SEED + 1))
    print("[data] done", flush=True)
    return paths


if __name__ == "__main__":
    import sys

    out = sys.argv[1] if len(sys.argv) > 1 else "../artifacts/data"
    ensure_dataset(out)
