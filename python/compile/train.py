"""Build-time VAE training: minibatch Adam on the ELBO, pure JAX.

No optax/flax in this offline environment — Adam is ~20 lines. The
training loop jits one step (ref kernels: interpret-mode Pallas is not for
training) and logs the test-ELBO in bits/dim, the quantity Table 2
compares against the achieved BB-ANS rate.
"""

from __future__ import annotations

import math
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def adam_init(params: M.Params) -> dict[str, Any]:
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.int32)}


@partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps"))
def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    tf = t.astype(jnp.float32)
    mhat_scale = 1.0 / (1.0 - b1**tf)
    vhat_scale = 1.0 / (1.0 - b2**tf)
    new_params = {
        k: params[k] - lr * (m[k] * mhat_scale) / (jnp.sqrt(v[k] * vhat_scale) + eps)
        for k in params
    }
    return new_params, {"m": m, "v": v, "t": t}


def make_train_step(spec):
    def loss_fn(params, x_raw, eps):
        e = M.elbo(params, spec, x_raw, eps, kernel="ref")
        return -jnp.mean(e)

    @jax.jit
    def step(params, opt_state, x_raw, eps):
        loss, grads = jax.value_and_grad(loss_fn)(params, x_raw, eps)
        params, opt_state = adam_update(params, grads, opt_state)
        return params, opt_state, loss

    return step


def evaluate_elbo(params, spec, images: np.ndarray, seed: int = 0, batch: int = 500) -> float:
    """Mean test ELBO in bits/dim (single posterior sample per image)."""
    key = jax.random.PRNGKey(seed)
    n = images.shape[0]
    total = 0.0

    @jax.jit
    def batch_elbo(params, x_raw, eps):
        return jnp.sum(M.elbo(params, spec, x_raw, eps, kernel="ref"))

    for i in range(0, n, batch):
        x = jnp.asarray(images[i : i + batch].reshape(-1, M.PIXELS).astype(np.float32))
        key, sub = jax.random.split(key)
        eps = jax.random.normal(sub, (x.shape[0], spec["latent"]))
        total += float(batch_elbo(params, x, eps))
    mean_nats = total / n
    return -mean_nats / (M.PIXELS * math.log(2.0))


def train(
    spec,
    train_images: np.ndarray,
    test_images: np.ndarray,
    epochs: int = 20,
    batch: int = 128,
    seed: int = 0,
    log=print,
) -> tuple[M.Params, float]:
    """Train one VAE; returns (params, test_elbo_bits_per_dim)."""
    params = M.init_params(spec, seed)
    opt_state = adam_init(params)
    step = make_train_step(spec)

    n = train_images.shape[0]
    x_all = train_images.reshape(n, M.PIXELS).astype(np.float32)
    key = jax.random.PRNGKey(seed + 1)
    rng = np.random.default_rng(seed + 2)

    t0 = time.time()
    for epoch in range(epochs):
        perm = rng.permutation(n)
        losses = []
        for i in range(0, n - batch + 1, batch):
            xb = jnp.asarray(x_all[perm[i : i + batch]])
            key, sub = jax.random.split(key)
            eps = jax.random.normal(sub, (batch, spec["latent"]))
            params, opt_state, loss = step(params, opt_state, xb, eps)
            losses.append(float(loss))
        bpd = float(np.mean(losses)) / (M.PIXELS * math.log(2.0))
        log(
            f"[train:{spec['name']}] epoch {epoch + 1}/{epochs} "
            f"train -ELBO {bpd:.4f} bits/dim ({time.time() - t0:.0f}s)",
            flush=True,
        )
    test_bpd = evaluate_elbo(params, spec, test_images, seed=seed + 3)
    log(f"[train:{spec['name']}] test -ELBO {test_bpd:.4f} bits/dim", flush=True)
    return params, test_bpd
