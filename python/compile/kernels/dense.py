"""L1 Pallas kernel: fused dense layer ``activation(x @ W + b)``.

TPU mapping (DESIGN.md §4, §Hardware-Adaptation): the grid tiles the output
[B, N] into (bm, bn) blocks; each grid step keeps an [bm, K] activation
tile, a [K, bn] weight tile and the [bm, bn] output tile resident in VMEM
and drives the MXU with a single f32 contraction. The VAE layer sizes here
(K, N <= 800) let us keep the full K dimension per block, so no K-loop /
accumulator is needed; bm/bn are chosen so each block's working set stays
well under VMEM (see EXPERIMENTS.md §Perf for the footprint table).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO, which both the
pytest oracle checks and the Rust runtime execute. The *structure* (grid,
BlockSpecs) is still the TPU schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _block(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is <= want (keeps grids exact)."""
    for cand in range(min(want, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bn"))
def dense(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    activation: str = "none",
    bm: int = 128,
    bn: int = 128,
) -> jnp.ndarray:
    """Fused dense layer via a Pallas kernel. x: [B, K], w: [K, N], b: [N]."""
    assert x.ndim == 2 and w.ndim == 2 and b.ndim == 1
    B, K = x.shape
    K2, N = w.shape
    assert K == K2 and b.shape[0] == N, (x.shape, w.shape, b.shape)
    bm = _block(B, bm)
    bn = _block(N, bn)
    grid = (B // bm, N // bn)
    return pl.pallas_call(
        functools.partial(_dense_kernel, activation=activation),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32))
