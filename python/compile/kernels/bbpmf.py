"""L1 Pallas kernel: beta-binomial PMF table.

From per-pixel ``(alpha, beta)`` produce the full per-pixel PMF over the
256-symbol pixel alphabet, *inside the decoder graph*. The Rust hot path
then quantizes a ready table instead of evaluating lgamma per symbol —
moving the special-function work onto the accelerator (paper §4.2 wants
exactly this: CDF computation on parallel hardware).

TPU mapping: elementwise/VPU-shaped. The grid blocks the pixel axis; each
block holds a [bd] alpha row, a [bd] beta row and its [bd, 256] output tile
in VMEM (bd=112 -> ~115 kB f32, comfortably VMEM-resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _bbpmf_kernel(a_ref, b_ref, o_ref, *, n: int):
    a = a_ref[...][:, None]  # [bd, 1]
    b = b_ref[...][:, None]
    k = lax.broadcasted_iota(jnp.float32, (1, n + 1), 1)  # [1, n+1]
    nf = jnp.float32(n)
    log_binom = lax.lgamma(nf + 1.0) - lax.lgamma(k + 1.0) - lax.lgamma(nf - k + 1.0)
    num = lax.lgamma(k + a) + lax.lgamma(nf - k + b) - lax.lgamma(nf + a + b)
    den = lax.lgamma(a) + lax.lgamma(b) - lax.lgamma(a + b)
    o_ref[...] = jnp.exp(log_binom + num - den)


def _block(dim: int, want: int) -> int:
    for cand in range(min(want, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(jax.jit, static_argnames=("n", "bd"))
def bbpmf(alpha: jnp.ndarray, beta: jnp.ndarray, n: int = 255, bd: int = 112) -> jnp.ndarray:
    """PMF table: alpha, beta [D] -> [D, n+1] (vmapped over leading batch).

    For batched inputs [B, D] use jax.vmap(bbpmf) at the call site or rely
    on this function's built-in promotion.
    """
    if alpha.ndim == 2:
        return jax.vmap(lambda a, b: bbpmf(a, b, n=n, bd=bd))(alpha, beta)
    assert alpha.ndim == 1 and alpha.shape == beta.shape
    d = alpha.shape[0]
    bd = _block(d, bd)
    grid = (d // bd,)
    return pl.pallas_call(
        functools.partial(_bbpmf_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((d, n + 1), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((bd,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bd, n + 1), lambda i: (i, 0)),
        interpret=True,
    )(alpha.astype(jnp.float32), beta.astype(jnp.float32))
