"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

These are the ground truth that ``python/tests/test_kernel.py`` checks the
Pallas implementations against (hypothesis sweeps shapes and dtypes), and
they are also what the *training* loop uses: interpret-mode Pallas is far
too slow to put inside the training step, and the math is identical. The
AOT-exported inference graphs (the artifacts Rust executes) use the real
Pallas kernels.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def dense_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, activation: str = "none") -> jnp.ndarray:
    """Fused dense layer: activation(x @ w + b).

    x: [B, K], w: [K, N], b: [N] -> [B, N]
    """
    y = jnp.dot(x, w) + b
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "none":
        pass
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return y


def beta_binomial_logpmf_ref(alpha: jnp.ndarray, beta: jnp.ndarray, n: int = 255) -> jnp.ndarray:
    """Log-PMF table of BetaBinomial(n, alpha, beta).

    alpha, beta: [..., D] -> [..., D, n+1]
    """
    k = jnp.arange(n + 1, dtype=alpha.dtype)
    a = alpha[..., None]
    b = beta[..., None]
    log_binom = (
        lax.lgamma(jnp.asarray(n + 1.0, dtype=alpha.dtype))
        - lax.lgamma(k + 1.0)
        - lax.lgamma(n - k + 1.0)
    )
    num = lax.lgamma(k + a) + lax.lgamma(n - k + b) - lax.lgamma(n + a + b)
    den = lax.lgamma(a) + lax.lgamma(b) - lax.lgamma(a + b)
    return log_binom + num - den


def bbpmf_ref(alpha: jnp.ndarray, beta: jnp.ndarray, n: int = 255) -> jnp.ndarray:
    """PMF table of BetaBinomial(n, alpha, beta): [..., D] -> [..., D, n+1]."""
    return jnp.exp(beta_binomial_logpmf_ref(alpha, beta, n))
