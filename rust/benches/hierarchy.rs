//! Hierarchical-latent benchmarks: naive BB-ANS vs Bit-Swap over the
//! L-layer VAE, L ∈ {1, 2, 3} — rate (bits/dim), chained throughput
//! (img/s), and the **initial-bits** cost of starting a fresh chain, which
//! is the quantity the Bit-Swap schedule exists to shrink.
//!
//! Emits `BENCH_hierarchy.json` via `--json` / `BBANS_BENCH_JSON` (same
//! trajectory format as the other targets, with the rates and initial-bit
//! measurements under `"annotations"`). The rate measurement runs through
//! the bits-back ledger, so the annotations also carry the measured
//! bits/dim decomposed into ELBO terms (`data_bpd`, per-layer latent net,
//! amortized initial bits) — the naive-vs-Bit-Swap startup gap is directly
//! readable from the JSON. The run **asserts** the subsystem's acceptance
//! criteria — Bit-Swap initial bits strictly below the naive schedule's
//! for L ≥ 2, and the ledger decomposition telescoping to the measured
//! net rate — so CI's quick-bench job enforces them on every push.

use bbans::ans::Ans;
use bbans::bbans::hierarchy::{HierCodec, Schedule};
use bbans::bbans::BbAnsConfig;
use bbans::bench::{black_box, table_header, Bench};
use bbans::data::synth;
use bbans::model::hierarchy::{HierMeta, HierVae};
use bbans::model::Likelihood;

fn main() {
    table_header("hierarchical latents: naive BB-ANS vs Bit-Swap, L in {1,2,3}");
    let mut bench = Bench::new();
    let fast = std::env::var_os("BBANS_BENCH_FAST").is_some();
    let n_images = if fast { 24 } else { 96 };

    // Binarized synthetic digits (784 pixels, Bernoulli likelihood) — the
    // artifact-free stand-in the test suites use.
    let images = synth::binarize(&synth::digits(n_images, 11), 12).images;

    for layers in 1..=3usize {
        let dims: Vec<usize> = (0..layers).map(|l| 32usize >> l).collect();
        let meta = HierMeta {
            name: format!("hier{layers}"),
            pixels: 784,
            dims,
            hidden: 64,
            likelihood: Likelihood::Bernoulli,
        };
        let backend = HierVae::random(meta, 77);
        let mut initial = [0u64; 2];

        for (i, schedule) in [Schedule::Naive, Schedule::BitSwap].into_iter().enumerate() {
            let codec = HierCodec::new(&backend, BbAnsConfig::default(), schedule).unwrap();

            // Rate and chain-startup cost (measured once, not timed),
            // through the rate ledger — byte-identical to the plain
            // encode, plus the ELBO-term decomposition.
            let (ans, _, ledger) = codec.encode_dataset_ledgered(&images).unwrap();
            let bpd = ans.frac_bit_len() / (images.len() as f64 * 784.0);
            initial[i] = codec.initial_bits(&images[0]).unwrap();
            let summary = ledger.summary(784);
            assert!(
                summary.max_residual < 1e-6,
                "ledger decomposition must telescope to the net rate \
                 (worst per-image residual {} bits)",
                summary.max_residual
            );
            let tag = format!("hier/L{layers}/{}", schedule.name());
            bench.annotate(&format!("{tag}/bits_per_dim"), bpd);
            bench.annotate(&format!("{tag}/initial_bits"), initial[i] as f64);
            bench.annotate(&format!("{tag}/ledger/net_bpd"), summary.net_bpd());
            bench.annotate(&format!("{tag}/ledger/data_bpd"), summary.data_bpd());
            bench.annotate(&format!("{tag}/ledger/initial_bpd"), summary.initial_bpd());
            bench.annotate(
                &format!("{tag}/ledger/initial_bits_total"),
                summary.initial_bits,
            );
            bench.annotate(
                &format!("{tag}/ledger/max_residual_bits"),
                summary.max_residual,
            );
            for l in 0..summary.layers {
                bench.annotate(
                    &format!("{tag}/ledger/latent{l}_net_bpd"),
                    summary.latent_net_bpd(l),
                );
            }
            println!(
                "    L={layers} {:>7}: {bpd:.4} bits/dim ({:.4} data + {:.4} latent), \
                 {} initial bits",
                schedule.name(),
                summary.data_bpd(),
                (0..summary.layers).map(|l| summary.latent_net_bpd(l)).sum::<f64>(),
                initial[i]
            );

            // Chained encode / decode throughput (L=1 is the single-layer
            // baseline the deeper chains compare against).
            bench.run(&format!("{tag} encode"), images.len() as f64, || {
                let (a, _) = codec.encode_dataset(&images).unwrap();
                black_box(a.stream_len());
            });
            let msg = ans.to_message();
            bench.run(&format!("{tag} decode"), images.len() as f64, || {
                let mut a = Ans::from_message(&msg, codec.cfg.clean_seed);
                let out = codec.decode_dataset(&mut a, images.len()).unwrap();
                black_box(out.len());
            });
        }

        // Acceptance criterion: interleaving must strictly shrink the
        // chain-startup cost once there is more than one layer.
        if layers >= 2 {
            assert!(
                initial[1] < initial[0],
                "L={layers}: Bit-Swap initial bits {} must be strictly below naive {}",
                initial[1],
                initial[0]
            );
        }
    }

    bench.finish("hierarchy");
}
