//! Chunk-parallel container benchmarks: BB-ANS encode/decode wall time
//! vs chunk count (paper §4.2 — independent chains parallelize
//! perfectly; this target measures how close std-thread fan-out gets).
//!
//! Runs on the artifact-free NativeVae::random backend, so it always
//! executes. Scale with BBANS_BENCH_IMAGES (default 192).

use bbans::bbans::container::ParallelContainer;
use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::bench::{black_box, table_header, Bench};
use bbans::model::{vae::NativeVae, Likelihood, ModelMeta};
use bbans::util::rng::Rng;

fn main() {
    let n_images: usize = std::env::var("BBANS_BENCH_IMAGES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(192);
    table_header(&format!(
        "chunk-parallel container: {n_images} images x 784 px, toy VAE"
    ));
    let mut bench = Bench::new();

    let meta = ModelMeta {
        name: "toy".into(),
        pixels: 784,
        latent_dim: 40,
        hidden: 100,
        likelihood: Likelihood::Bernoulli,
        test_elbo_bpd: f64::NAN,
    };
    let backend = NativeVae::random(meta, 7);
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();

    let mut rng = Rng::new(1);
    let images: Vec<Vec<u8>> = (0..n_images)
        .map(|_| (0..784).map(|_| (rng.f64() < 0.2) as u8).collect())
        .collect();

    let mut single_lane = f64::NAN;
    for n_chunks in [1usize, 2, 4, 8] {
        let m = bench.run(
            &format!("parallel/encode {n_images} imgs, {n_chunks} chunks"),
            n_images as f64,
            || {
                let pc = ParallelContainer::encode_with(&codec, &images, n_chunks).unwrap();
                black_box(pc.byte_len());
            },
        );
        let rate = m.units_per_sec();
        if n_chunks == 1 {
            single_lane = rate;
        }
        println!(
            "    {n_chunks} chunk(s): {rate:.1} img/s encode ({:.2}x vs 1 chunk)",
            rate / single_lane
        );
    }

    // Decode side.
    let containers: Vec<ParallelContainer> = [1usize, 2, 4, 8]
        .iter()
        .map(|&k| ParallelContainer::encode_with(&codec, &images, k).unwrap())
        .collect();
    let mut single_dec = f64::NAN;
    for pc in &containers {
        let k = pc.chunks.len();
        let m = bench.run(
            &format!("parallel/decode {n_images} imgs, {k} chunks"),
            n_images as f64,
            || {
                black_box(pc.decode_with(&codec).unwrap().len());
            },
        );
        let rate = m.units_per_sec();
        if k == 1 {
            single_dec = rate;
        }
        println!(
            "    {k} chunk(s): {rate:.1} img/s decode ({:.2}x vs 1 chunk)",
            rate / single_dec
        );
    }

    // Worker-pool sweep at a fixed chunk count (ISSUE 3): n_chunks is a
    // container-format knob, workers a machine knob — bytes identical
    // (property-tested), wall time scales with the pool.
    for workers in [1usize, 2, 4, 8] {
        bench.run(
            &format!("parallel/encode {n_images} imgs, 8 chunks, {workers} workers"),
            n_images as f64,
            || {
                let pc =
                    ParallelContainer::encode_with_workers(&codec, &images, 8, workers).unwrap();
                black_box(pc.byte_len());
            },
        );
    }
    let pc8 = ParallelContainer::encode_with(&codec, &images, 8).unwrap();
    for workers in [1usize, 2, 4, 8] {
        bench.run(
            &format!("parallel/decode {n_images} imgs, 8 chunks, {workers} workers"),
            n_images as f64,
            || {
                black_box(pc8.decode_with_workers(&codec, workers).unwrap().len());
            },
        );
    }

    // Rate overhead of chunking: each extra chunk pays its own chain
    // startup (clean bits) and head, nothing else.
    let b1 = containers[0].byte_len();
    println!();
    for pc in &containers {
        println!(
            "    {} chunk(s): {} bytes ({:.4} bits/dim, +{} B vs 1 chunk)",
            pc.chunks.len(),
            pc.byte_len(),
            pc.bits_per_dim(),
            pc.byte_len() as i64 - b1 as i64
        );
    }
}
