//! Table 3 regenerator (bench form): benchmark codecs on the ImageNet64
//! stand-in + binarized digits; BB-ANS column is the paper's PixelVAE
//! prediction (constants), exactly as the paper computes it.

use bbans::baselines::standard_suite;
use bbans::bench::{table_header, Bench};
use bbans::data::{load_split, synth};
use bbans::runtime::{artifacts_available, default_artifact_dir};

fn main() {
    table_header("Table 3: benchmark codecs for the PixelVAE prediction");
    let mut bench = Bench::new();

    println!(
        "BB-ANS w/ PixelVAE predictions (paper constants): bin-MNIST 0.15, \
         ImageNet64 3.66 bits/dim\n"
    );

    let nat = synth::natural(64, 64, 4242);
    for codec in standard_suite(false) {
        let mut bpd = 0.0;
        bench.run(
            &format!("{}/natural-64 compress 64 images", codec.name()),
            64.0,
            || {
                bpd = codec.bits_per_dim(&nat).unwrap();
            },
        );
        println!("    {}: {bpd:.4} bits/dim (paper ImageNet64 ref in example)\n", codec.name());
    }

    let dir = default_artifact_dir();
    if artifacts_available(&dir) {
        let ds = load_split(&dir, "test", true).unwrap().subset(1000);
        for codec in standard_suite(true) {
            let mut bpd = 0.0;
            bench.run(
                &format!("{}/bin-mnist compress 1000 images", codec.name()),
                1000.0,
                || {
                    bpd = codec.bits_per_dim(&ds).unwrap();
                },
            );
            println!("    {}: {bpd:.4} bits/dim\n", codec.name());
        }
    }
}
