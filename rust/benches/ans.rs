//! ANS coder micro-benchmarks: push/pop throughput, the interleaved
//! multi-lane extension (paper §4.2 / Giesen 2014), and the unified
//! `EntropyCoder` trait driving single-lane vs multi-lane coding through
//! the exact same call path.

use bbans::ans::interleaved::{InterleavedAns, Interval};
use bbans::ans::{Ans, EntropyCoder, PreparedInterval, SymbolTable};
use bbans::bench::{black_box, table_header, Bench};
use bbans::codecs::quantize::DecodeLut;
use bbans::util::rng::Rng;

fn dist(prec: u32, k: usize) -> Vec<Interval> {
    let total = 1u64 << prec;
    let raw: Vec<u64> = (0..k).map(|i| (i as u64 + 1) * (i as u64 + 1)).collect();
    let s: u64 = raw.iter().sum();
    let mut freqs: Vec<u32> = raw.iter().map(|&r| ((r * total) / s).max(1) as u32).collect();
    let fix = total as i64 - freqs.iter().map(|&f| f as i64).sum::<i64>();
    let last = freqs.len() - 1;
    freqs[last] = (freqs[last] as i64 + fix) as u32;
    let mut start = 0u32;
    freqs
        .into_iter()
        .map(|f| {
            let iv = Interval { start, freq: f };
            start += f;
            iv
        })
        .collect()
}

fn main() {
    table_header("ANS coder throughput (L3 hot path)");
    let mut bench = Bench::new();
    let prec = 14u32;
    let k = 64usize;
    let d = dist(prec, k);
    let n = 1_000_000usize;
    let mut rng = Rng::new(1);
    let syms: Vec<usize> = (0..n).map(|_| rng.below(k as u64) as usize).collect();

    // The headline hot path (ISSUE 2): prepared symbols — reciprocals
    // built once per distribution symbol, every push division-free. Bit
    // -identical output to the division baseline below.
    let table = SymbolTable::from_intervals(&d, prec);
    bench.run("ans/push 1M skewed symbols", n as f64, || {
        let mut ans = Ans::new(0);
        for &s in &syms {
            ans.push_prepared(table.get(s));
        }
        black_box(ans.stream_len());
    });
    bench.run("ans/push 1M skewed symbols (div baseline)", n as f64, || {
        let mut ans = Ans::new(0);
        for &s in &syms {
            ans.push(d[s].start, d[s].freq, prec);
        }
        black_box(ans.stream_len());
    });

    // Pre-encode once for the pop benchmarks.
    let mut encoded = Ans::new(0);
    for &s in syms.iter().rev() {
        encoded.push(d[s].start, d[s].freq, prec);
    }
    let msg = encoded.to_message();

    // Decode-side hot path: O(1) direct-index LUT replacing the per-pop
    // binary search.
    let cdf: Vec<u32> = d
        .iter()
        .map(|iv| iv.start)
        .chain(std::iter::once(1u32 << prec))
        .collect();
    let lut = DecodeLut::build(&cdf, prec);
    bench.run("ans/pop 1M skewed symbols", n as f64, || {
        let mut ans = Ans::from_message(&msg, 0);
        let mut acc = 0usize;
        for _ in 0..n {
            let s = ans.pop_with(prec, |cf| {
                let i = lut.lookup(&cdf, cf);
                (i, d[i].start, d[i].freq)
            });
            acc ^= s;
        }
        black_box(acc);
    });
    bench.run(
        "ans/pop 1M skewed symbols (binary-search baseline)",
        n as f64,
        || {
            let mut ans = Ans::from_message(&msg, 0);
            let mut acc = 0usize;
            for _ in 0..n {
                let s = ans.pop_with(prec, |cf| {
                    // Binary search over cumulative starts.
                    let i = d.partition_point(|iv| iv.start <= cf) - 1;
                    (i, d[i].start, d[i].freq)
                });
                acc ^= s;
            }
            black_box(acc);
        },
    );

    let ivs: Vec<Interval> = syms.iter().map(|&s| d[s]).collect();
    let prepared: Vec<PreparedInterval> = syms.iter().map(|&s| *table.get(s)).collect();
    bench.run("ans/interleaved-4 encode 1M (prepared)", n as f64, || {
        let mut c = InterleavedAns::<4>::new();
        c.encode_prepared(&prepared);
        black_box(c.stream_len());
    });
    bench.run("ans/interleaved-2 encode 1M", n as f64, || {
        let mut c = InterleavedAns::<2>::new();
        c.encode(&ivs, prec);
        black_box(c.stream_len());
    });
    bench.run("ans/interleaved-4 encode 1M", n as f64, || {
        let mut c = InterleavedAns::<4>::new();
        c.encode(&ivs, prec);
        black_box(c.stream_len());
    });

    let mut c4 = InterleavedAns::<4>::new();
    c4.encode(&ivs, prec);
    bench.run("ans/interleaved-4 decode 1M", n as f64, || {
        let mut c = c4.clone();
        let out = c.decode(n, prec, |cf| {
            let i = d.partition_point(|iv| iv.start <= cf) - 1;
            (i, d[i])
        });
        black_box(out.len());
    });

    // Uniform pushes (the latent-prior path: freq=1).
    bench.run("ans/push 1M uniform-12bit (prior path)", n as f64, || {
        let mut ans = Ans::new(0);
        for &s in &syms {
            ans.push((s as u32 * 61) & 0xfff, 1, 12);
        }
        black_box(ans.stream_len());
    });

    // ---- EntropyCoder trait: single-lane vs multi-lane through the SAME
    // ---- generic call path (what the codecs and the bbans fast path use).
    fn coder_encode_decode<C: EntropyCoder>(
        bench: &mut Bench,
        label: &str,
        make: impl Fn() -> C,
        ivs: &[Interval],
        d: &[Interval],
        prec: u32,
    ) {
        let n = ivs.len();
        bench.run(&format!("coder/{label} encode 1M"), n as f64, || {
            let mut c = make();
            c.encode_all(ivs, prec);
            black_box(c.bit_len());
        });
        bench.run(&format!("coder/{label} decode 1M"), n as f64, || {
            let mut c = make();
            c.encode_all(ivs, prec);
            let out = c.decode_all(n, prec, |cf| {
                let i = d.partition_point(|iv| iv.start <= cf) - 1;
                (i, d[i])
            });
            black_box(out.len());
        });
    }

    println!("\n-- EntropyCoder trait: multi-lane vs single-lane throughput --");
    coder_encode_decode(&mut bench, "stack (1 lane)", || Ans::new(0), &ivs, &d, prec);
    coder_encode_decode(
        &mut bench,
        "interleaved-2",
        InterleavedAns::<2>::new,
        &ivs,
        &d,
        prec,
    );
    coder_encode_decode(
        &mut bench,
        "interleaved-4",
        InterleavedAns::<4>::new,
        &ivs,
        &d,
        prec,
    );
    coder_encode_decode(
        &mut bench,
        "interleaved-8",
        InterleavedAns::<8>::new,
        &ivs,
        &d,
        prec,
    );
    println!("(same trait calls, same distribution: lane count is the only variable)");

    // Record the trajectory (BENCH_ans.json with --json / BBANS_BENCH_JSON).
    bench.finish("ans");
}
