//! Model-layer benchmarks (ISSUE 3): packed-GEMM GFLOP/s vs the scalar
//! reference kernel, and VAE forward throughput in images/sec at
//! B ∈ {1, 16, 64, 256}. The acceptance target is batched packed forward
//! ≥ 3× the B=1 scalar baseline at B=64.
//!
//! Emits `BENCH_model.json` via `--json` / `BBANS_BENCH_JSON` (the same
//! trajectory format as the `ans` target); CI's quick-bench job records
//! it on every push.

use bbans::bench::{black_box, table_header, Bench};
use bbans::model::tensor::{dense, dense_packed, Epilogue, Matrix};
use bbans::model::{vae::NativeVae, Backend, Likelihood, ModelMeta};
use bbans::simd;
use bbans::util::rng::Rng;

fn rand_matrix(rng: &mut Rng, rows: usize, cols: usize, sparsity: f64) -> Matrix {
    Matrix::new(
        rows,
        cols,
        (0..rows * cols)
            .map(|_| {
                if rng.f64() < sparsity {
                    0.0
                } else {
                    (rng.normal() * 0.5) as f32
                }
            })
            .collect(),
    )
}

fn main() {
    table_header("model layer: packed GEMM + batched VAE forward");
    let mut bench = Bench::new();
    let mut rng = Rng::new(3);

    // ---- SIMD dispatch (ISSUE 5): record which kernel this host runs
    // ---- and measure the packed GEMM under every dispatchable variant.
    let dispatched = simd::active();
    println!("dispatched kernel: {}\n", dispatched.name());
    // Annotations are numeric; the variant is one-hot keyed by name.
    bench.annotate(&format!("model/kernel_is_{}", dispatched.name()), 1.0);

    // ---- raw GEMM at the VAE's layer shapes (dense latent inputs; the
    // ---- generative net dominates runtime, exactly as the paper notes).
    let mut kernel_gflops: Vec<(bbans::simd::Kernel, f64)> = Vec::new();
    for &(m, k, n) in &[(64usize, 40usize, 100usize), (64, 100, 1568), (256, 784, 100)] {
        let x = rand_matrix(&mut rng, m, k, 0.0);
        let w = rand_matrix(&mut rng, k, n, 0.0);
        let wp = w.packed();
        let b: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.1) as f32).collect();
        // units = FLOPs, so units/s in the JSON is FLOP/s.
        let flops = 2.0 * (m * k * n) as f64;
        // Per-variant packed GEMM GFLOP/s (forced dispatch; restored
        // below). The biggest shape feeds the per-variant annotations.
        for kernel in simd::available() {
            simd::force(Some(kernel));
            let meas = bench.run(
                &format!("model/gemm {m}x{k}x{n} packed[{}]", kernel.name()),
                flops,
                || {
                    black_box(dense_packed(&x, &wp, &b, Epilogue::Linear).data[0]);
                },
            );
            let gflops = meas.units_per_sec() / 1e9;
            if (m, k, n) == (256, 784, 100) {
                bench.annotate(&format!("model/gemm_gflops_{}", kernel.name()), gflops);
                kernel_gflops.push((kernel, gflops));
            }
        }
        simd::force(None);
        bench.run(&format!("model/gemm {m}x{k}x{n} scalar"), flops, || {
            black_box(dense(&x, &w, &b).data[0]);
        });
    }
    // SIMD-vs-scalar-packed speedup on the big shape (the ISSUE 5
    // acceptance number: AVX2 >= 2x scalar-packed on the CI host).
    if let Some(&(_, scalar)) = kernel_gflops
        .iter()
        .find(|(k, _)| *k == bbans::simd::Kernel::Scalar)
    {
        for &(kernel, gflops) in &kernel_gflops {
            if kernel != bbans::simd::Kernel::Scalar && scalar > 0.0 {
                let ratio = gflops / scalar;
                println!(
                    "    {} vs scalar-packed GEMM: {ratio:.2}x \
                     ({gflops:.2} vs {scalar:.2} GFLOP/s)",
                    kernel.name()
                );
                bench.annotate(
                    &format!("model/gemm_{}_vs_scalar_packed", kernel.name()),
                    ratio,
                );
            }
        }
    }

    // ---- full VAE forward (recognition + generative net) per image.
    let meta = ModelMeta {
        name: "bench".into(),
        pixels: 784,
        latent_dim: 40,
        hidden: 100,
        likelihood: Likelihood::Bernoulli,
        test_elbo_bpd: f64::NAN,
    };
    let packed = NativeVae::random(meta.clone(), 7);
    let scalar = NativeVae::random(meta, 7).with_reference_gemm(true);

    let max_b = 512usize;
    // MNIST-like sparse images (scaled) and dense latents.
    let xs = rand_matrix(&mut rng, max_b, 784, 0.8);
    let ys = rand_matrix(&mut rng, max_b, 40, 0.0);
    let sub = |m: &Matrix, b: usize, cols: usize| -> Matrix {
        Matrix::new(b, cols, m.data[..b * cols].to_vec())
    };

    println!();
    let scalar_b1 = {
        let (xb, yb) = (sub(&xs, 1, 784), sub(&ys, 1, 40));
        bench
            .run("model/forward B=1 scalar", 1.0, || {
                let p = scalar.encode_batch(&xb).unwrap();
                let l = scalar.decode_batch(&yb).unwrap();
                black_box((p.len(), l.len()));
            })
            .units_per_sec()
    };
    // Autoscaling sweep (ROADMAP): walk the batch axis to find the knee
    // where forward throughput saturates, then suggest NN_CHUNK from it.
    let mut sweep: Vec<(usize, f64)> = Vec::new();
    for &b in &[1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512] {
        let (xb, yb) = (sub(&xs, b, 784), sub(&ys, b, 40));
        let m = bench.run(&format!("model/forward B={b} packed"), b as f64, || {
            let p = packed.encode_batch(&xb).unwrap();
            let l = packed.decode_batch(&yb).unwrap();
            black_box((p.len(), l.len()));
        });
        println!(
            "    B={b}: {:.1} img/s packed ({:.2}x vs B=1 scalar)",
            m.units_per_sec(),
            m.units_per_sec() / scalar_b1
        );
        sweep.push((b, m.units_per_sec()));
    }
    let best = sweep.iter().map(|&(_, r)| r).fold(0.0f64, f64::max);
    // Knee = smallest batch within 10% of peak throughput; larger batches
    // only add latency and memory. NN_CHUNK should sit at or past it so
    // the posterior-precompute blocks dispatch at saturated throughput.
    let knee = sweep
        .iter()
        .find(|&&(_, r)| r >= 0.9 * best)
        .map(|&(b, _)| b)
        .unwrap_or(bbans::bbans::NN_CHUNK);
    let suggest = knee.max(16);
    println!(
        "\n    throughput knee at B={knee}; suggested NN_CHUNK = {suggest} (current {})",
        bbans::bbans::NN_CHUNK
    );
    bench.annotate("model/throughput_knee_batch", knee as f64);
    bench.annotate("model/suggested_nn_chunk", suggest as f64);

    bench.finish("model");
}
