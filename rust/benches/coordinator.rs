//! Coordinator benchmarks: serving throughput and the cross-stream
//! batching win (mean NN batch size) under concurrent load.

use std::collections::HashMap;
use std::time::Duration;

use bbans::bench::table_header;
use bbans::coordinator::{ModelService, ServiceParams};
use bbans::model::{vae::NativeVae, Backend, Likelihood, ModelMeta};
use bbans::util::rng::Rng;
use bbans::util::timer::Timer;

fn toy_service(window_ms: u64) -> ModelService {
    ModelService::spawn_with(
        ServiceParams {
            max_jobs: 32,
            max_batch_delay: Duration::from_millis(window_ms),
            ..Default::default()
        },
        || {
            let meta = ModelMeta {
                name: "toy".into(),
                pixels: 784,
                latent_dim: 40,
                hidden: 100,
                likelihood: Likelihood::Bernoulli,
                test_elbo_bpd: f64::NAN,
            };
            let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
            map.insert("toy".into(), Box::new(NativeVae::random(meta, 7)));
            Ok(map)
        },
    )
}

fn images(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..784).map(|_| (rng.f64() < 0.2) as u8).collect())
        .collect()
}

fn run_load(clients: usize, per_req: usize, window_ms: u64) -> (f64, f64, f64) {
    let svc = toy_service(window_ms);
    let t = Timer::start();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let h = svc.handle();
            scope.spawn(move || {
                let imgs = images(per_req, c as u64);
                let container = h.compress("toy", imgs).unwrap();
                let _ = h.decompress(container).unwrap();
            });
        }
    });
    let wall = t.elapsed_secs();
    let throughput = (2 * clients * per_req) as f64 / wall;
    let mbs = svc.metrics.mean_batch_size();
    svc.shutdown();
    (wall, throughput, mbs)
}

fn main() {
    table_header("coordinator: concurrent serving throughput + batching");
    println!(
        "{:>8} {:>8} {:>10} {:>12} {:>16} {:>12}",
        "clients", "imgs/req", "window ms", "wall s", "imgs/s (e+d)", "batch size"
    );
    for (clients, window_ms) in [(1usize, 0u64), (4, 2), (8, 2), (16, 4), (16, 0)] {
        let (wall, tput, mbs) = run_load(clients, 24, window_ms);
        println!(
            "{clients:>8} {:>8} {window_ms:>10} {wall:>12.2} {tput:>16.1} {mbs:>12.2}",
            24
        );
    }
    println!("\n(batch size > 1 under concurrency = the §4.2 parallelization win; the");
    println!(" window=0 row shows throughput without intentional lingering)");
}
