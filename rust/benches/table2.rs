//! Table 2 regenerator (bench form): BB-ANS + all baselines on both test
//! sets, reporting bits/dim and throughput. `examples/mnist_compress.rs`
//! prints the paper-formatted table; this target times the pipeline.
//!
//! Scale with BBANS_BENCH_N (default 2000 images).

use bbans::baselines::standard_suite;
use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::bench::{black_box, table_header, Bench};
use bbans::data::load_split;
use bbans::model::vae::load_native;
use bbans::model::Backend;
use bbans::runtime::{artifacts_available, default_artifact_dir};

fn main() {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("skipping table2 bench: run `make artifacts`");
        return;
    }
    let n: usize = std::env::var("BBANS_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000);
    table_header(&format!("Table 2 pipeline (n = {n} images per dataset)"));
    let mut bench = Bench::new();

    for (model, binarized, pixel_prec) in [("bin", true, 16u32), ("full", false, 18u32)] {
        let ds = load_split(&dir, "test", binarized).unwrap().subset(n);
        let backend = load_native(&dir, model).unwrap();
        let cfg = BbAnsConfig {
            pixel_prec,
            ..Default::default()
        };
        let codec = VaeCodec::new(&backend, cfg).unwrap();

        let mut rate = 0.0;
        bench.run(&format!("bbans/{model} encode {n} images"), n as f64, || {
            let (ans, _) = codec.encode_dataset(&ds.images).unwrap();
            rate = ans.frac_bit_len() / (n as f64 * 784.0);
            black_box(ans.stream_len());
        });
        println!(
            "    bbans/{model}: {rate:.4} bits/dim (test ELBO {:.4})\n",
            backend.meta().test_elbo_bpd
        );

        let (ans0, _) = codec.encode_dataset(&ds.images).unwrap();
        let msg = ans0.to_message();
        bench.run(&format!("bbans/{model} decode {n} images"), n as f64, || {
            let mut ans = bbans::ans::Ans::from_message(&msg, cfg.clean_seed);
            black_box(codec.decode_dataset(&mut ans, n).unwrap().len());
        });

        for bcodec in standard_suite(binarized) {
            let name = format!("{}/{model} compress {n} images", bcodec.name());
            let mut bpd = 0.0;
            bench.run(&name, n as f64, || {
                bpd = bcodec.bits_per_dim(&ds).unwrap();
            });
            println!("    {}: {bpd:.4} bits/dim\n", bcodec.name());
        }
    }
}
