//! Distribution-codec benchmarks: the per-pixel and per-latent-dim costs
//! that dominate the BB-ANS hot path.

use bbans::ans::{Ans, EntropyCoder, Interval};
use bbans::bench::{black_box, table_header, Bench};
use bbans::codecs::beta_binomial::BetaBinomial;
use bbans::codecs::categorical::Categorical;
use bbans::codecs::gaussian::{DiscretizedGaussian, MaxEntropyBuckets};
use bbans::codecs::quantize::QuantizedCdf;
use bbans::codecs::SymbolCodec;
use bbans::util::rng::Rng;

fn main() {
    table_header("distribution codecs (per-symbol hot path)");
    let mut bench = Bench::new();
    let mut rng = Rng::new(2);

    // Bernoulli pixels (binarized model): build + push.
    let probs: Vec<f64> = (0..784).map(|_| rng.f64()).collect();
    let bits: Vec<usize> = (0..784).map(|i| (probs[i] > 0.5) as usize).collect();
    bench.run("bernoulli/build+push 784 pixels", 784.0, || {
        let mut ans = Ans::new(0);
        for p in 0..784 {
            let c = Categorical::bernoulli(probs[p], 16);
            c.push(&mut ans, bits[p]);
        }
        black_box(ans.stream_len());
    });

    // Beta-binomial from parameters (native backend path).
    let alphas: Vec<f64> = (0..784).map(|_| 0.3 + rng.f64() * 8.0).collect();
    let betas: Vec<f64> = (0..784).map(|_| 0.3 + rng.f64() * 8.0).collect();
    let pix: Vec<u32> = (0..784).map(|_| rng.below(256) as u32).collect();
    bench.run("beta-binomial/from_params 784 pixels", 784.0, || {
        let mut ans = Ans::new(0);
        for p in 0..784 {
            let c = BetaBinomial::from_params(255, alphas[p], betas[p], 18);
            c.push(&mut ans, pix[p]);
        }
        black_box(ans.stream_len());
    });

    // Beta-binomial from a PMF table row (PJRT backend path).
    let table: Vec<f32> = (0..784 * 256)
        .map(|i| {
            bbans::util::math::beta_binomial_logpmf(
                (i % 256) as u32,
                255,
                alphas[i / 256],
                betas[i / 256],
            )
            .exp() as f32
        })
        .collect();
    bench.run("beta-binomial/from_pmf_row 784 pixels", 784.0, || {
        let mut ans = Ans::new(0);
        for p in 0..784 {
            let c = BetaBinomial::from_pmf_row(&table[p * 256..(p + 1) * 256], 18);
            c.push(&mut ans, pix[p]);
        }
        black_box(ans.stream_len());
    });
    // Same path with the reusable f64 row buffer (the CodecScratch form
    // the BB-ANS image loops use): no per-pixel allocation.
    bench.run("beta-binomial/from_pmf_row 784 pixels (scratch)", 784.0, || {
        let mut ans = Ans::new(0);
        let mut pmf = Vec::new();
        for p in 0..784 {
            let c =
                BetaBinomial::from_pmf_row_scratch(&table[p * 256..(p + 1) * 256], 18, &mut pmf);
            c.push(&mut ans, pix[p]);
        }
        black_box(ans.stream_len());
    });

    // Bulk categorical coding through the prepared table + decode LUT
    // (division-free pushes, O(1) symbol lookup).
    let bulk_pmf: Vec<f64> = (0..256).map(|_| rng.f64() + 1e-6).collect();
    let bulk_syms: Vec<usize> = (0..16_384).map(|_| rng.below(256) as usize).collect();
    let plain_cat = Categorical::from_pmf(&bulk_pmf, 16);
    let fast_cat = Categorical::from_pmf(&bulk_pmf, 16).prepare();
    let mut scratch = Vec::new();
    bench.run("categorical/encode_all 16k syms (prepared)", 16_384.0, || {
        let mut ans = Ans::new(0);
        fast_cat.encode_all_scratch(&mut ans, &bulk_syms, &mut scratch);
        black_box(ans.stream_len());
    });
    let mut encoded = Ans::new(0);
    fast_cat.encode_all_scratch(&mut encoded, &bulk_syms, &mut scratch);
    bench.run("categorical/decode_all 16k syms (LUT)", 16_384.0, || {
        let mut ans = encoded.clone();
        black_box(fast_cat.decode_all(&mut ans, bulk_syms.len()).len());
    });
    // Raw binary-search baseline (decode_all itself now builds a coarse
    // LUT past its break-even, so probe the search path directly).
    bench.run(
        "categorical/decode_all 16k syms (binary-search baseline)",
        16_384.0,
        || {
            let mut ans = encoded.clone();
            let q = plain_cat.quantized();
            let out = EntropyCoder::decode_all(&mut ans, bulk_syms.len(), 16, |cf| {
                let s = q.lookup_binary(cf);
                (
                    s,
                    Interval {
                        start: q.start(s),
                        freq: q.freq(s),
                    },
                )
            });
            black_box(out.len());
        },
    );

    // Discretized Gaussian posterior: pop (sampling via bisection) and push.
    let buckets = MaxEntropyBuckets::new(12);
    let mus: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
    let sigmas: Vec<f64> = (0..40).map(|_| 0.05 + rng.f64()).collect();
    bench.run("gaussian/pop 40 latent dims (bisection)", 40.0, || {
        let mut ans = Ans::new(7);
        for d in 0..40 {
            let g = DiscretizedGaussian::new(buckets.clone(), mus[d], sigmas[d], 24);
            black_box(g.pop(&mut ans));
        }
    });
    let idxs: Vec<u32> = (0..40).map(|_| rng.below(1 << 12) as u32).collect();
    bench.run("gaussian/push 40 latent dims", 40.0, || {
        let mut ans = Ans::new(0);
        for d in 0..40 {
            let g = DiscretizedGaussian::new(buckets.clone(), mus[d], sigmas[d], 24);
            g.push(&mut ans, idxs[d]);
        }
        black_box(ans.stream_len());
    });

    // Raw quantization cost.
    let pmf: Vec<f64> = (0..256).map(|_| rng.f64() + 1e-6).collect();
    bench.run("quantize/256-symbol pmf -> 2^18", 256.0, || {
        black_box(QuantizedCdf::from_pmf(&pmf, 18));
    });

    bench.finish("codecs");
}
