//! Figure 3 regenerator (bench form): time the chained encode over three
//! shuffled copies of the test set and report the final moving-average
//! rate. The plotted curve comes from `examples/fig3_moving_average.rs`.

use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::bench::{black_box, table_header, Bench};
use bbans::data::load_split;
use bbans::model::vae::load_native;
use bbans::model::Backend;
use bbans::runtime::{artifacts_available, default_artifact_dir};
use bbans::util::rng::Rng;

fn main() {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("skipping fig3 bench: run `make artifacts`");
        return;
    }
    let n_per_copy: usize = std::env::var("BBANS_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3000);
    table_header(&format!(
        "Figure 3 pipeline: 3 x {n_per_copy} shuffled images, chained"
    ));
    let mut bench = Bench::new();

    let ds = load_split(&dir, "test", true).unwrap();
    let mut rng = Rng::new(303);
    let mut images = Vec::with_capacity(3 * n_per_copy);
    for _ in 0..3 {
        let mut idx: Vec<usize> = (0..ds.len().min(n_per_copy)).collect();
        rng.shuffle(&mut idx);
        images.extend(idx.into_iter().map(|i| ds.images[i].clone()));
    }

    let backend = load_native(&dir, "bin").unwrap();
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let n = images.len();
    let mut final_rate = 0.0;
    bench.run(&format!("fig3/chained encode {n} images"), n as f64, || {
        let (ans, stats) = codec.encode_dataset(&images).unwrap();
        let window = 2000.min(stats.len());
        final_rate = stats[stats.len() - window..]
            .iter()
            .map(|s| s.net_bits / 784.0)
            .sum::<f64>()
            / window as f64;
        black_box(ans.stream_len());
    });
    println!(
        "    final 2000-image moving average: {final_rate:.4} bits/dim (ELBO {:.4})",
        backend.meta().test_elbo_bpd
    );
}
