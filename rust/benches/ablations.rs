//! Ablation benches for the paper's §2.5/§3.2 claims and DESIGN.md's
//! design choices:
//!
//! * latent precision sweep (gains saturate by ~16 bits/dim);
//! * pixel-codec precision sweep (quantization overhead);
//! * clean-bits chain-startup cost;
//! * HMM time-series extension: startup bits scale with T (paper §4.1)
//!   and chained rate approaches -log p(x).

use bbans::bbans::timeseries::{demo_hmm, sample_sequence, HmmCodec};
use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::bench::table_header;
use bbans::data::load_split;
use bbans::model::vae::load_native;
use bbans::model::Backend;
use bbans::runtime::{artifacts_available, default_artifact_dir};
use bbans::util::rng::Rng;

fn main() {
    table_header("ablations (paper §2.5, §3.2, §4.1)");
    let dir = default_artifact_dir();

    if artifacts_available(&dir) {
        let ds = load_split(&dir, "test", true).unwrap().subset(400);
        let backend = load_native(&dir, "bin").unwrap();
        let elbo = backend.meta().test_elbo_bpd;

        println!("\n-- latent discretization sweep (bin model, 400 images; ELBO {elbo:.4}) --");
        println!("{:>12} {:>14} {:>16}", "latent bits", "rate bits/dim", "gap vs ELBO %");
        for latent_bits in [6u32, 8, 10, 12, 14, 16] {
            let cfg = BbAnsConfig {
                latent_bits,
                posterior_prec: (latent_bits + 12).min(32),
                ..Default::default()
            };
            let codec = VaeCodec::new(&backend, cfg).unwrap();
            let (ans, _) = codec.encode_dataset(&ds.images).unwrap();
            let bpd = ans.frac_bit_len() / (ds.len() as f64 * 784.0);
            println!(
                "{latent_bits:>12} {bpd:>14.4} {:>15.2}%",
                (bpd - elbo) / elbo * 100.0
            );
        }

        println!("\n-- pixel-codec precision sweep --");
        println!("{:>12} {:>14}", "pixel prec", "rate bits/dim");
        for pixel_prec in [10u32, 12, 14, 16, 20] {
            let cfg = BbAnsConfig {
                pixel_prec,
                ..Default::default()
            };
            let codec = VaeCodec::new(&backend, cfg).unwrap();
            let (ans, _) = codec.encode_dataset(&ds.images).unwrap();
            let bpd = ans.frac_bit_len() / (ds.len() as f64 * 784.0);
            println!("{pixel_prec:>12} {bpd:>14.4}");
        }

        println!("\n-- clean bits to start the chain (paper: ~400) --");
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let (ans, _) = codec.encode_dataset(&ds.images[..50].to_vec()).unwrap();
        println!("clean bits consumed: {}", ans.clean_bits_used());
    } else {
        eprintln!("(artifact-dependent ablations skipped: run `make artifacts`)");
    }

    // §2.3: chaining with arithmetic coding pays a flush per image; ANS
    // chaining is free. Code the same per-image symbol stream both ways.
    println!("\n-- AC flush overhead vs ANS chaining (paper §2.3) --");
    {
        use bbans::ans::arith::ArithEncoder;
        use bbans::ans::Ans;
        use bbans::codecs::quantize::QuantizedCdf;
        let prec = 14u32;
        let mut rng = Rng::new(21);
        let pmf: Vec<f64> = (0..64).map(|_| rng.f64() + 1e-6).collect();
        let q = QuantizedCdf::from_pmf(&pmf, prec);
        let images = 500usize;
        let symbols_per_image = 784usize;
        let syms: Vec<usize> = (0..images * symbols_per_image)
            .map(|_| q.lookup(rng.below(1 << prec) as u32))
            .collect();

        let mut ans = Ans::new(0);
        for &s in syms.iter().rev() {
            ans.push(q.start(s), q.freq(s), prec);
        }
        let ans_bits = ans.frac_bit_len();

        let mut ac_bits = 0usize;
        for chunk in syms.chunks(symbols_per_image) {
            let mut enc = ArithEncoder::new();
            for &s in chunk {
                enc.encode(q.start(s), q.freq(s), prec);
            }
            ac_bits += enc.finish().len() * 8; // flush per image (Frey-style)
        }
        println!(
            "{images} images x {symbols_per_image} symbols: ANS one stream {ans_bits:.0} bits; \
             AC with per-image flush {ac_bits} bits"
        );
        println!(
            "AC chaining overhead: {:+.1} bits/image ({:+.5} bits/dim) — ANS chaining costs 0",
            (ac_bits as f64 - ans_bits) / images as f64,
            (ac_bits as f64 - ans_bits) / (images * symbols_per_image) as f64
        );
    }

    println!("\n-- HMM time-series naive BB-ANS (paper §4.1) --");
    let hmm = demo_hmm();
    let codec = HmmCodec::new(&hmm, 16);
    println!(
        "{:>8} {:>16} {:>18} {:>14}",
        "T", "startup bits", "chained bits/sym", "-log p(x)/sym"
    );
    for t_len in [10usize, 30, 100, 300, 1000] {
        let mut rng = Rng::new(11);
        let seqs: Vec<Vec<usize>> = (0..30)
            .map(|_| sample_sequence(&hmm, t_len, &mut rng))
            .collect();
        let mut ans = bbans::ans::Ans::new(5);
        let mut net = 0.0;
        let mut ideal = 0.0;
        for s in &seqs {
            net += codec.encode_sequence(&mut ans, s).unwrap();
            ideal += -hmm.smoothed_marginals(s).1;
        }
        // Startup = clean bits drawn by the first sequence alone.
        let mut a2 = bbans::ans::Ans::new(5);
        codec.encode_sequence(&mut a2, &seqs[0]).unwrap();
        println!(
            "{t_len:>8} {:>16} {:>18.4} {:>14.4}",
            a2.clean_bits_used(),
            net / (30.0 * t_len as f64),
            ideal / (30.0 * t_len as f64)
        );
    }
    println!("(startup bits grow ~linearly with T — the paper's §4.1 caveat, measured)");
}
