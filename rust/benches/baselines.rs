//! Baseline codec benchmarks: throughput + rate of our from-scratch
//! implementations on image data. With `--features external-codecs` the
//! reference flate2/bzip2 crates run alongside for cross-validation.

use bbans::baselines::{bz, deflate, gzip, png, webp};
use bbans::bench::{black_box, table_header, Bench};
use bbans::data::synth;

fn main() {
    table_header("baseline codecs: throughput and rate (28x28 digits + 64x64 natural)");
    let mut bench = Bench::new();

    let digits = synth::digits(512, 99);
    let flat = digits.flat();
    let nat = synth::natural(16, 64, 98);

    println!(
        "workload: {} bytes of digit images, {} bytes of natural images\n",
        flat.len(),
        nat.raw_bytes()
    );

    // Our DEFLATE (vs flate2 when the external-codecs feature is on).
    bench.run("deflate/ours compress digits", flat.len() as f64, || {
        black_box(deflate::compress(&flat, 128));
    });
    let compressed = deflate::compress(&flat, 128);
    #[cfg(feature = "external-codecs")]
    {
        use bbans::baselines::external;
        bench.run("deflate/flate2 compress digits", flat.len() as f64, || {
            black_box(external::flate2_gzip(&flat));
        });
        println!(
            "    rate: ours {} B vs flate2 {} B\n",
            compressed.len(),
            external::flate2_gzip(&flat).len()
        );
    }
    #[cfg(not(feature = "external-codecs"))]
    println!(
        "    rate: ours {} B (flate2 comparison needs --features external-codecs)\n",
        compressed.len()
    );
    bench.run("deflate/ours decompress digits", flat.len() as f64, || {
        black_box(deflate::decompress(&compressed).unwrap());
    });

    // Our bz-style (vs bzip2 when the feature is on).
    bench.run("bz/ours compress digits", flat.len() as f64, || {
        black_box(bz::compress(&flat, 256 * 1024));
    });
    let bzc = bz::compress(&flat, 256 * 1024);
    #[cfg(feature = "external-codecs")]
    {
        use bbans::baselines::external;
        bench.run("bz/bzip2 compress digits", flat.len() as f64, || {
            black_box(external::bzip2_compress(&flat));
        });
        println!(
            "    rate: ours {} B vs bzip2 {} B\n",
            bzc.len(),
            external::bzip2_compress(&flat).len()
        );
    }
    #[cfg(not(feature = "external-codecs"))]
    println!(
        "    rate: ours {} B (bzip2 comparison needs --features external-codecs)\n",
        bzc.len()
    );
    bench.run("bz/ours decompress digits", flat.len() as f64, || {
        black_box(bz::decompress(&bzc).unwrap());
    });

    // PNG per image.
    bench.run("png/encode 512 digit images", 512.0, || {
        for img in &digits.images {
            black_box(png::encode(img, 28, 28, 8).unwrap());
        }
    });
    let pngs: Vec<Vec<u8>> = digits
        .images
        .iter()
        .map(|i| png::encode(i, 28, 28, 8).unwrap())
        .collect();
    bench.run("png/decode 512 digit images", 512.0, || {
        for p in &pngs {
            black_box(png::decode(p).unwrap());
        }
    });

    // WebP-style on natural images.
    bench.run("webp/encode 16 natural 64x64", 16.0, || {
        for img in &nat.images {
            black_box(webp::encode(img, 64, 64).unwrap());
        }
    });

    // gzip container overheads.
    bench.run("gzip/ours container digits", flat.len() as f64, || {
        black_box(gzip::gzip_compress(&flat, 128));
    });
}
