//! Fault-injection campaigns over every container format.
//!
//! The contract under test (ISSUE 7): **no mutated input may ever panic a
//! parser** — corruption surfaces as `Err`, never as an abort — and BBC4
//! must additionally (a) detect every single-bit flip in strict mode and
//! (b) recover every uncorrupted page bit-exactly under salvage, with a
//! `RecoveryReport` that names exactly the lost images.
//!
//! Campaigns are seeded ([`bbans::util::fault`]), so any failure prints a
//! fault description that replays exactly.

use bbans::bbans::bbc4::{Bbc4Container, Bbc4StreamReader};
use bbans::bbans::container::{Container, HierContainer, ParallelContainer};
use bbans::bbans::hierarchy::{HierCodec, Schedule};
use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::format::{find_magic, read_frame, FrameRead};
use bbans::model::hierarchy::{HierMeta, HierVae};
use bbans::model::{vae::NativeVae, Backend, Likelihood, ModelMeta};
use bbans::util::fault::{self, Fault};
use bbans::util::rng::Rng;

const PIXELS: usize = 16;

fn vae_backend() -> NativeVae {
    NativeVae::random(
        ModelMeta {
            name: "fault-vae".into(),
            pixels: PIXELS,
            latent_dim: 3,
            hidden: 8,
            likelihood: Likelihood::BetaBinomial,
            test_elbo_bpd: f64::NAN,
        },
        0xFA17,
    )
}

fn hier_backend() -> HierVae {
    HierVae::random(
        HierMeta {
            name: "fault-hier".into(),
            pixels: PIXELS,
            dims: vec![4, 2],
            hidden: 8,
            likelihood: Likelihood::BetaBinomial,
        },
        0xFA17,
    )
}

fn images(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..PIXELS).map(|_| rng.below(256) as u8).collect())
        .collect()
}

/// One clean serialized container per format (BBC4 in both kinds).
fn corpora() -> Vec<(&'static str, Vec<u8>)> {
    let backend = vae_backend();
    let cfg = BbAnsConfig::default();
    let codec = VaeCodec::new(&backend, cfg).unwrap();
    let imgs = images(10, 0x11);

    let (ans, _) = codec.encode_dataset(&imgs).unwrap();
    let bbc1 = Container {
        model: "fault-vae".into(),
        backend_id: backend.backend_id(),
        cfg,
        num_images: imgs.len() as u32,
        pixels: PIXELS as u32,
        message: ans.into_message(),
    }
    .to_bytes();
    let bbc2 = ParallelContainer::encode_with(&codec, &imgs, 3).unwrap().to_bytes();

    let hier = hier_backend();
    let hcodec = HierCodec::new(&hier, cfg, Schedule::BitSwap).unwrap();
    let bbc3 = HierContainer::encode_with(&hcodec, &imgs, 3).unwrap().to_bytes();

    let bbc4 = Bbc4Container::encode_vae(&codec, &imgs, 3).unwrap().to_bytes();
    let bbc4h = Bbc4Container::encode_hier(&hcodec, &imgs, 3).unwrap().to_bytes();

    vec![
        ("bbc1", bbc1),
        ("bbc2", bbc2),
        ("bbc3", bbc3),
        ("bbc4", bbc4),
        ("bbc4", bbc4h),
    ]
}

/// Run every parser that accepts this format; a panic fails the test.
fn parse_any(name: &str, bytes: &[u8]) {
    match name {
        "bbc1" => {
            let _ = Container::from_bytes(bytes);
        }
        "bbc2" => {
            let _ = ParallelContainer::from_bytes(bytes);
        }
        "bbc3" => {
            let _ = HierContainer::from_bytes(bytes);
        }
        "bbc4" => {
            let _ = Bbc4Container::from_bytes(bytes);
            let _ = Bbc4Container::salvage(bytes);
        }
        other => panic!("unknown format {other}"),
    }
}

/// Byte ranges `[start, end)` of the page frames in a clean BBC4 file.
fn page_ranges(bytes: &[u8], n_pages: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = find_magic(bytes, from) {
        if let FrameRead::Ok { next, .. } = read_frame(bytes, pos) {
            out.push((pos, next));
            from = next;
        } else {
            from = pos + 1;
        }
    }
    assert_eq!(out.len(), n_pages, "frame scan must find every page");
    out
}

#[test]
fn mixed_fault_campaign_never_panics() {
    for (fi, (name, bytes)) in corpora().into_iter().enumerate() {
        for f in fault::campaign(0xFA_017 + fi as u64, bytes.len(), 64) {
            parse_any(name, &f.apply(&bytes));
        }
    }
}

#[test]
fn truncation_at_every_byte_never_panics() {
    // Strictly stronger than "every frame boundary ±1": every prefix of
    // every format must parse to a clean error, never an abort.
    for (name, bytes) in corpora() {
        for cut in 0..=bytes.len() {
            parse_any(name, &bytes[..cut]);
        }
    }
}

#[test]
fn every_single_bit_flip_is_survived_and_bbc4_detects_it() {
    for (name, bytes) in corpora() {
        for f in fault::bitflip_sweep(bytes.len(), 1) {
            let mutated = f.apply(&bytes);
            parse_any(name, &mutated);
            if name == "bbc4" {
                // Every byte of a BBC4 file is covered by some checksum
                // (or locates one), so strict mode must reject any flip.
                assert!(
                    Bbc4Container::from_bytes(&mutated).is_err(),
                    "{}: strict BBC4 parse accepted corrupted bytes",
                    f.describe()
                );
            }
        }
    }
}

#[test]
fn bbc4_salvage_recovers_intact_pages_bit_exactly() {
    let backend = vae_backend();
    let cfg = BbAnsConfig::default();
    let codec = VaeCodec::new(&backend, cfg).unwrap();
    let imgs = images(11, 0x22);
    let bytes = Bbc4Container::encode_vae(&codec, &imgs, 4).unwrap().to_bytes();
    for f in fault::campaign(0xD15C, bytes.len(), 48) {
        let mutated = f.apply(&bytes);
        // A destroyed header is legitimately unrecoverable; anything the
        // salvage reader does return must decode bit-exactly.
        let Ok(s) = Bbc4Container::salvage(&mutated) else {
            continue;
        };
        let slots = s
            .container
            .decode_slots_vae(&codec)
            .unwrap_or_else(|e| panic!("{}: recovered pages must decode: {e:#}", f.describe()));
        let mut lost = Vec::new();
        for (i, slot) in slots.iter().enumerate() {
            match slot {
                Some(img) => assert_eq!(img, &imgs[i], "{}: image {i}", f.describe()),
                None => lost.push(i as u32),
            }
        }
        assert_eq!(lost, s.report.images_lost, "{}", f.describe());
    }
}

#[test]
fn bbc4_hier_salvage_recovers_intact_pages_bit_exactly() {
    let backend = hier_backend();
    let cfg = BbAnsConfig::default();
    let codec = HierCodec::new(&backend, cfg, Schedule::BitSwap).unwrap();
    let imgs = images(9, 0x33);
    let bytes = Bbc4Container::encode_hier(&codec, &imgs, 3).unwrap().to_bytes();
    for f in fault::campaign(0x7E47, bytes.len(), 32) {
        let mutated = f.apply(&bytes);
        let Ok(s) = Bbc4Container::salvage(&mutated) else {
            continue;
        };
        let slots = s
            .container
            .decode_slots_hier(&codec)
            .unwrap_or_else(|e| panic!("{}: recovered pages must decode: {e:#}", f.describe()));
        let mut lost = Vec::new();
        for (i, slot) in slots.iter().enumerate() {
            match slot {
                Some(img) => assert_eq!(img, &imgs[i], "{}: image {i}", f.describe()),
                None => lost.push(i as u32),
            }
        }
        assert_eq!(lost, s.report.images_lost, "{}", f.describe());
    }
}

/// Satellite 3: for EVERY subset of corrupted pages, salvage decodes the
/// intact images byte-identically to a clean decode and the report names
/// exactly the lost pages/images.
#[test]
fn every_corrupted_page_subset_is_isolated() {
    const N_PAGES: usize = 4;
    let backend = vae_backend();
    let cfg = BbAnsConfig::default();
    let codec = VaeCodec::new(&backend, cfg).unwrap();
    let imgs = images(10, 0x44);
    let container = Bbc4Container::encode_vae(&codec, &imgs, N_PAGES).unwrap();
    let clean = container.to_bytes();
    let ranges = page_ranges(&clean, N_PAGES);
    let page_images: Vec<(u32, u32)> = container
        .pages
        .iter()
        .map(|p| (p.first_image, p.num_images))
        .collect();

    for mask in 1u32..1 << N_PAGES {
        let mut bytes = clean.clone();
        let mut expect_pages = Vec::new();
        for (pi, &(start, _)) in ranges.iter().enumerate() {
            if mask & (1 << pi) != 0 {
                bytes[start + 21] ^= 0x40; // flip one payload bit
                expect_pages.push(pi as u32);
            }
        }
        assert!(
            Bbc4Container::from_bytes(&bytes).is_err(),
            "mask {mask:#06b}: strict parse must reject"
        );
        let s = Bbc4Container::salvage(&bytes).unwrap();
        assert_eq!(s.report.pages_lost, expect_pages, "mask {mask:#06b}");
        let mut expect_images = Vec::new();
        for &p in &expect_pages {
            let (first, n) = page_images[p as usize];
            expect_images.extend(first..first + n);
        }
        assert_eq!(s.report.images_lost, expect_images, "mask {mask:#06b}");
        let slots = s.container.decode_slots_vae(&codec).unwrap();
        for (i, slot) in slots.into_iter().enumerate() {
            if expect_images.contains(&(i as u32)) {
                assert!(slot.is_none(), "mask {mask:#06b}: image {i} should be lost");
            } else {
                assert_eq!(
                    slot.as_deref(),
                    Some(imgs[i].as_slice()),
                    "mask {mask:#06b}: image {i} must match the clean decode"
                );
            }
        }
    }
}

/// Satellite (ISSUE 10): a trailer_len claiming more bytes than the file
/// holds — and a forged index with an absurd entry count — must fail as
/// clean errors in every BBC4 reader, while salvage still recovers the
/// pages via the forward scan (it never trusts the trailer).
#[test]
fn trailer_len_beyond_the_file_is_rejected_cleanly() {
    let backend = vae_backend();
    let cfg = BbAnsConfig::default();
    let codec = VaeCodec::new(&backend, cfg).unwrap();
    let imgs = images(10, 0x66);
    let clean = Bbc4Container::encode_vae(&codec, &imgs, 3).unwrap().to_bytes();
    let n = clean.len();

    for claim in [n as u32 + 1, n as u32 * 2, u32::MAX, u32::MAX - 7] {
        let mut bad = clean.clone();
        bad[n - 4..].copy_from_slice(&claim.to_le_bytes());
        assert!(
            Bbc4Container::from_bytes(&bad).is_err(),
            "claim {claim}: strict parse must reject"
        );
        assert!(
            Bbc4StreamReader::open(std::io::Cursor::new(bad.clone())).is_err(),
            "claim {claim}: stream reader must reject"
        );
        // Salvage ignores the trailer claim and recovers every page.
        let s = Bbc4Container::salvage(&bad).unwrap();
        assert_eq!(s.report.pages_recovered, 3, "claim {claim}");
        assert!(s.report.images_lost.is_empty(), "claim {claim}");
    }

    // Forged trailer whose entry count would overflow `count * entry_len`
    // against the available bytes: replace the real index with
    // [magic | count=u32::MAX | bogus crc | trailer_len], trailer_len
    // sized to the forged block so it is the one the readers locate.
    let real_trailer_len =
        u32::from_le_bytes(clean[n - 4..].try_into().unwrap()) as usize;
    let mut forged = clean[..n - real_trailer_len].to_vec();
    forged.extend_from_slice(&[0xB4, 0x49, 0x58, 0x1A]); // INDEX_MAGIC
    forged.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd count
    forged.extend_from_slice(&0u32.to_le_bytes()); // "index crc"
    forged.extend_from_slice(&16u32.to_le_bytes()); // trailer_len
    assert!(Bbc4Container::from_bytes(&forged).is_err());
    assert!(Bbc4StreamReader::open(std::io::Cursor::new(forged.clone())).is_err());
    let s = Bbc4Container::salvage(&forged).unwrap();
    assert_eq!(s.report.pages_recovered, 3);
}

/// Satellite (ISSUE 10): the salvage report pins the torn tail's exact
/// byte range — `[end of last recovered structure, file end)` — and an
/// empty range for a clean cut at a page boundary.
#[test]
fn salvage_reports_the_truncated_tail_byte_range() {
    const N_PAGES: usize = 3;
    let backend = vae_backend();
    let cfg = BbAnsConfig::default();
    let codec = VaeCodec::new(&backend, cfg).unwrap();
    let imgs = images(9, 0x77);
    let clean = Bbc4Container::encode_vae(&codec, &imgs, N_PAGES).unwrap().to_bytes();
    let ranges = page_ranges(&clean, N_PAGES);

    // Intact file: no tail to report.
    let s = Bbc4Container::salvage(&clean).unwrap();
    assert_eq!(s.report.truncated_tail, None);

    // Cut mid-page-1: pages 0 is the last recovered structure.
    let (p1_start, p1_end) = ranges[1];
    let cut = (p1_start + p1_end) / 2;
    let s = Bbc4Container::salvage(&clean[..cut]).unwrap();
    assert!(!s.report.index_intact);
    assert_eq!(s.report.truncated_tail, Some((ranges[0].1, cut)));
    assert!(s.report.summary().contains("torn tail"), "{}", s.report.summary());

    // Cut exactly at a page boundary: the tail range is empty (only the
    // structures after it are missing, no partial bytes remain).
    let s = Bbc4Container::salvage(&clean[..p1_end]).unwrap();
    assert_eq!(s.report.truncated_tail, Some((p1_end, p1_end)));
    assert!(s.report.summary().contains("truncated at"), "{}", s.report.summary());
}

/// Truncation sweep bracketing every frame boundary: every page that lies
/// entirely before the cut must still be recovered (the forward scan works
/// with the trailer index gone).
#[test]
fn bbc4_truncation_keeps_all_complete_pages() {
    const N_PAGES: usize = 3;
    let backend = vae_backend();
    let cfg = BbAnsConfig::default();
    let codec = VaeCodec::new(&backend, cfg).unwrap();
    let imgs = images(8, 0x55);
    let clean = Bbc4Container::encode_vae(&codec, &imgs, N_PAGES).unwrap().to_bytes();
    let ranges = page_ranges(&clean, N_PAGES);
    let bounds: Vec<usize> = ranges.iter().flat_map(|&(s, e)| [s, e]).collect();

    for f in fault::boundary_truncations(&bounds, clean.len()) {
        let Fault::Truncate { len } = f else {
            panic!("boundary_truncations produced {f:?}");
        };
        if len == clean.len() {
            continue; // not actually truncated
        }
        let Ok(s) = Bbc4Container::salvage(&f.apply(&clean)) else {
            continue; // cut inside the header: unrecoverable, but clean
        };
        for (pi, &(_, end)) in ranges.iter().enumerate() {
            if end <= len {
                assert!(
                    s.container.pages.iter().any(|p| p.index == pi as u32),
                    "cut to {len}: page {pi} is complete but was not recovered"
                );
            }
        }
    }
}
