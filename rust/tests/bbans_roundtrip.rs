//! End-to-end BB-ANS over the *trained* models: roundtrip correctness and
//! the paper's core claim — achieved rate ≈ negative test ELBO (§3.2).
//! Self-skips without artifacts.

use bbans::bbans::{container::Container, BbAnsConfig, VaeCodec};
use bbans::data::load_split;
use bbans::model::{vae::NativeVae, vae::PjrtVae, Backend, Likelihood, ModelMeta};
use bbans::runtime::{artifacts_available, default_artifact_dir, load_config, Engine};
use std::sync::Arc;

fn have_artifacts() -> bool {
    artifacts_available(default_artifact_dir())
}

fn native(name: &str) -> NativeVae {
    let dir = default_artifact_dir();
    let config = load_config(&dir).unwrap();
    let m = config.get("models").unwrap().get(name).unwrap();
    let meta = ModelMeta {
        name: name.to_string(),
        pixels: config.get("pixels").unwrap().as_usize().unwrap(),
        latent_dim: m.get("latent_dim").unwrap().as_usize().unwrap(),
        hidden: m.get("hidden").unwrap().as_usize().unwrap(),
        likelihood: Likelihood::parse(m.get("likelihood").unwrap().as_str().unwrap()).unwrap(),
        test_elbo_bpd: m.get("test_elbo_bpd").unwrap().as_f64().unwrap(),
    };
    let weights = dir.join(m.get("weights").unwrap().as_str().unwrap());
    NativeVae::load(weights, meta).unwrap()
}

#[test]
fn native_bin_roundtrip_and_rate_near_elbo() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let backend = native("bin");
    let elbo = backend.meta().test_elbo_bpd;
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let ds = load_split(default_artifact_dir(), "test", true).unwrap();
    let n = 300; // enough to amortize chain startup
    let images: Vec<Vec<u8>> = ds.images.iter().take(n).cloned().collect();

    let (mut ans, stats) = codec.encode_dataset(&images).unwrap();
    let total_bits = ans.frac_bit_len();
    let bpd = total_bits / (n as f64 * 784.0);
    eprintln!("bin: rate {bpd:.4} bpd vs test ELBO {elbo:.4}");
    // Within 5% of the ELBO (the test-set slice differs slightly from the
    // full test-set ELBO; the paper reports ~1% on the full set).
    assert!(
        (bpd - elbo).abs() / elbo < 0.05,
        "rate {bpd} vs elbo {elbo}"
    );

    // Per-image net bits average to roughly the ELBO too.
    let mean_net: f64 =
        stats.iter().map(|s| s.net_bits).sum::<f64>() / (n as f64 * 784.0);
    assert!((mean_net - elbo).abs() / elbo < 0.05, "net {mean_net}");

    let decoded = codec.decode_dataset(&mut ans, n).unwrap();
    assert_eq!(decoded, images, "lossless roundtrip");
}

#[test]
fn native_full_roundtrip_and_rate_near_elbo() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let backend = native("full");
    let elbo = backend.meta().test_elbo_bpd;
    let cfg = BbAnsConfig {
        pixel_prec: 18,
        ..Default::default()
    };
    let codec = VaeCodec::new(&backend, cfg).unwrap();
    let ds = load_split(default_artifact_dir(), "test", false).unwrap();
    let n = 100;
    let images: Vec<Vec<u8>> = ds.images.iter().take(n).cloned().collect();
    let (mut ans, _) = codec.encode_dataset(&images).unwrap();
    let bpd = ans.frac_bit_len() / (n as f64 * 784.0);
    eprintln!("full: rate {bpd:.4} bpd vs test ELBO {elbo:.4}");
    assert!(
        (bpd - elbo).abs() / elbo < 0.06,
        "rate {bpd} vs elbo {elbo}"
    );
    let decoded = codec.decode_dataset(&mut ans, n).unwrap();
    assert_eq!(decoded, images);
}

#[test]
fn pjrt_bin_roundtrip_via_container() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let dir = default_artifact_dir();
    let engine = Arc::new(Engine::cpu(&dir).unwrap());
    let config = load_config(&dir).unwrap();
    let backend = PjrtVae::from_config(engine, &config, "bin").unwrap();
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let ds = load_split(&dir, "test", true).unwrap();
    let n = 40;
    let images: Vec<Vec<u8>> = ds.images.iter().take(n).cloned().collect();
    let (ans, _) = codec.encode_dataset(&images).unwrap();

    // Serialize to a container and decode a fresh coder from the bytes.
    let container = Container {
        model: "bin".into(),
        backend_id: backend.backend_id(),
        cfg: codec.cfg,
        num_images: n as u32,
        pixels: 784,
        message: ans.into_message(),
    };
    let bytes = container.to_bytes();
    let parsed = Container::from_bytes(&bytes).unwrap();
    assert_eq!(parsed.backend_id, backend.backend_id());
    let mut ans2 = bbans::ans::Ans::from_message(&parsed.message, parsed.cfg.clean_seed);
    let decoded = codec.decode_dataset(&mut ans2, n).unwrap();
    assert_eq!(decoded, images);
}

#[test]
fn pjrt_and_native_rates_agree() {
    // Backends can't be mixed within a stream, but both should achieve
    // nearly identical rates (same weights, same quantization).
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let dir = default_artifact_dir();
    let ds = load_split(&dir, "test", true).unwrap();
    let n = 50;
    let images: Vec<Vec<u8>> = ds.images.iter().take(n).cloned().collect();

    let nat = native("bin");
    let codec_n = VaeCodec::new(&nat, BbAnsConfig::default()).unwrap();
    let (ans_n, _) = codec_n.encode_dataset(&images).unwrap();

    let engine = Arc::new(Engine::cpu(&dir).unwrap());
    let config = load_config(&dir).unwrap();
    let pj = PjrtVae::from_config(engine, &config, "bin").unwrap();
    let codec_p = VaeCodec::new(&pj, BbAnsConfig::default()).unwrap();
    let (ans_p, _) = codec_p.encode_dataset(&images).unwrap();

    let rate_n = ans_n.frac_bit_len();
    let rate_p = ans_p.frac_bit_len();
    let rel = (rate_n - rate_p).abs() / rate_n;
    eprintln!("native {rate_n:.0} bits vs pjrt {rate_p:.0} bits (rel {rel:.5})");
    assert!(rel < 0.01, "backend rates diverge: {rate_n} vs {rate_p}");
}

#[test]
fn clean_bits_to_start_chain_are_small() {
    // Paper §3.2: "around 400 bits" of clean bits to start the chain.
    // Scale depends on posterior entropy; assert it's hundreds, not
    // thousands-per-image.
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let backend = native("bin");
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let ds = load_split(default_artifact_dir(), "test", true).unwrap();
    let images: Vec<Vec<u8>> = ds.images.iter().take(20).cloned().collect();
    let (ans, _) = codec.encode_dataset(&images).unwrap();
    let clean = ans.clean_bits_used();
    eprintln!("clean bits used to start the chain: {clean}");
    assert!(clean > 0, "chain must consume some clean bits");
    assert!(
        clean < 3000,
        "startup cost should be a few hundred bits, got {clean}"
    );
}
