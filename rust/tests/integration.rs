//! Cross-module integration tests that need no artifacts: the HMM
//! time-series extension, container/codec interplay, and rate accounting
//! consistency between layers.

use bbans::ans::{Ans, AnsMessage};
use bbans::bbans::timeseries::{demo_hmm, sample_sequence, HmmCodec};
use bbans::bbans::{
    container::{Container, ParallelContainer},
    BbAnsConfig, VaeCodec,
};
use bbans::model::{vae::NativeVae, Backend, Likelihood, ModelMeta};
use bbans::util::rng::Rng;

fn toy_backend(seed: u64) -> NativeVae {
    NativeVae::random(
        ModelMeta {
            name: "toy".into(),
            pixels: 49,
            latent_dim: 7,
            hidden: 14,
            likelihood: Likelihood::Bernoulli,
            test_elbo_bpd: f64::NAN,
        },
        seed,
    )
}

#[test]
fn container_roundtrip_preserves_decodability() {
    let backend = toy_backend(1);
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let mut rng = Rng::new(2);
    let images: Vec<Vec<u8>> = (0..12)
        .map(|_| (0..49).map(|_| (rng.f64() < 0.35) as u8).collect())
        .collect();
    let (ans, _) = codec.encode_dataset(&images).unwrap();
    let container = Container {
        model: "toy".into(),
        backend_id: backend.backend_id(),
        cfg: codec.cfg,
        num_images: images.len() as u32,
        pixels: 49,
        message: ans.into_message(),
    };
    // Through bytes and back.
    let parsed = Container::from_bytes(&container.to_bytes()).unwrap();
    assert_eq!(parsed, container);
    let mut ans2 = Ans::from_message(&parsed.message, parsed.cfg.clean_seed);
    let decoded = codec.decode_dataset(&mut ans2, parsed.num_images as usize).unwrap();
    assert_eq!(decoded, images);
}

/// Tentpole acceptance: the chunk-parallel container roundtrips with
/// chunk counts 1, 2 and 8 on the same input, every chunk count decodes
/// to byte-identical pixels, and serialization is deterministic.
#[test]
fn parallel_container_roundtrips_across_chunk_counts() {
    let backend = toy_backend(11);
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let mut rng = Rng::new(12);
    let images: Vec<Vec<u8>> = (0..37)
        .map(|_| (0..49).map(|_| (rng.f64() < 0.3) as u8).collect())
        .collect();

    let mut decoded_by_chunks = Vec::new();
    for n_chunks in [1usize, 2, 8] {
        let pc = ParallelContainer::encode_with(&codec, &images, n_chunks).unwrap();
        assert_eq!(pc.chunks.len(), n_chunks);
        assert_eq!(pc.num_images() as usize, images.len());

        // Deterministic bytes: encoding twice gives the identical blob.
        let bytes = pc.to_bytes();
        let again = ParallelContainer::encode_with(&codec, &images, n_chunks).unwrap();
        assert_eq!(bytes, again.to_bytes(), "{n_chunks}-chunk encode not deterministic");

        // Through bytes and back, then thread-parallel decode.
        let parsed = ParallelContainer::from_bytes(&bytes).unwrap();
        assert_eq!(parsed, pc);
        let decoded = parsed.decode_with(&codec).unwrap();
        assert_eq!(decoded, images, "{n_chunks}-chunk roundtrip");

        // Sequential decode (the coordinator's dyn-Backend path) agrees.
        assert_eq!(parsed.decode_sequential(&codec).unwrap(), images);
        decoded_by_chunks.push(decoded);
    }
    // 1-chunk and N-chunk encodings of the same stream decode identically.
    assert_eq!(decoded_by_chunks[0], decoded_by_chunks[1]);
    assert_eq!(decoded_by_chunks[0], decoded_by_chunks[2]);
}

#[test]
fn parallel_container_rejects_mismatched_codec() {
    let backend = toy_backend(13);
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let images = vec![vec![0u8; 49]; 4];
    let pc = ParallelContainer::encode_with(&codec, &images, 2).unwrap();
    // Different coding config than the header: must refuse to decode.
    let other = VaeCodec::new(
        &backend,
        BbAnsConfig {
            latent_bits: 10,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(pc.decode_with(&other).is_err());
}

/// Satellite: clean-bit accounting survives serialization. The clean
/// words drawn during encode are replayed exactly by `Ans::from_message`,
/// so a decoder resumed from bytes behaves bit-for-bit like one that
/// never left memory (encode → serialize → resume → decode equals
/// straight decode).
#[test]
fn clean_bits_replay_exactly_through_from_message() {
    let backend = toy_backend(17);
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let mut rng = Rng::new(18);
    let images: Vec<Vec<u8>> = (0..20)
        .map(|_| (0..49).map(|_| (rng.f64() < 0.4) as u8).collect())
        .collect();

    let (encoded, _) = codec.encode_dataset(&images).unwrap();
    let clean_after_encode = encoded.clean_words_used();
    assert!(clean_after_encode > 0, "chain must draw clean bits");

    // Straight decode: the coder object that did the encoding.
    let mut straight = encoded.clone();
    let straight_out = codec.decode_dataset(&mut straight, images.len()).unwrap();

    // Resumed decode: serialize, parse, and rebuild via from_message.
    let bytes = encoded.to_message().to_bytes();
    let msg = AnsMessage::from_bytes(&bytes).unwrap();
    assert_eq!(msg.clean_words_used, clean_after_encode);
    let mut resumed = Ans::from_message(&msg, codec.cfg.clean_seed);
    assert_eq!(resumed.clean_words_used(), clean_after_encode);
    let resumed_out = codec.decode_dataset(&mut resumed, images.len()).unwrap();

    assert_eq!(resumed_out, straight_out);
    assert_eq!(resumed_out, images);
    // Bit-for-bit identical end states: same clean-word count, same
    // message (decode returns the borrowed bits in both).
    assert_eq!(resumed.clean_words_used(), straight.clean_words_used());
    assert_eq!(resumed.to_message(), straight.to_message());
}

/// Golden roundtrip (ISSUE 3): container outputs are UNCHANGED by the
/// batched/packed inference rebuild. The packed GEMM accumulates every
/// output element in the seed `dense()` order (bias first, then `k`
/// ascending — see `model::tensor` module docs), so no golden vector
/// needed regenerating: the scalar reference pipeline, kept as
/// `with_reference_gemm(true)`, must produce byte-identical containers,
/// and both must decode losslessly.
#[test]
fn golden_containers_unchanged_by_batched_inference() {
    for (seed, likelihood) in [(41u64, Likelihood::Bernoulli), (42, Likelihood::BetaBinomial)] {
        let meta = || ModelMeta {
            name: "golden".into(),
            pixels: 49,
            latent_dim: 7,
            hidden: 14,
            likelihood,
            test_elbo_bpd: f64::NAN,
        };
        let packed = NativeVae::random(meta(), seed);
        let reference = NativeVae::random(meta(), seed).with_reference_gemm(true);
        let levels = match likelihood {
            Likelihood::Bernoulli => 2u64,
            Likelihood::BetaBinomial => 256,
        };
        let mut rng = Rng::new(seed ^ 0xD00D);
        let images: Vec<Vec<u8>> = (0..90)
            .map(|_| (0..49).map(|_| rng.below(levels) as u8).collect())
            .collect();

        let cp = VaeCodec::new(&packed, BbAnsConfig::default()).unwrap();
        let cr = VaeCodec::new(&reference, BbAnsConfig::default()).unwrap();

        // BBC1: one sequential chain.
        let (ans_p, _) = cp.encode_dataset(&images).unwrap();
        let (ans_r, _) = cr.encode_dataset(&images).unwrap();
        assert_eq!(
            ans_p.to_message(),
            ans_r.to_message(),
            "seed {seed}: packed chain diverged from the scalar reference"
        );

        // BBC2: chunk-parallel container, byte-for-byte.
        let pc_p = ParallelContainer::encode_with(&cp, &images, 3).unwrap();
        let pc_r = ParallelContainer::encode_with(&cr, &images, 3).unwrap();
        assert_eq!(
            pc_p.to_bytes(),
            pc_r.to_bytes(),
            "seed {seed}: packed container bytes diverged"
        );

        // Cross-decode: reference-encoded bytes decode under the packed
        // backend (the property that lets deployed decoders upgrade).
        let parsed = ParallelContainer::from_bytes(&pc_r.to_bytes()).unwrap();
        assert_eq!(parsed.decode_with(&cp).unwrap(), images);
        let mut ans = Ans::from_message(&ans_r.to_message(), cp.cfg.clean_seed);
        assert_eq!(cp.decode_dataset(&mut ans, images.len()).unwrap(), images);
    }
}

#[test]
fn image_and_sequence_codecs_share_one_stack() {
    // BB-ANS image coding and HMM sequence coding interleave on one ANS
    // stack — the "everything is a stack op" property that makes the
    // scheme composable across model families.
    let backend = toy_backend(3);
    let vcodec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let hmm = demo_hmm();
    let hcodec = HmmCodec::new(&hmm, 16);
    let mut rng = Rng::new(4);

    let img: Vec<u8> = (0..49).map(|_| (rng.f64() < 0.4) as u8).collect();
    let seq = sample_sequence(&hmm, 120, &mut rng);

    let mut ans = Ans::new(9);
    vcodec.encode_image(&mut ans, &img).unwrap();
    hcodec.encode_sequence(&mut ans, &seq).unwrap();
    // LIFO: decode sequence first, then image.
    let got_seq = hcodec.decode_sequence(&mut ans, seq.len()).unwrap();
    assert_eq!(got_seq, seq);
    let got_img = vcodec.decode_image(&mut ans).unwrap();
    assert_eq!(got_img, img);
}

#[test]
fn per_image_stats_sum_to_total_message_growth() {
    let backend = toy_backend(5);
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let mut rng = Rng::new(6);
    let images: Vec<Vec<u8>> = (0..30)
        .map(|_| (0..49).map(|_| (rng.f64() < 0.3) as u8).collect())
        .collect();
    let (ans, stats) = codec.encode_dataset(&images).unwrap();
    let net_sum: f64 = stats.iter().map(|s| s.net_bits).sum();
    // Effective message length (content minus borrowed clean words)
    // relative to the pristine coder (64-bit head).
    let total = ans.frac_bit_len() - 32.0 * ans.clean_words_used() as f64 - 32.0;
    assert!(
        (net_sum - total).abs() < 1.0,
        "stats sum {net_sum} vs message growth {total}"
    );
}

#[test]
fn hmm_vs_vae_rate_accounting_consistent() {
    // Both codecs' "net bits" must equal actual coder growth.
    let hmm = demo_hmm();
    let codec = HmmCodec::new(&hmm, 16);
    let mut rng = Rng::new(7);
    let mut ans = Ans::new(8);
    let mut claimed = 0.0;
    for _ in 0..20 {
        let seq = sample_sequence(&hmm, 100, &mut rng);
        claimed += codec.encode_sequence(&mut ans, &seq).unwrap();
    }
    let actual = ans.frac_bit_len() - 32.0 * ans.clean_words_used() as f64 - 32.0;
    assert!(
        (claimed - actual).abs() < 1.0,
        "claimed {claimed} vs actual {actual}"
    );
}
