//! Cross-module integration tests that need no artifacts: the HMM
//! time-series extension, container/codec interplay, and rate accounting
//! consistency between layers.

use bbans::ans::Ans;
use bbans::bbans::timeseries::{demo_hmm, sample_sequence, HmmCodec};
use bbans::bbans::{container::Container, BbAnsConfig, VaeCodec};
use bbans::model::{vae::NativeVae, Backend, Likelihood, ModelMeta};
use bbans::util::rng::Rng;

fn toy_backend(seed: u64) -> NativeVae {
    NativeVae::random(
        ModelMeta {
            name: "toy".into(),
            pixels: 49,
            latent_dim: 7,
            hidden: 14,
            likelihood: Likelihood::Bernoulli,
            test_elbo_bpd: f64::NAN,
        },
        seed,
    )
}

#[test]
fn container_roundtrip_preserves_decodability() {
    let backend = toy_backend(1);
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let mut rng = Rng::new(2);
    let images: Vec<Vec<u8>> = (0..12)
        .map(|_| (0..49).map(|_| (rng.f64() < 0.35) as u8).collect())
        .collect();
    let (ans, _) = codec.encode_dataset(&images).unwrap();
    let container = Container {
        model: "toy".into(),
        backend_id: backend.backend_id(),
        cfg: codec.cfg,
        num_images: images.len() as u32,
        pixels: 49,
        message: ans.into_message(),
    };
    // Through bytes and back.
    let parsed = Container::from_bytes(&container.to_bytes()).unwrap();
    assert_eq!(parsed, container);
    let mut ans2 = Ans::from_message(&parsed.message, parsed.cfg.clean_seed);
    let decoded = codec.decode_dataset(&mut ans2, parsed.num_images as usize).unwrap();
    assert_eq!(decoded, images);
}

#[test]
fn image_and_sequence_codecs_share_one_stack() {
    // BB-ANS image coding and HMM sequence coding interleave on one ANS
    // stack — the "everything is a stack op" property that makes the
    // scheme composable across model families.
    let backend = toy_backend(3);
    let vcodec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let hmm = demo_hmm();
    let hcodec = HmmCodec::new(&hmm, 16);
    let mut rng = Rng::new(4);

    let img: Vec<u8> = (0..49).map(|_| (rng.f64() < 0.4) as u8).collect();
    let seq = sample_sequence(&hmm, 120, &mut rng);

    let mut ans = Ans::new(9);
    vcodec.encode_image(&mut ans, &img).unwrap();
    hcodec.encode_sequence(&mut ans, &seq).unwrap();
    // LIFO: decode sequence first, then image.
    let got_seq = hcodec.decode_sequence(&mut ans, seq.len()).unwrap();
    assert_eq!(got_seq, seq);
    let got_img = vcodec.decode_image(&mut ans).unwrap();
    assert_eq!(got_img, img);
}

#[test]
fn per_image_stats_sum_to_total_message_growth() {
    let backend = toy_backend(5);
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let mut rng = Rng::new(6);
    let images: Vec<Vec<u8>> = (0..30)
        .map(|_| (0..49).map(|_| (rng.f64() < 0.3) as u8).collect())
        .collect();
    let (ans, stats) = codec.encode_dataset(&images).unwrap();
    let net_sum: f64 = stats.iter().map(|s| s.net_bits).sum();
    // Effective message length (content minus borrowed clean words)
    // relative to the pristine coder (64-bit head).
    let total = ans.frac_bit_len() - 32.0 * ans.clean_words_used() as f64 - 32.0;
    assert!(
        (net_sum - total).abs() < 1.0,
        "stats sum {net_sum} vs message growth {total}"
    );
}

#[test]
fn hmm_vs_vae_rate_accounting_consistent() {
    // Both codecs' "net bits" must equal actual coder growth.
    let hmm = demo_hmm();
    let codec = HmmCodec::new(&hmm, 16);
    let mut rng = Rng::new(7);
    let mut ans = Ans::new(8);
    let mut claimed = 0.0;
    for _ in 0..20 {
        let seq = sample_sequence(&hmm, 100, &mut rng);
        claimed += codec.encode_sequence(&mut ans, &seq).unwrap();
    }
    let actual = ans.frac_bit_len() - 32.0 * ans.clean_words_used() as f64 - 32.0;
    assert!(
        (claimed - actual).abs() < 1.0,
        "claimed {claimed} vs actual {actual}"
    );
}
