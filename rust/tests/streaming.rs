//! Crash-consistent streaming BBC4 (ISSUE 10 tentpole).
//!
//! The contract under test: a page-stream encode interrupted by a power
//! cut at **any byte boundary** of its durable write sequence can be
//! reopened and resumed, and the resumed encode produces a strict-valid
//! BBC4 file byte-identical to the uninterrupted one. The uninterrupted
//! streamed output is itself byte-identical to the one-shot
//! [`Bbc4Container`] encoder (golden cross-pin), the journal never leads
//! the data file, and the rate ledger of an interrupted-plus-resumed
//! encode merges to exactly the uninterrupted entries.

use std::io::Cursor;

use bbans::bbans::bbc4::{Bbc4Container, Bbc4Model, Bbc4StreamReader, Bbc4StreamWriter, Resumed};
use bbans::bbans::hierarchy::{HierCodec, Schedule};
use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::format::stream::{
    journal_path, journal_prefix, JournalRecord, VecMedium, JOURNAL_RECORD_LEN,
};
use bbans::model::hierarchy::{HierMeta, HierVae};
use bbans::model::{vae::NativeVae, Likelihood, ModelMeta};
use bbans::util::fault::{self, Fault};
use bbans::util::rng::Rng;

const PIXELS: usize = 16;
const N_IMAGES: usize = 6;
const N_PAGES: u32 = 3;

fn vae_backend() -> NativeVae {
    NativeVae::random(
        ModelMeta {
            name: "stream-vae".into(),
            pixels: PIXELS,
            latent_dim: 3,
            hidden: 8,
            likelihood: Likelihood::BetaBinomial,
            test_elbo_bpd: f64::NAN,
        },
        0x57EA,
    )
}

fn hier_backend() -> HierVae {
    HierVae::random(
        HierMeta {
            name: "stream-hier".into(),
            pixels: PIXELS,
            dims: vec![4, 2],
            hidden: 8,
            likelihood: Likelihood::BetaBinomial,
        },
        0x57EA,
    )
}

fn images(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..PIXELS).map(|_| rng.below(256) as u8).collect())
        .collect()
}

fn vae_shell(codec: &VaeCodec<'_, NativeVae>, n: usize, pages: u32) -> Bbc4Container {
    Bbc4Container::new_shell(
        Bbc4Model::for_vae(codec),
        codec.cfg,
        PIXELS as u32,
        n as u32,
        pages,
    )
    .unwrap()
}

/// Stream-encode `imgs` uninterrupted; returns `(data, journal)` bytes.
fn stream_all_vae(
    codec: &VaeCodec<'_, NativeVae>,
    imgs: &[Vec<u8>],
    pages: u32,
) -> (Vec<u8>, Vec<u8>) {
    let shell = vae_shell(codec, imgs.len(), pages);
    let mut w = Bbc4StreamWriter::start(VecMedium::new(), VecMedium::new(), shell).unwrap();
    while w.encode_next_vae(codec, imgs).unwrap() {}
    let (d, j) = w.finish().unwrap();
    (d.buf, j.buf)
}

/// Parse every journal record (the file must be exactly whole records).
fn records(journal: &[u8]) -> Vec<JournalRecord> {
    let mut recs = Vec::new();
    let mut at = 0;
    while let Some(r) = JournalRecord::from_bytes(&journal[at..]) {
        recs.push(r);
        at += JOURNAL_RECORD_LEN;
    }
    assert_eq!(at, journal.len(), "journal must be whole records");
    recs
}

#[test]
fn uninterrupted_stream_is_byte_identical_to_one_shot() {
    let backend = vae_backend();
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let imgs = images(N_IMAGES, 0xA1);
    let one_shot = Bbc4Container::encode_vae(&codec, &imgs, N_PAGES as usize)
        .unwrap()
        .to_bytes();
    let (streamed, journal) = stream_all_vae(&codec, &imgs, N_PAGES);
    assert_eq!(streamed, one_shot, "vae stream must match the one-shot bytes");

    // One record per durable commit: the header plus every page, each
    // telescoping over the data file (monotone lengths, exact counts).
    let recs = records(&journal);
    assert_eq!(recs.len(), N_PAGES as usize + 1);
    let mut prev = 0u64;
    for (i, r) in recs.iter().enumerate() {
        assert_eq!(r.pages_done, i as u32);
        assert!(r.bytes_written > prev, "record {i} must extend the file");
        prev = r.bytes_written;
    }
    assert_eq!(recs.last().unwrap().images_done, N_IMAGES as u32);

    let hb = hier_backend();
    let hcodec = HierCodec::new(&hb, BbAnsConfig::default(), Schedule::BitSwap).unwrap();
    let hier_one_shot = Bbc4Container::encode_hier(&hcodec, &imgs, N_PAGES as usize)
        .unwrap()
        .to_bytes();
    let shell = Bbc4Container::new_shell(
        Bbc4Model::for_hier(&hcodec),
        hcodec.cfg,
        PIXELS as u32,
        imgs.len() as u32,
        N_PAGES,
    )
    .unwrap();
    let mut w = Bbc4StreamWriter::start(VecMedium::new(), VecMedium::new(), shell).unwrap();
    while w.encode_next_hier(&hcodec, &imgs).unwrap() {}
    let (d, _) = w.finish().unwrap();
    assert_eq!(d.buf, hier_one_shot, "hier stream must match the one-shot bytes");
}

/// The tentpole property: cut the durable write sequence at EVERY byte
/// boundary; reopen-and-resume must always complete to a file
/// byte-identical to the uninterrupted encode.
#[test]
fn resume_after_a_cut_at_every_byte_reproduces_the_uninterrupted_encode() {
    let backend = vae_backend();
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let imgs = images(N_IMAGES, 0xB2);
    let (full_data, full_journal) = stream_all_vae(&codec, &imgs, N_PAGES);
    let recs = records(&full_journal);

    // Reconstruct the exact interleaved durable write sequence from the
    // journal (each record is committed right after the data bytes it
    // vouches for): D[header] J[rec0] D[page0] J[rec1] … D[trailer].
    let mut ops: Vec<(bool, &[u8])> = Vec::new();
    let mut dpos = 0usize;
    for (i, r) in recs.iter().enumerate() {
        ops.push((true, &full_data[dpos..r.bytes_written as usize]));
        dpos = r.bytes_written as usize;
        ops.push((
            false,
            &full_journal[i * JOURNAL_RECORD_LEN..(i + 1) * JOURNAL_RECORD_LEN],
        ));
    }
    ops.push((true, &full_data[dpos..])); // the trailer index
    let total: usize = ops.iter().map(|(_, b)| b.len()).sum();
    assert_eq!(total, full_data.len() + full_journal.len());

    for cut in 0..=total {
        // State after a power cut at byte `cut` of the write sequence.
        let (mut data, mut journal) = (Vec::new(), Vec::new());
        let mut left = cut;
        for (is_data, b) in &ops {
            let take = left.min(b.len());
            if *is_data {
                data.extend_from_slice(&b[..take]);
            } else {
                journal.extend_from_slice(&b[..take]);
            }
            left -= take;
        }
        let shell = vae_shell(&codec, imgs.len(), N_PAGES);
        let resumed = Bbc4StreamWriter::resume_media(
            VecMedium::from_bytes(data.clone()),
            VecMedium::from_bytes(journal.clone()),
            &data,
            &journal,
            shell,
        )
        .unwrap_or_else(|e| panic!("cut {cut}: resume failed: {e:#}"));
        let out = match resumed {
            Resumed::Complete => data,
            Resumed::Writer(mut w) => {
                while w
                    .encode_next_vae(&codec, &imgs)
                    .unwrap_or_else(|e| panic!("cut {cut}: encode failed: {e:#}"))
                {}
                let (d, j) = w.finish().unwrap();
                // The resume invariant holds on the continued journal too.
                let (_, last) = journal_prefix(&j.buf);
                assert!(last.unwrap().bytes_written <= d.buf.len() as u64, "cut {cut}");
                d.buf
            }
        };
        assert_eq!(out, full_data, "cut {cut}: resumed bytes differ");
    }

    // One strict decode covers every cut (all outputs are byte-equal).
    let c = Bbc4Container::from_bytes(&full_data).unwrap();
    let decoded: Vec<Vec<u8>> = c
        .decode_slots_vae(&codec)
        .unwrap()
        .into_iter()
        .map(Option::unwrap)
        .collect();
    assert_eq!(decoded, imgs);
}

/// A journal that *leads* the data file means bytes the journal vouched
/// for are gone — that is data loss, not a torn tail, and resume must
/// refuse (pointing at salvage) rather than silently re-encode.
#[test]
fn journal_leading_the_data_file_is_rejected() {
    let backend = vae_backend();
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let imgs = images(N_IMAGES, 0xC3);
    let (full_data, full_journal) = stream_all_vae(&codec, &imgs, N_PAGES);
    let recs = records(&full_journal);

    // Data truncated to header + page 0, journal claiming all pages.
    let data = full_data[..recs[1].bytes_written as usize].to_vec();
    let err = Bbc4StreamWriter::resume_media(
        VecMedium::from_bytes(data.clone()),
        VecMedium::from_bytes(full_journal.clone()),
        &data,
        &full_journal,
        vae_shell(&codec, imgs.len(), N_PAGES),
    )
    .map(|_| ())
    .unwrap_err();
    assert!(format!("{err:#}").contains("salvage"), "got: {err:#}");

    // Data with intact pages but no journal at all: the sidecar is gone,
    // so the stream identity cannot be vouched for — also a hard error.
    let err = Bbc4StreamWriter::resume_media(
        VecMedium::from_bytes(data.clone()),
        VecMedium::new(),
        &data,
        &[],
        vae_shell(&codec, imgs.len(), N_PAGES),
    )
    .map(|_| ())
    .unwrap_err();
    assert!(format!("{err:#}").contains("journal"), "got: {err:#}");

    // A different encode's header is never silently overwritten.
    let other = images(N_IMAGES, 0xDD);
    let other_shell = vae_shell(&codec, other.len(), N_PAGES + 1);
    let err = Bbc4StreamWriter::resume_media(
        VecMedium::from_bytes(data.clone()),
        VecMedium::from_bytes(full_journal.clone()),
        &data,
        &full_journal,
        other_shell,
    )
    .map(|_| ())
    .unwrap_err();
    assert!(format!("{err:#}").contains("header mismatch"), "got: {err:#}");
}

/// File-backed power-cut campaign (CI leg): seeded cuts at page
/// boundaries, their ±1 neighbours, and mid-page interiors, each with a
/// consistent and a lagging journal. Every cut must reopen, resume, and
/// finish to the identical file, retiring the journal sidecar.
#[test]
fn file_backed_powercut_campaign_resumes_to_identical_bytes() {
    let dir = std::env::temp_dir().join(format!("bbans-powercut-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let backend = vae_backend();
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let imgs = images(N_IMAGES, 0xD4);
    let (full_data, full_journal) = stream_all_vae(&codec, &imgs, N_PAGES);
    let recs = records(&full_journal);
    let boundaries: Vec<usize> = recs.iter().map(|r| r.bytes_written as usize).collect();

    for (fi, f) in fault::powercut_campaign(0x9C7, &boundaries, full_data.len(), 2)
        .into_iter()
        .enumerate()
    {
        let Fault::Truncate { len } = f else {
            panic!("powercut_campaign produced {f:?}");
        };
        let path = dir.join(format!("cut-{fi}.bbc4"));
        // Journal consistent with the cut (all records the data still
        // covers), plus a lagging variant (record lost with the cut).
        let keep = recs.iter().filter(|r| r.bytes_written as usize <= len).count();
        for lag in 0..=1usize {
            let k = keep.saturating_sub(lag);
            std::fs::write(&path, &full_data[..len]).unwrap();
            std::fs::write(journal_path(&path), &full_journal[..k * JOURNAL_RECORD_LEN])
                .unwrap();
            let shell = vae_shell(&codec, imgs.len(), N_PAGES);
            let mut w = match Bbc4StreamWriter::resume(&path, shell)
                .unwrap_or_else(|e| panic!("cut {len} lag {lag}: {e:#}"))
            {
                Resumed::Complete => {
                    assert_eq!(std::fs::read(&path).unwrap(), full_data);
                    assert!(!journal_path(&path).exists(), "journal must be retired");
                    continue;
                }
                Resumed::Writer(w) => *w,
            };
            while w.encode_next_vae(&codec, &imgs).unwrap() {}
            w.finish_file().unwrap();
            assert_eq!(
                std::fs::read(&path).unwrap(),
                full_data,
                "cut {len} lag {lag}: resumed file differs"
            );
            assert!(!journal_path(&path).exists(), "journal must be retired");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite 3: the rate ledger survives a resume — the interrupted
/// run's entries merged with the resumed run's entries equal the
/// uninterrupted encode's per-image entries, and every entry's ELBO
/// decomposition telescopes (residual ≈ 0).
#[test]
fn interrupted_plus_resumed_ledger_matches_the_uninterrupted_entries() {
    let dir = std::env::temp_dir().join(format!("bbans-ledger-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let backend = vae_backend();
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let imgs = images(N_IMAGES, 0xE5);

    let mut w = Bbc4StreamWriter::start(
        VecMedium::new(),
        VecMedium::new(),
        vae_shell(&codec, imgs.len(), N_PAGES),
    )
    .unwrap();
    w.enable_ledger();
    while w.encode_next_vae(&codec, &imgs).unwrap() {}
    let full_ledger = w.take_ledger().unwrap();
    let (full_data, _) = w.finish().unwrap();
    assert_eq!(full_ledger.entries.len(), N_IMAGES);

    // Interrupted file-backed run: one page, then the process "dies".
    let path = dir.join("ledgered.bbc4");
    let mut w1 =
        Bbc4StreamWriter::create(&path, vae_shell(&codec, imgs.len(), N_PAGES)).unwrap();
    w1.enable_ledger();
    assert!(w1.encode_next_vae(&codec, &imgs).unwrap());
    let l1 = w1.take_ledger().unwrap();
    drop(w1);

    let mut w2 = match Bbc4StreamWriter::resume(&path, vae_shell(&codec, imgs.len(), N_PAGES))
        .unwrap()
    {
        Resumed::Writer(w) => *w,
        Resumed::Complete => panic!("one page written; stream cannot be complete"),
    };
    assert_eq!(w2.pages_done(), 1);
    w2.enable_ledger();
    while w2.encode_next_vae(&codec, &imgs).unwrap() {}
    let l2 = w2.take_ledger().unwrap();
    w2.finish_file().unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), full_data.buf);

    let mut merged = l1;
    merged.merge(l2);
    assert_eq!(
        merged.entries, full_ledger.entries,
        "merged ledger must equal the uninterrupted encode's entries"
    );
    for (i, e) in merged.entries.iter().enumerate() {
        assert!(
            e.decomposition_residual() < 1e-6,
            "entry {i}: residual {}",
            e.decomposition_residual()
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Bounded-memory reader: page-at-a-time decode equals the one-shot
/// decode, raw parts reassemble the file byte-identically, and a
/// trailer_len claiming more bytes than the file holds is rejected.
#[test]
fn stream_reader_decodes_page_at_a_time_identically() {
    let backend = vae_backend();
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let imgs = images(N_IMAGES, 0xF6);
    let bytes = Bbc4Container::encode_vae(&codec, &imgs, N_PAGES as usize)
        .unwrap()
        .to_bytes();

    let mut r = Bbc4StreamReader::open(Cursor::new(bytes.clone())).unwrap();
    assert_eq!(r.n_pages(), N_PAGES);

    // header + frames + trailer reassemble the exact file (this is what
    // the wire-fetch client concatenates).
    let mut rebuilt = r.header_raw().unwrap();
    for i in 0..N_PAGES as usize {
        let (frame, _crc) = r.raw_frame(i).unwrap();
        rebuilt.extend_from_slice(&frame);
    }
    rebuilt.extend_from_slice(r.trailer_raw());
    assert_eq!(rebuilt, bytes);

    let mut got = vec![Vec::new(); N_IMAGES];
    while let Some((first, page)) = r.decode_next_vae(&codec).unwrap() {
        for (k, img) in page.into_iter().enumerate() {
            got[first as usize + k] = img;
        }
    }
    assert_eq!(got, imgs);

    // Hierarchical pages decode the same way.
    let hb = hier_backend();
    let hcodec = HierCodec::new(&hb, BbAnsConfig::default(), Schedule::BitSwap).unwrap();
    let hbytes = Bbc4Container::encode_hier(&hcodec, &imgs, N_PAGES as usize)
        .unwrap()
        .to_bytes();
    let mut hr = Bbc4StreamReader::open(Cursor::new(hbytes)).unwrap();
    let mut hgot = vec![Vec::new(); N_IMAGES];
    while let Some((first, page)) = hr.decode_next_hier(&hcodec).unwrap() {
        for (k, img) in page.into_iter().enumerate() {
            hgot[first as usize + k] = img;
        }
    }
    assert_eq!(hgot, imgs);

    // trailer_len pointing past the file must be a clean error.
    for claim in [bytes.len() as u32 + 1, u32::MAX] {
        let mut bad = bytes.clone();
        let n = bad.len();
        bad[n - 4..].copy_from_slice(&claim.to_le_bytes());
        assert!(Bbc4StreamReader::open(Cursor::new(bad)).is_err(), "claim {claim}");
    }
}
