//! Integration tests for the baseline codecs: cross-validation against
//! the reference crates (format interop + rate sanity; gated behind the
//! `external-codecs` feature since those crates are not vendored
//! offline) and roundtrips on the real artifact dataset when present.

use bbans::baselines::{standard_suite, BzCodec, GzipCodec, ImageCodec};
use bbans::data::{load_split, synth};
use bbans::runtime::{artifacts_available, default_artifact_dir};
use bbans::util::prop::check_bytes;

#[cfg(feature = "external-codecs")]
#[test]
fn our_gzip_interops_with_flate2_both_ways() {
    use bbans::baselines::external;
    check_bytes(61, 25, 20_000, |data| {
        let ours = bbans::baselines::gzip::gzip_compress(data, 128);
        let via_flate2 = external::flate2_gunzip(&ours).ok();
        let theirs = external::flate2_gzip(data);
        let via_ours = bbans::baselines::gzip::gzip_decompress(&theirs).ok();
        via_flate2.as_deref() == Some(data) && via_ours.as_deref() == Some(data)
    });
}

#[cfg(feature = "external-codecs")]
#[test]
fn our_deflate_rate_is_competitive_with_miniz() {
    use bbans::baselines::external;
    // Within 15% of flate2 level 6 on a realistic mix.
    let mut total_ours = 0usize;
    let mut total_theirs = 0usize;
    let mut rng = bbans::util::rng::Rng::new(62);
    for case in 0..12 {
        let data = bbans::util::prop::gen_bytes(&mut rng, 60_000, case);
        total_ours += bbans::baselines::gzip::gzip_compress(&data, 128).len();
        total_theirs += external::flate2_gzip(&data).len();
    }
    let ratio = total_ours as f64 / total_theirs as f64;
    eprintln!("our gzip / flate2 size ratio: {ratio:.3}");
    assert!(ratio < 1.15, "our deflate is too weak: ratio {ratio}");
}

#[cfg(feature = "external-codecs")]
#[test]
fn our_bz_rate_is_sane_vs_bzip2() {
    use bbans::baselines::external;
    // Containers differ; compare rates on block-sorting-friendly data.
    let data: Vec<u8> = b"the quick brown fox jumps over the lazy dog. "
        .iter()
        .cycle()
        .take(200_000)
        .copied()
        .collect();
    let ours = bbans::baselines::bz::compress(&data, 256 * 1024).len();
    let theirs = external::bzip2_compress(&data).len();
    let ratio = ours as f64 / theirs as f64;
    eprintln!("our bz / bzip2 size ratio: {ratio:.3} ({ours} vs {theirs})");
    // bzip2 has multi-table Huffman + better RLE; allow up to 2x on this
    // extreme input but require the same order of magnitude.
    assert!(ratio < 2.0, "bz-style rate too weak: {ratio}");
}

/// Offline stand-in for the flate2 interop check: our gzip container must
/// carry a correct CRC-32 and ISIZE and reject tampering with either —
/// the format-level properties an external reader would rely on.
#[test]
fn gzip_container_checksums_are_correct() {
    check_bytes(63, 25, 20_000, |data| {
        let ours = bbans::baselines::gzip::gzip_compress(data, 128);
        // Trailer: CRC-32 (LE) then ISIZE (LE), per RFC 1952.
        let n = ours.len();
        let crc = u32::from_le_bytes(ours[n - 8..n - 4].try_into().unwrap());
        let isize_ = u32::from_le_bytes(ours[n - 4..].try_into().unwrap());
        crc == bbans::util::crc32::hash(data) && isize_ as usize == data.len()
    });
}

#[test]
fn rates_on_real_dataset_match_expected_ordering() {
    if !artifacts_available(default_artifact_dir()) {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Paper Table 2 ordering on binarized MNIST: bz2 < gzip < PNG.
    let ds = load_split(default_artifact_dir(), "test", true)
        .unwrap()
        .subset(1000);
    let mut rates = std::collections::BTreeMap::new();
    for codec in standard_suite(true) {
        let bpd = codec.bits_per_dim(&ds).unwrap();
        eprintln!("{:>10}: {bpd:.3} bits/dim (binarized)", codec.name());
        rates.insert(codec.name().to_string(), bpd);
    }
    assert!(rates["bz2-style"] < rates["gzip"], "bz should beat gzip");
    assert!(rates["gzip"] < rates["png"], "gzip should beat per-image png");
    // Stream baselines must beat raw (1 bit/dim for binarized data).
    assert!(rates["bz2-style"] < 1.0 && rates["gzip"] < 1.0);
}

#[test]
fn whole_suite_roundtrips_on_artifact_data() {
    if !artifacts_available(default_artifact_dir()) {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let ds = load_split(default_artifact_dir(), "test", false)
        .unwrap()
        .subset(64);
    for codec in standard_suite(false) {
        let blobs = codec.compress_dataset(&ds).unwrap();
        let images = codec
            .decompress_dataset(&blobs, (ds.len(), ds.rows, ds.cols))
            .unwrap();
        assert_eq!(images, ds.images, "{} roundtrip on artifact data", codec.name());
    }
}

#[test]
fn stream_codecs_on_synthetic_natural_images() {
    // Table 3 substrate: 64x64 "natural" images roundtrip + rates < 9 bpd.
    let ds = synth::natural(8, 64, 77);
    for codec in [
        Box::new(GzipCodec { max_chain: 128 }) as Box<dyn ImageCodec>,
        Box::new(BzCodec {
            block_size: 256 * 1024,
        }),
    ] {
        let blobs = codec.compress_dataset(&ds).unwrap();
        let images = codec
            .decompress_dataset(&blobs, (ds.len(), ds.rows, ds.cols))
            .unwrap();
        assert_eq!(images, ds.images);
        let bpd = codec.bits_per_dim(&ds).unwrap();
        eprintln!("{:>10}: {bpd:.3} bits/dim (natural 64x64)", codec.name());
        assert!(bpd < 9.0);
    }
}
