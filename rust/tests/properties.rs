//! Cross-module property tests (our mini prop framework stands in for
//! proptest): codec invariants swept across random shapes, configs and
//! adversarial inputs.

use bbans::ans::interleaved::InterleavedAns;
use bbans::ans::{Ans, EntropyCoder, Interval, PreparedInterval, SymbolTable};
use bbans::bbans::container::{HierContainer, ParallelContainer};
use bbans::bbans::hierarchy::{HierCodec, Schedule};
use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::model::hierarchy::{HierMeta, HierVae};
use bbans::codecs::categorical::Categorical;
use bbans::codecs::gaussian::{DiscretizedGaussian, MaxEntropyBuckets};
use bbans::codecs::quantize::DecodeLut;
use bbans::codecs::SymbolCodec;
use bbans::model::{vae::NativeVae, Backend, Likelihood, ModelMeta};
use bbans::util::prop::{check_coders, check_coders_wide};
use bbans::util::rng::Rng;

/// Fuzz BB-ANS roundtrips across model shapes, likelihoods and coding
/// precisions.
#[test]
fn bbans_roundtrip_sweep() {
    let mut rng = Rng::new(0xfeed);
    for trial in 0..15 {
        let pixels = 4 + rng.below(60) as usize;
        let latent = 1 + rng.below(12) as usize;
        let likelihood = if trial % 2 == 0 {
            Likelihood::Bernoulli
        } else {
            Likelihood::BetaBinomial
        };
        let meta = ModelMeta {
            name: format!("fuzz{trial}"),
            pixels,
            latent_dim: latent,
            hidden: 4 + rng.below(20) as usize,
            likelihood,
            test_elbo_bpd: f64::NAN,
        };
        let backend = NativeVae::random(meta, 1000 + trial as u64);
        let cfg = BbAnsConfig {
            latent_bits: 8 + (trial % 3) as u32 * 4, // 8, 12, 16
            posterior_prec: 24,
            pixel_prec: 12 + (trial % 4) as u32 * 2, // 12..18
            clean_seed: trial as u64,
        };
        let codec = VaeCodec::new(&backend, cfg).unwrap();
        let levels = match likelihood {
            Likelihood::Bernoulli => 2u64,
            Likelihood::BetaBinomial => 256,
        };
        let n_imgs = 1 + rng.below(10) as usize;
        let images: Vec<Vec<u8>> = (0..n_imgs)
            .map(|_| (0..pixels).map(|_| rng.below(levels) as u8).collect())
            .collect();
        let (mut ans, _) = codec.encode_dataset(&images).unwrap();
        let decoded = codec.decode_dataset(&mut ans, n_imgs).unwrap();
        assert_eq!(decoded, images, "trial {trial}");
    }
}

/// Tentpole property (ISSUE 3): batched inference and the pipelined /
/// chunk-pooled encode paths are bit-identical to the B=1 sequential
/// path for EVERY batch size and worker count — the packed GEMM and a
/// fixed block order make the posterior parameters row-independent, so
/// neither batching nor thread count can change a single coded bit.
#[test]
fn batched_inference_bit_identical_across_batch_and_workers() {
    let mut rng = Rng::new(0xba7c);
    for (trial, likelihood) in [Likelihood::Bernoulli, Likelihood::BetaBinomial]
        .into_iter()
        .enumerate()
    {
        let meta = ModelMeta {
            name: format!("batch{trial}"),
            pixels: 30,
            latent_dim: 5,
            hidden: 11,
            likelihood,
            test_elbo_bpd: f64::NAN,
        };
        let backend = NativeVae::random(meta, 500 + trial as u64);
        let cfg = BbAnsConfig::default();
        let codec = VaeCodec::new(&backend, cfg).unwrap();
        let levels = match likelihood {
            Likelihood::Bernoulli => 2u64,
            Likelihood::BetaBinomial => 256,
        };
        // > 2*NN_CHUNK images so the pipelined encode spans several
        // posterior blocks.
        let images: Vec<Vec<u8>> = (0..150)
            .map(|_| (0..30).map(|_| rng.below(levels) as u8).collect())
            .collect();

        // Posterior params: full batch vs one-image calls, bitwise.
        let scaled: Vec<Vec<f32>> = images.iter().map(|i| codec.scale_image(i)).collect();
        let refs: Vec<&[f32]> = scaled.iter().map(|v| v.as_slice()).collect();
        let full = backend.posterior(&refs).unwrap();
        for (i, x) in scaled.iter().enumerate() {
            let one = backend.posterior(&[x.as_slice()]).unwrap();
            assert_eq!(one[0], full[i], "trial {trial} image {i}");
        }

        // One sequential chain vs the pipelined encode at several worker
        // counts: identical serialized message.
        let (base, _) = codec.encode_dataset(&images).unwrap();
        let base_msg = base.to_message();
        for workers in [1usize, 2, 5] {
            let mut ans = Ans::new(cfg.clean_seed);
            codec
                .encode_dataset_pipelined(&mut ans, &images, workers)
                .unwrap();
            assert_eq!(
                ans.to_message(),
                base_msg,
                "trial {trial}: pipelined encode with {workers} workers diverged"
            );
        }

        // Chunk-parallel container: the worker pool never changes bytes,
        // and every pool size decodes losslessly.
        let c1 = ParallelContainer::encode_with_workers(&codec, &images, 4, 1).unwrap();
        for workers in [2usize, 8] {
            let c = ParallelContainer::encode_with_workers(&codec, &images, 4, workers).unwrap();
            assert_eq!(
                c.to_bytes(),
                c1.to_bytes(),
                "trial {trial}: chunked encode with {workers} workers diverged"
            );
        }
        assert_eq!(c1.decode_with_workers(&codec, 3).unwrap(), images);
    }
}

/// ISSUE 5 golden-stream invariance: the scalar reference GEMM, the
/// scalar packed kernel, and every SIMD kernel this CPU can dispatch
/// must produce byte-identical BBC1/BBC2/BBC3 containers — across chunk
/// counts and worker counts — and each variant must decode the others'
/// output. This is the container-level pin of the whole SIMD layer's
/// bit-identity contract.
#[test]
fn simd_kernel_variants_bit_identical_across_containers() {
    use bbans::bbans::container::Container;
    use bbans::simd;

    // Restore runtime dispatch even if an assertion fails mid-test.
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            simd::force(None);
        }
    }
    let _restore = Restore;

    let cfg = BbAnsConfig::default();
    for (trial, likelihood) in [Likelihood::Bernoulli, Likelihood::BetaBinomial]
        .into_iter()
        .enumerate()
    {
        let meta = ModelMeta {
            name: format!("simd{trial}"),
            pixels: 30,
            latent_dim: 5,
            hidden: 11,
            likelihood,
            test_elbo_bpd: f64::NAN,
        };
        let backend = NativeVae::random(meta.clone(), 0x51D0 + trial as u64);
        let reference = NativeVae::random(meta, 0x51D0 + trial as u64).with_reference_gemm(true);
        let levels = match likelihood {
            Likelihood::Bernoulli => 2u64,
            Likelihood::BetaBinomial => 256,
        };
        let mut rng = Rng::new(0xE5 + trial as u64);
        // > NN_CHUNK images so batched recognition spans several blocks.
        let images: Vec<Vec<u8>> = (0..70)
            .map(|_| (0..30).map(|_| rng.below(levels) as u8).collect())
            .collect();

        // Reference bytes: scalar reference GEMM under the forced-scalar
        // kernel (the most conservative path in the system).
        simd::force(Some(simd::Kernel::Scalar));
        let ref_codec = VaeCodec::new(&reference, cfg).unwrap();
        let bbc1_ref = {
            let (ans, _) = ref_codec.encode_dataset(&images).unwrap();
            Container {
                model: "simd".into(),
                backend_id: reference.backend_id(),
                cfg,
                num_images: images.len() as u32,
                pixels: 30,
                message: ans.into_message(),
            }
            .to_bytes()
        };
        let bbc2_ref = ParallelContainer::encode_with_workers(&ref_codec, &images, 3, 1)
            .unwrap()
            .to_bytes();

        for kernel in simd::available() {
            simd::force(Some(kernel));
            let codec = VaeCodec::new(&backend, cfg).unwrap();
            // BBC1: one chained stream.
            let (ans, _) = codec.encode_dataset(&images).unwrap();
            let bbc1 = Container {
                model: "simd".into(),
                backend_id: backend.backend_id(),
                cfg,
                num_images: images.len() as u32,
                pixels: 30,
                message: ans.into_message(),
            }
            .to_bytes();
            assert_eq!(bbc1, bbc1_ref, "{kernel:?} {likelihood:?}: BBC1 bytes diverged");
            // BBC2: chunk counts x worker counts.
            for (n_chunks, workers) in [(1usize, 1usize), (3, 2), (3, 5)] {
                let pc =
                    ParallelContainer::encode_with_workers(&codec, &images, n_chunks, workers)
                        .unwrap();
                if n_chunks == 3 {
                    assert_eq!(
                        pc.to_bytes(),
                        bbc2_ref,
                        "{kernel:?} {likelihood:?}: BBC2 bytes diverged (w={workers})"
                    );
                }
                // Cross-kernel decode of this variant's own output.
                assert_eq!(pc.decode_with_workers(&codec, 2).unwrap(), images);
            }
            // Decode the scalar-reference container under this kernel.
            let parsed = ParallelContainer::from_bytes(&bbc2_ref).unwrap();
            assert_eq!(
                parsed.decode_with_workers(&codec, 3).unwrap(),
                images,
                "{kernel:?}: failed to decode the reference stream"
            );
        }
        simd::force(None);
    }

    // BBC3: the hierarchical chain (no reference-GEMM switch exists, so
    // the forced-scalar kernel is the reference arm).
    let hmeta = HierMeta {
        name: "simd-hier".into(),
        pixels: 30,
        dims: vec![5, 4],
        hidden: 11,
        likelihood: Likelihood::Bernoulli,
    };
    let hbackend = HierVae::random(hmeta, 0xAB0);
    let mut rng = Rng::new(0x77AB);
    let images: Vec<Vec<u8>> = (0..70)
        .map(|_| (0..30).map(|_| (rng.f64() < 0.35) as u8).collect())
        .collect();
    for schedule in [Schedule::Naive, Schedule::BitSwap] {
        let codec = HierCodec::new(&hbackend, BbAnsConfig::default(), schedule).unwrap();
        simd::force(Some(simd::Kernel::Scalar));
        let href = HierContainer::encode_with_workers(&codec, &images, 3, 1)
            .unwrap()
            .to_bytes();
        for kernel in simd::available() {
            simd::force(Some(kernel));
            for workers in [1usize, 4] {
                let hc = HierContainer::encode_with_workers(&codec, &images, 3, workers).unwrap();
                assert_eq!(
                    hc.to_bytes(),
                    href,
                    "{kernel:?} {schedule:?}: BBC3 bytes diverged (w={workers})"
                );
            }
            let parsed = HierContainer::from_bytes(&href).unwrap();
            assert_eq!(parsed.decode_with_workers(&codec, 3).unwrap(), images);
            assert_eq!(parsed.decode_lockstep(&codec).unwrap(), images);
        }
        simd::force(None);
    }
}

/// Hierarchical extension of the invariance suite (ISSUE 4): for BOTH
/// coding schedules and L ∈ {2, 3}, the encode bitstream is identical
/// across worker counts and batch groupings, chunked container bytes are
/// worker-invariant, and every decode route (per-chunk pooled, lock-step
/// batched) restores the images byte-for-byte.
#[test]
fn hier_bit_identity_across_workers_and_schedules() {
    for (trial, dims) in [[5usize, 4].as_slice(), &[5, 4, 3]].into_iter().enumerate() {
        let meta = HierMeta {
            name: format!("hier{trial}"),
            pixels: 30,
            dims: dims.to_vec(),
            hidden: 11,
            likelihood: Likelihood::Bernoulli,
        };
        let backend = HierVae::random(meta, 700 + trial as u64);
        let mut rng = Rng::new(0x41e7 + trial as u64);
        // > 2*NN_CHUNK images so the pipelined encode spans several
        // layer-0 posterior blocks.
        let images: Vec<Vec<u8>> = (0..150)
            .map(|_| (0..30).map(|_| (rng.f64() < 0.35) as u8).collect())
            .collect();
        for schedule in [Schedule::Naive, Schedule::BitSwap] {
            let cfg = BbAnsConfig::default();
            let codec = HierCodec::new(&backend, cfg, schedule).unwrap();

            // One sequential chain vs the pipelined encode at several
            // worker counts: identical serialized message.
            let (base, _) = codec.encode_dataset(&images).unwrap();
            let base_msg = base.to_message();
            for workers in [1usize, 2, 5] {
                let mut ans = Ans::new(cfg.clean_seed);
                codec
                    .encode_dataset_pipelined(&mut ans, &images, workers)
                    .unwrap();
                assert_eq!(ans.to_message(), base_msg, "{schedule:?} w={workers}");
            }

            // Chunked container: the worker pool never changes bytes, and
            // both decode routes restore the dataset.
            let c1 = HierContainer::encode_with_workers(&codec, &images, 4, 1).unwrap();
            for workers in [2usize, 8] {
                let c = HierContainer::encode_with_workers(&codec, &images, 4, workers).unwrap();
                assert_eq!(c.to_bytes(), c1.to_bytes(), "{schedule:?} w={workers}");
            }
            assert_eq!(c1.decode_with_workers(&codec, 3).unwrap(), images);
            assert_eq!(c1.decode_lockstep(&codec).unwrap(), images);
        }
    }
}

/// Interleaving pushes/pops of unrelated codecs on one stack must still
/// invert exactly (the property BB-ANS chaining relies on).
#[test]
fn mixed_codec_stack_discipline() {
    let mut rng = Rng::new(0xabcd);
    let buckets = MaxEntropyBuckets::new(10);
    #[derive(Debug)]
    enum Op {
        Cat(Categorical, usize),
        Gauss(DiscretizedGaussian, u32),
    }
    let mut ans = Ans::new(1);
    let mut ops = Vec::new();
    for _ in 0..3000 {
        if rng.f64() < 0.5 {
            let k = 2 + rng.below(40) as usize;
            let pmf: Vec<f64> = (0..k).map(|_| rng.f64() + 1e-9).collect();
            let c = Categorical::from_pmf(&pmf, 16);
            let s = rng.below(k as u64) as usize;
            c.push(&mut ans, s);
            ops.push(Op::Cat(c, s));
        } else {
            let d = DiscretizedGaussian::new(
                buckets.clone(),
                rng.normal() * 3.0,
                0.05 + rng.f64() * 2.0,
                22,
            );
            let s = rng.below(1 << 10) as u32;
            d.push(&mut ans, s);
            ops.push(Op::Gauss(d, s));
        }
    }
    for op in ops.iter().rev() {
        match op {
            Op::Cat(c, s) => assert_eq!(c.pop(&mut ans), *s),
            Op::Gauss(d, s) => assert_eq!(d.pop(&mut ans), *s),
        }
    }
    assert!(ans.is_empty());
}

/// Cross-coder fuzz through the `EntropyCoder` trait: the stack coder and
/// every interleaved lane count must roundtrip the same generated interval
/// tables and symbol sequences, return to the pristine state, and decode
/// symbols in identical (stream) order.
#[test]
fn entropy_coder_cross_coder_roundtrips() {
    fn run_one<C: EntropyCoder>(
        coder: &mut C,
        ivs: &[Interval],
        syms: &[usize],
        prec: u32,
    ) -> Option<Vec<usize>> {
        let seq: Vec<Interval> = syms.iter().map(|&s| ivs[s]).collect();
        coder.encode_all(&seq, prec);
        let decoded = coder.decode_all(syms.len(), prec, |cf| {
            let s = ivs.partition_point(|iv| iv.start <= cf) - 1;
            (s, ivs[s])
        });
        coder.is_pristine().then_some(decoded)
    }

    check_coders(0xC0DE, 48, |cfg, ivs, syms| {
        let from_stack = run_one(&mut Ans::new(0), ivs, syms, cfg.prec);
        let from_l2 = run_one(&mut InterleavedAns::<2>::new(), ivs, syms, cfg.prec);
        let from_l4 = run_one(&mut InterleavedAns::<4>::new(), ivs, syms, cfg.prec);
        let from_l8 = run_one(&mut InterleavedAns::<8>::new(), ivs, syms, cfg.prec);
        let want = Some(syms.to_vec());
        from_stack == want && from_l2 == want && from_l4 == want && from_l8 == want
    });
}

/// The prepared (division-free) encode path must be bit-identical to the
/// division path on both coders, for every random distribution, symbol
/// sequence and precision — the invariant that lets the hot path swap in
/// without bumping any container version (ISSUE 2).
#[test]
fn prepared_encode_bit_identical_to_division_path() {
    fn identical(ivs: &[Interval], syms: &[usize], prec: u32) -> bool {
        let seq: Vec<Interval> = syms.iter().map(|&s| ivs[s]).collect();
        let table = SymbolTable::from_intervals(ivs, prec);
        let mut prep = Vec::new();
        table.gather_into(syms, &mut prep);

        // Stack coder: identical serialized message.
        let mut a = Ans::new(9);
        a.encode_all(&seq, prec);
        let mut b = Ans::new(9);
        b.encode_all_prepared(&prep, prec);
        if a.to_message() != b.to_message() {
            return false;
        }

        // Interleaved coder: identical full state (heads + stream), at a
        // lane count that exercises striping.
        let mut ia = InterleavedAns::<4>::new();
        ia.encode_all(&seq, prec);
        let mut ib = InterleavedAns::<4>::new();
        ib.encode_all_prepared(&prep, prec);
        if ia != ib {
            return false;
        }

        // Per-symbol prepared pushes (the prior/posterior path) match the
        // batched path too.
        let mut c = Ans::new(9);
        for &s in syms.iter().rev() {
            c.push_prepared(&PreparedInterval::new(ivs[s].start, ivs[s].freq, prec));
        }
        c.to_message() == b.to_message()
    }

    check_coders(0x11AD, 40, |cfg, ivs, syms| identical(ivs, syms, cfg.prec));
    // Full precision range 2..=32: reciprocal + renormalization edges.
    check_coders_wide(0xF1DE, 60, |cfg, ivs, syms| identical(ivs, syms, cfg.prec));
}

/// Decode-side LUTs (dense and coarse) must agree with binary search for
/// every cumulative value of every random distribution.
#[test]
fn lut_lookup_agrees_with_binary_search_for_every_cf() {
    let probe_rng = std::cell::RefCell::new(Rng::new(0x10075));
    check_coders(0xC0A5, 40, |cfg, ivs, _syms| {
        // check_coders precisions stay ≤ 24, so 2^prec fits u32.
        let cdf: Vec<u32> = ivs
            .iter()
            .map(|iv| iv.start)
            .chain(std::iter::once(1u32 << cfg.prec))
            .collect();
        let reference = |cf: u32| cdf.partition_point(|&c| c <= cf) - 1;

        let mut luts = vec![DecodeLut::coarse(&cdf, cfg.prec)];
        if cfg.prec <= 16 {
            luts.push(DecodeLut::dense(&cdf, cfg.prec));
        }
        for lut in &luts {
            // Every interval boundary (first/last cf of each symbol)...
            for (s, iv) in ivs.iter().enumerate() {
                if lut.lookup(&cdf, iv.start) != s
                    || lut.lookup(&cdf, iv.start + iv.freq - 1) != s
                {
                    return false;
                }
            }
            // ...plus exhaustive or sampled interior probes.
            if cfg.prec <= 12 {
                for cf in 0..(1u32 << cfg.prec) {
                    if lut.lookup(&cdf, cf) != reference(cf) {
                        return false;
                    }
                }
            } else {
                let mut rng = probe_rng.borrow_mut();
                for _ in 0..4096 {
                    let cf = rng.below(1 << cfg.prec) as u32;
                    if lut.lookup(&cdf, cf) != reference(cf) {
                        return false;
                    }
                }
            }
        }
        true
    });
}

/// Rates through the trait agree across coders up to the fixed per-lane
/// head overhead — interleaving buys parallelism, not rate.
#[test]
fn entropy_coder_rates_agree_across_lane_counts() {
    check_coders(0xBEEF, 16, |cfg, ivs, syms| {
        if syms.is_empty() {
            return true;
        }
        let seq: Vec<Interval> = syms.iter().map(|&s| ivs[s]).collect();
        let mut stack = Ans::new(0);
        stack.encode_all(&seq, cfg.prec);
        let mut lanes = InterleavedAns::<8>::new();
        lanes.encode_all(&seq, cfg.prec);
        let diff = lanes.bit_len() as i64 - EntropyCoder::bit_len(&stack) as i64;
        // 7 extra 64-bit heads, ±1 renormalization word per lane.
        diff.abs() <= 8 * 64 + 8 * 32
    });
}

/// The ANS message after compressing data is incompressible (near-optimal
/// codes look uniformly random): gzip on top must not gain > 2%.
#[test]
fn bbans_output_is_incompressible() {
    let meta = ModelMeta {
        name: "t".into(),
        pixels: 64,
        latent_dim: 8,
        hidden: 16,
        likelihood: Likelihood::Bernoulli,
        test_elbo_bpd: f64::NAN,
    };
    let backend = NativeVae::random(meta, 31);
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let mut rng = Rng::new(32);
    let images: Vec<Vec<u8>> = (0..200)
        .map(|_| (0..64).map(|_| (rng.f64() < 0.3) as u8).collect())
        .collect();
    let (ans, _) = codec.encode_dataset(&images).unwrap();
    let payload = ans.into_message().to_bytes();
    let gz = bbans::baselines::gzip::gzip_compress(&payload, 128);
    assert!(
        gz.len() as f64 > payload.len() as f64 * 0.98,
        "BB-ANS output should be incompressible: {} -> {}",
        payload.len(),
        gz.len()
    );
}

/// Baseline codecs vs adversarial byte patterns (all-zero, all-0xff,
/// single byte, alternating, long runs at buffer boundaries).
#[test]
fn baseline_edge_case_inputs() {
    let cases: Vec<Vec<u8>> = vec![
        vec![],
        vec![0],
        vec![0xff],
        vec![0; 100_000],
        vec![0xaa; 65_536],
        (0..=255u8).collect(),
        (0..70_000u32).map(|i| (i % 2) as u8 * 255).collect(),
        {
            // runs exactly at the 32k window boundary
            let mut v = vec![7u8; 32 * 1024];
            v.extend_from_slice(&[9u8; 300]);
            v.extend_from_slice(&vec![7u8; 32 * 1024]);
            v
        },
    ];
    for (i, data) in cases.iter().enumerate() {
        let d = bbans::baselines::deflate::compress(data, 128);
        assert_eq!(
            bbans::baselines::deflate::decompress(&d).unwrap(),
            *data,
            "deflate case {i}"
        );
        let b = bbans::baselines::bz::compress(data, 16 * 1024);
        assert_eq!(
            bbans::baselines::bz::decompress(&b).unwrap(),
            *data,
            "bz case {i}"
        );
    }
}

/// ANS rate is invariant to clean-seed choice and deterministic given the
/// seed (container reproducibility).
#[test]
fn encode_is_deterministic_given_seed() {
    let meta = ModelMeta {
        name: "t".into(),
        pixels: 36,
        latent_dim: 6,
        hidden: 12,
        likelihood: Likelihood::Bernoulli,
        test_elbo_bpd: f64::NAN,
    };
    let backend = NativeVae::random(meta, 77);
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let mut rng = Rng::new(5);
    let images: Vec<Vec<u8>> = (0..10)
        .map(|_| (0..36).map(|_| (rng.f64() < 0.4) as u8).collect())
        .collect();
    let (a1, _) = codec.encode_dataset(&images).unwrap();
    let (a2, _) = codec.encode_dataset(&images).unwrap();
    assert_eq!(a1.to_message(), a2.to_message());
}
