//! Integration tests for the PJRT runtime against the real AOT artifacts.
//!
//! These self-skip when `make artifacts` has not been run (e.g. fresh
//! checkout); every other suite runs without artifacts.

use bbans::model::{vae::NativeVae, vae::PjrtVae, Backend, Likelihood, ModelMeta, PixelParams};
use bbans::runtime::{artifacts_available, default_artifact_dir, load_config, Engine, Tensor};
use std::sync::Arc;

fn engine_or_skip() -> Option<Arc<Engine>> {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Engine::cpu(&dir).expect("PJRT cpu client")))
}

fn native(name: &str) -> NativeVae {
    let dir = default_artifact_dir();
    let config = load_config(&dir).unwrap();
    let m = config.get("models").unwrap().get(name).unwrap();
    let meta = ModelMeta {
        name: name.to_string(),
        pixels: config.get("pixels").unwrap().as_usize().unwrap(),
        latent_dim: m.get("latent_dim").unwrap().as_usize().unwrap(),
        hidden: m.get("hidden").unwrap().as_usize().unwrap(),
        likelihood: Likelihood::parse(m.get("likelihood").unwrap().as_str().unwrap()).unwrap(),
        test_elbo_bpd: m.get("test_elbo_bpd").unwrap().as_f64().unwrap(),
    };
    let weights = dir.join(m.get("weights").unwrap().as_str().unwrap());
    NativeVae::load(weights, meta).unwrap()
}

#[test]
fn engine_loads_and_runs_bin_encoder() {
    let Some(engine) = engine_or_skip() else { return };
    engine.load("enc_bin_b1.hlo.txt").unwrap();
    let x = Tensor::new(vec![1, 784], vec![0.5; 784]);
    let out = engine.run("enc_bin_b1.hlo.txt", &[x]).unwrap();
    assert_eq!(out.len(), 2, "(mu, sigma)");
    assert_eq!(out[0].dims, vec![1, 40]);
    assert_eq!(out[1].dims, vec![1, 40]);
    assert!(out[1].data.iter().all(|&s| s > 0.0), "sigma must be positive");
}

#[test]
fn pjrt_matches_native_bin() {
    let Some(engine) = engine_or_skip() else { return };
    let config = load_config(default_artifact_dir()).unwrap();
    let pjrt = PjrtVae::from_config(engine, &config, "bin").unwrap();
    let nat = native("bin");

    // A quasi-image: sparse binary pattern.
    let x: Vec<f32> = (0..784).map(|i| ((i * 37 + 11) % 5 == 0) as u32 as f32).collect();
    let pj = pjrt.posterior(&[&x]).unwrap();
    let nv = nat.posterior(&[&x]).unwrap();
    for (a, b) in pj[0].0.iter().zip(nv[0].0.iter()) {
        assert!((a - b).abs() < 1e-3, "mu mismatch {a} vs {b}");
    }
    for (a, b) in pj[0].1.iter().zip(nv[0].1.iter()) {
        assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "sigma mismatch {a} vs {b}");
    }

    // Decoder paths agree too.
    let y: Vec<f32> = (0..40).map(|i| (i as f32 / 40.0) - 0.5).collect();
    let pl = pjrt.likelihood(&[&y]).unwrap();
    let nl = nat.likelihood(&[&y]).unwrap();
    match (&pl[0], &nl[0]) {
        (PixelParams::Bernoulli(a), PixelParams::Bernoulli(b)) => {
            for (x, y) in a.iter().zip(b.iter()) {
                assert!((x - y).abs() < 1e-3, "prob mismatch {x} vs {y}");
            }
        }
        other => panic!("unexpected params {other:?}"),
    }
}

#[test]
fn pjrt_full_decoder_outputs_valid_pmf_table() {
    let Some(engine) = engine_or_skip() else { return };
    let config = load_config(default_artifact_dir()).unwrap();
    let pjrt = PjrtVae::from_config(engine, &config, "full").unwrap();
    let y: Vec<f32> = (0..50).map(|i| ((i as f32) * 0.1).sin() * 0.8).collect();
    let out = pjrt.likelihood(&[&y]).unwrap();
    match &out[0] {
        PixelParams::BetaBinomialTable(table) => {
            assert_eq!(table.len(), 784 * 256);
            // Each row is a PMF: non-negative, sums ~1.
            for px in 0..784 {
                let row = &table[px * 256..(px + 1) * 256];
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-2, "pixel {px} pmf sum {sum}");
                assert!(row.iter().all(|&p| p >= 0.0));
            }
        }
        other => panic!("unexpected params {other:?}"),
    }
}

#[test]
fn pjrt_full_table_matches_native_analytic() {
    let Some(engine) = engine_or_skip() else { return };
    let config = load_config(default_artifact_dir()).unwrap();
    let pjrt = PjrtVae::from_config(engine, &config, "full").unwrap();
    let nat = native("full");
    let y: Vec<f32> = (0..50).map(|i| ((i * 13 % 7) as f32 - 3.0) * 0.2).collect();
    let (table, ab) = (
        pjrt.likelihood(&[&y]).unwrap().remove(0),
        nat.likelihood(&[&y]).unwrap().remove(0),
    );
    let (PixelParams::BetaBinomialTable(t), PixelParams::BetaBinomialAb { alpha, beta }) =
        (table, ab)
    else {
        panic!("unexpected param kinds");
    };
    // Spot-check a few pixels: analytic beta-binomial pmf vs the L1
    // kernel's table.
    for &px in &[0usize, 100, 399, 783] {
        let row = &t[px * 256..(px + 1) * 256];
        for &k in &[0u32, 50, 128, 255] {
            let want = bbans::util::math::beta_binomial_logpmf(
                k,
                255,
                alpha[px] as f64,
                beta[px] as f64,
            )
            .exp();
            let got = row[k as usize] as f64;
            assert!(
                (got - want).abs() < 5e-4 + want * 0.02,
                "pixel {px} k {k}: table {got} vs analytic {want}"
            );
        }
    }
}

#[test]
fn batched_variants_agree_with_b1() {
    let Some(engine) = engine_or_skip() else { return };
    let config = load_config(default_artifact_dir()).unwrap();
    let pjrt = PjrtVae::from_config(engine, &config, "bin").unwrap();
    let imgs: Vec<Vec<f32>> = (0..5)
        .map(|s| (0..784).map(|i| ((i + s * 31) % 3 == 0) as u32 as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    // One batched call (chunks into b4+b1 or b16 padded) ...
    let batched = pjrt.posterior(&refs).unwrap();
    // ... vs one-at-a-time.
    for (i, img) in refs.iter().enumerate() {
        let single = pjrt.posterior(&[img]).unwrap();
        for (a, b) in batched[i].0.iter().zip(single[0].0.iter()) {
            assert!((a - b).abs() < 1e-4, "img {i}: batched {a} vs single {b}");
        }
    }
}
