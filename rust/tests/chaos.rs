//! Chaos campaigns: seeded fault injection against a live server.
//!
//! A [`FaultyBackend`] wraps the registered model and injects panics,
//! error returns, and latency spikes into live NN dispatches, proving
//! the coordinator's containment story end to end:
//!
//! - the model worker survives injected panics (supervised, not dead);
//! - only the faulted round's requests fail — the very next request on
//!   the same connection and model succeeds;
//! - a repeatedly panicking model is quarantined and fast-fails while
//!   healthy-model traffic keeps flowing;
//! - expired-TTL jobs are shed before any NN dispatch;
//! - the PR 7 client retry policy composes: admission rejections are
//!   retried, panic replies are fatal (never re-dispatched);
//! - requests that survive a campaign produce container bytes
//!   bit-identical to a fault-free run.
//!
//! Every fault is armed deterministically (no seeds drawn at test time),
//! so a failure replays exactly. Every test arms a [`Watchdog`]: a
//! supervision deadlock must abort in minutes, not hang CI.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use bbans::bbans::bbc4::Bbc4Container;
use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::coordinator::{Client, ModelService, PageStore, RetryPolicy, Server, ServiceParams};
use bbans::model::{vae::NativeVae, Backend, Likelihood, ModelMeta};
use bbans::util::fault::{DispatchFault, FaultControl, FaultPlan, FaultyBackend};
use bbans::util::rng::Rng;

/// Aborts the process if still armed after `secs` — a hung join is a bug
/// this suite exists to catch, and a hang would otherwise mask it.
struct Watchdog {
    armed: Arc<AtomicBool>,
}

impl Watchdog {
    fn new(secs: u64) -> Watchdog {
        let armed = Arc::new(AtomicBool::new(true));
        let a = armed.clone();
        std::thread::spawn(move || {
            let deadline = Instant::now() + Duration::from_secs(secs);
            while Instant::now() < deadline {
                if !a.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(100));
            }
            eprintln!("chaos watchdog expired after {secs}s — aborting");
            std::process::abort();
        });
        Watchdog { armed }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.armed.store(false, Ordering::Relaxed);
    }
}

fn meta(name: &str) -> ModelMeta {
    ModelMeta {
        name: name.into(),
        pixels: 64,
        latent_dim: 8,
        hidden: 16,
        likelihood: Likelihood::Bernoulli,
        test_elbo_bpd: f64::NAN,
    }
}

const FLAKY_SEED: u64 = 4097;
const TOY_SEED: u64 = 2024;

fn sample_images(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..64).map(|_| (rng.f64() < 0.25) as u8).collect())
        .collect()
}

/// Service with two models: "flaky" (fault-wrapped, driven by the
/// returned controls) and "toy" (wrapped with an empty plan purely so its
/// dispatch counter is observable — it never faults). Returns the
/// service plus the (flaky, toy) fault controls.
fn chaos_service(
    params: ServiceParams,
    plan: FaultPlan,
) -> (ModelService, Arc<FaultControl>, Arc<FaultControl>) {
    let flaky = FaultyBackend::new(NativeVae::random(meta("flaky"), FLAKY_SEED), plan);
    let toy = FaultyBackend::new(NativeVae::random(meta("toy"), TOY_SEED), FaultPlan::new());
    let fctl = flaky.control();
    let tctl = toy.control();
    let svc = ModelService::spawn_with(params, move || {
        let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
        map.insert("flaky".into(), Box::new(flaky));
        map.insert("toy".into(), Box::new(toy));
        Ok(map)
    });
    (svc, fctl, tctl)
}

/// The same two models with no fault wrapper at all — the fault-free
/// reference run for bit-identity assertions.
fn plain_service() -> ModelService {
    ModelService::spawn_with(ServiceParams::default(), move || {
        let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
        map.insert(
            "flaky".into(),
            Box::new(NativeVae::random(meta("flaky"), FLAKY_SEED)),
        );
        map.insert(
            "toy".into(),
            Box::new(NativeVae::random(meta("toy"), TOY_SEED)),
        );
        Ok(map)
    })
}

fn default_params() -> ServiceParams {
    ServiceParams {
        max_jobs: 8,
        max_batch_delay: Duration::from_millis(1),
        ..Default::default()
    }
}

/// The flagship campaign: ≥ 10 injected panics plus error returns and
/// latency spikes, interleaved with clean traffic. The worker survives
/// all of it, only faulted requests fail, and every surviving request's
/// bytes are bit-identical to a fault-free run.
#[test]
fn worker_survives_mixed_campaign_and_survivors_are_bit_identical() {
    let _wd = Watchdog::new(300);
    let (svc, fctl, _tctl) = chaos_service(default_params(), FaultPlan::new());
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let mut client = Client::connect(server.addr).unwrap();

    // (model, images, wire bytes) of every request that survived.
    let mut survivors: Vec<(&str, Vec<Vec<u8>>, Vec<u8>)> = Vec::new();

    for i in 0..10u64 {
        // An injected panic fails the faulted round's request, naming
        // both the containment and the payload.
        fctl.arm(DispatchFault::Panic);
        let err = client
            .compress("flaky", 64, sample_images(2, 1000 + i))
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("internal panic"), "{msg}");
        assert!(msg.contains("injected"), "{msg}");
        assert!(svc.handle().is_alive(), "worker died on panic {i}");

        // Only the faulted round fails: the very next request on the
        // same model and connection succeeds (and resets the
        // supervisor's consecutive-panic count, so 10 spaced panics
        // never trip quarantine).
        let imgs = sample_images(2, 2000 + i);
        let bytes = client.compress("flaky", 64, imgs.clone()).unwrap();
        survivors.push(("flaky", imgs, bytes));

        // Healthy-model traffic is untouched throughout.
        let imgs = sample_images(2, 3000 + i);
        let bytes = client.compress("toy", 64, imgs.clone()).unwrap();
        assert_eq!(client.decompress(bytes.clone()).unwrap(), imgs);
        survivors.push(("toy", imgs, bytes));

        // Mix in the other fault kinds: an error return is an ordinary
        // failure (no unwinding, no panic counted for it) ...
        if i % 3 == 0 {
            fctl.arm(DispatchFault::Error);
            let err = client
                .compress("flaky", 64, sample_images(2, 4000 + i))
                .unwrap_err();
            assert!(format!("{err:#}").contains("injected"), "{err:#}");
        }
        // ... and a latency spike delays but does not corrupt.
        if i % 3 == 1 {
            fctl.arm(DispatchFault::Delay(Duration::from_millis(20)));
            let imgs = sample_images(2, 5000 + i);
            let bytes = client.compress("flaky", 64, imgs.clone()).unwrap();
            survivors.push(("flaky", imgs, bytes));
        }
    }

    assert!(
        svc.metrics.panics.load(Ordering::Relaxed) >= 10,
        "expected >= 10 contained panics, got {}",
        svc.metrics.panics.load(Ordering::Relaxed)
    );
    assert!(
        svc.metrics.quarantined_keys().is_empty(),
        "spaced panics must not quarantine: {:?}",
        svc.metrics.quarantined_keys()
    );

    // The health probe over the wire reflects the carnage and liveness.
    let health = client.health().unwrap();
    let j = bbans::util::json::Json::parse(&health).unwrap();
    assert_eq!(j.get("alive"), Some(&bbans::util::json::Json::Bool(true)));
    assert!(
        j.get("panics").and_then(|v| v.as_u64()).unwrap_or(0) >= 10,
        "{health}"
    );

    server.stop();
    svc.shutdown();

    // Bit-identity: replay every surviving request against a fault-free
    // service; the bytes must match exactly.
    let plain = plain_service();
    let h = plain.handle();
    for (model, imgs, bytes) in &survivors {
        let reference = h.compress(model, imgs.clone()).unwrap();
        assert_eq!(
            &reference, bytes,
            "survivor bytes for model '{model}' differ from the fault-free run"
        );
    }
    plain.shutdown();
}

/// After `quarantine_after` consecutive panicking rounds, the model is
/// quarantined: requests for it fast-fail without touching the backend,
/// while the healthy model keeps serving and the wire health op names
/// the quarantined key.
#[test]
fn quarantined_model_fast_fails_while_healthy_model_serves() {
    let _wd = Watchdog::new(300);
    let params = ServiceParams {
        quarantine_after: 2,
        ..default_params()
    };
    let (svc, fctl, _tctl) = chaos_service(params, FaultPlan::new());
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let mut client = Client::connect(server.addr).unwrap();

    for i in 0..2u64 {
        fctl.arm(DispatchFault::Panic);
        let err = client
            .compress("flaky", 64, sample_images(2, 100 + i))
            .unwrap_err();
        assert!(format!("{err:#}").contains("internal panic"), "{err:#}");
    }

    // Third request: rejected fast, with zero backend dispatches.
    let calls_before = fctl.calls();
    let err = client
        .compress("flaky", 64, sample_images(2, 200))
        .unwrap_err();
    assert!(format!("{err:#}").contains("quarantined"), "{err:#}");
    assert_eq!(
        fctl.calls(),
        calls_before,
        "a quarantined request must never reach the backend"
    );

    // The worker is alive and the healthy model is unaffected.
    assert!(svc.handle().is_alive());
    let imgs = sample_images(3, 300);
    let bytes = client.compress("toy", 64, imgs.clone()).unwrap();
    assert_eq!(client.decompress(bytes).unwrap(), imgs);

    // Health over the wire reports the quarantine.
    let health = client.health().unwrap();
    let j = bbans::util::json::Json::parse(&health).unwrap();
    match j.get("quarantined") {
        Some(bbans::util::json::Json::Arr(keys)) => {
            assert!(
                keys.contains(&bbans::util::json::Json::Str("flaky".into())),
                "{health}"
            );
        }
        other => panic!("quarantined missing or not an array: {other:?}"),
    }
    assert_eq!(j.get("alive"), Some(&bbans::util::json::Json::Bool(true)));

    server.stop();
    svc.shutdown();
}

/// Retry composition, fatal half: a panic reply is a server-side error
/// the retry policy must NOT retry — the request fails after exactly one
/// backend dispatch despite a generous retry budget.
#[test]
fn panic_replies_are_fatal_to_the_retry_policy() {
    let _wd = Watchdog::new(300);
    let (svc, fctl, _tctl) = chaos_service(default_params(), FaultPlan::new());
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let mut client = Client::connect_with(
        server.addr,
        RetryPolicy {
            max_retries: 5,
            base_delay: Duration::from_millis(10),
            ..Default::default()
        },
    )
    .unwrap();

    let calls_before = fctl.calls();
    fctl.arm(DispatchFault::Panic);
    let err = client
        .compress("flaky", 64, sample_images(2, 42))
        .unwrap_err();
    assert!(format!("{err:#}").contains("internal panic"), "{err:#}");
    assert_eq!(
        fctl.calls() - calls_before,
        1,
        "a fatal panic reply must not be re-dispatched by retries"
    );
    assert_eq!(fctl.armed_len(), 0);

    // The connection and service both survive for clean traffic.
    let imgs = sample_images(2, 43);
    let bytes = client.compress("flaky", 64, imgs.clone()).unwrap();
    assert_eq!(client.decompress(bytes).unwrap(), imgs);

    server.stop();
    svc.shutdown();
}

/// Retry composition, transient half: while a latency spike wedges the
/// worker and the 1-slot queue is full, a retrying client's admission
/// rejection ("overloaded") is retried until the queue drains — no
/// caller-visible error.
#[test]
fn overload_during_latency_spike_is_retried_to_success() {
    let _wd = Watchdog::new(300);
    let params = ServiceParams {
        queue_cap: 1,
        ..default_params()
    };
    let (svc, fctl, _tctl) = chaos_service(params, FaultPlan::new());
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let addr = server.addr;

    // Wedge the worker: the next flaky dispatch sleeps 800ms.
    fctl.arm(DispatchFault::Delay(Duration::from_millis(800)));
    let calls_before = fctl.calls();
    let wedge = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.compress("flaky", 64, sample_images(2, 50))
    });
    // Wait until the worker is inside the delayed dispatch (the counter
    // bumps at dispatch entry, before the injected sleep).
    let deadline = Instant::now() + Duration::from_secs(10);
    while fctl.calls() == calls_before {
        assert!(Instant::now() < deadline, "wedge dispatch never started");
        std::thread::sleep(Duration::from_millis(5));
    }

    // Fill the single queue slot while the worker sleeps.
    let occupant = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.compress("toy", 64, sample_images(2, 51))
    });
    while svc.metrics.queue_depth.load(Ordering::Relaxed) < 1 {
        assert!(Instant::now() < deadline, "occupant never queued");
        std::thread::sleep(Duration::from_millis(5));
    }

    // The retrying client is rejected at admission, backs off, and
    // succeeds once the spike passes and the queue drains.
    let imgs = sample_images(2, 52);
    let mut retrying = Client::connect_with(
        addr,
        RetryPolicy {
            max_retries: 20,
            base_delay: Duration::from_millis(25),
            max_delay: Duration::from_millis(500),
            ..Default::default()
        },
    )
    .unwrap();
    let bytes = retrying.compress("toy", 64, imgs.clone()).unwrap();
    assert_eq!(retrying.decompress(bytes).unwrap(), imgs);
    assert!(
        svc.metrics.rejected.load(Ordering::Relaxed) >= 1,
        "the retrying client should have met a full queue at least once"
    );

    assert!(wedge.join().unwrap().is_ok());
    assert!(occupant.join().unwrap().is_ok());
    server.stop();
    svc.shutdown();
}

/// ISSUE 10: a wire transfer dropped mid-way resumes on a fresh
/// connection at the last intact page, and the server's page dispatch
/// counter proves no page is ever sent twice. The page store answers
/// handler-side, so transfers work even while the model worker is wedged
/// in a latency spike.
#[test]
fn dropped_fetch_resumes_at_last_intact_page_without_resending() {
    let _wd = Watchdog::new(300);
    const N_PAGES: u32 = 4;
    let dir = std::env::temp_dir().join(format!("bbans-chaos-fetch-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let backend = NativeVae::random(meta("toy"), TOY_SEED);
    let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
    let imgs = sample_images(8, 70);
    let bytes = Bbc4Container::encode_vae(&codec, &imgs, N_PAGES as usize)
        .unwrap()
        .to_bytes();
    std::fs::write(dir.join("data.bbc4"), &bytes).unwrap();

    let (svc, fctl, _tctl) = chaos_service(default_params(), FaultPlan::new());
    let store = Arc::new(PageStore::new(dir.clone()));
    let server =
        Server::start_with_store("127.0.0.1:0", svc.handle(), None, Some(store.clone())).unwrap();
    let addr = server.addr;

    // Wedge the model worker: page serving must not care (handler-side).
    fctl.arm(DispatchFault::Delay(Duration::from_millis(800)));
    let wedge = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.compress("flaky", 64, sample_images(2, 71))
    });

    // First transfer: two single-page ranges, then the connection drops
    // (client goes out of scope mid-transfer).
    let mut local: Vec<u8> = Vec::new();
    {
        let mut c1 = Client::connect(addr).unwrap();
        let r0 = c1.fetch_pages("data.bbc4", 0, 1).unwrap();
        assert_eq!(r0.n_pages, N_PAGES);
        assert!(!r0.header.is_empty() && r0.trailer.is_empty());
        local.extend_from_slice(&r0.header);
        local.extend_from_slice(&r0.pages[0].bytes);
        let r1 = c1.fetch_pages("data.bbc4", 1, 1).unwrap();
        assert!(r1.header.is_empty(), "header rides only on the first range");
        local.extend_from_slice(&r1.pages[0].bytes);
    }
    assert_eq!(store.pages_served(), 2);

    // The partial file scans to exactly the intact prefix.
    let (shell, prefix) = Bbc4Container::scan_prefix(&local).unwrap();
    assert_eq!(shell.n_pages, N_PAGES);
    assert_eq!(prefix.pages, 2);
    assert!(!prefix.complete);
    assert_eq!(prefix.keep, local.len());

    // Resume on a NEW connection at the first missing page.
    let mut c2 = Client::connect(addr).unwrap();
    let mut from = prefix.pages;
    loop {
        let r = c2.fetch_pages("data.bbc4", from, 1).unwrap();
        local.extend_from_slice(&r.pages[0].bytes);
        from += 1;
        if from == r.n_pages {
            assert!(!r.trailer.is_empty(), "trailer rides on the last range");
            local.extend_from_slice(&r.trailer);
            break;
        }
        assert!(r.trailer.is_empty());
    }

    // Byte-identical assembly, strict-valid, and decodable.
    assert_eq!(local, bytes, "assembled transfer must equal the source file");
    let (_, done) = Bbc4Container::scan_prefix(&local).unwrap();
    assert!(done.complete);
    let decoded: Vec<Vec<u8>> = Bbc4Container::from_bytes(&local)
        .unwrap()
        .decode_slots_vae(&codec)
        .unwrap()
        .into_iter()
        .map(Option::unwrap)
        .collect();
    assert_eq!(decoded, imgs);

    // The dispatch counter proves no page was ever sent twice across the
    // dropped and resumed connections.
    assert_eq!(store.pages_served(), N_PAGES as u64, "a page was re-sent");

    // Path traversal and unknown names are clean errors, not file reads.
    assert!(c2.fetch_pages("../data.bbc4", 0, 1).is_err());
    assert!(c2.fetch_pages("no-such.bbc4", 0, 1).is_err());
    // Out-of-range resume point is rejected server-side.
    assert!(c2.fetch_pages("data.bbc4", N_PAGES, 1).is_err());

    assert!(wedge.join().unwrap().is_ok());
    server.stop();
    svc.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// TTL shedding under chaos: a job whose deadline passes while the
/// worker is wedged in another model's latency spike is shed at round
/// formation — its model's backend sees zero dispatches for it.
#[test]
fn expired_job_is_shed_before_any_nn_dispatch() {
    let _wd = Watchdog::new(300);
    let (svc, fctl, tctl) = chaos_service(default_params(), FaultPlan::new());
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let addr = server.addr;

    // Wedge the worker in a 1s flaky dispatch.
    fctl.arm(DispatchFault::Delay(Duration::from_millis(1000)));
    let calls_before = fctl.calls();
    let wedge = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.compress("flaky", 64, sample_images(2, 60))
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    while fctl.calls() == calls_before {
        assert!(Instant::now() < deadline, "wedge dispatch never started");
        std::thread::sleep(Duration::from_millis(5));
    }

    // A TTL'd toy request queues behind the spike; its 50ms budget is
    // long gone when the worker forms the next round.
    let toy_calls_before = tctl.calls();
    let shed = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.compress_with_ttl("toy", 64, sample_images(2, 61), Some(50))
    });

    let err = shed.join().unwrap().unwrap_err();
    assert!(
        format!("{err:#}").contains("deadline exceeded"),
        "{err:#}"
    );
    assert_eq!(
        tctl.calls(),
        toy_calls_before,
        "a shed job must never reach the NN"
    );
    assert_eq!(svc.metrics.expired.load(Ordering::Relaxed), 1);

    // The wedged request itself survives its spike.
    assert!(wedge.join().unwrap().is_ok());
    assert!(svc.handle().is_alive());
    server.stop();
    svc.shutdown();
}
