//! End-to-end server tests: framed TCP → batcher → BB-ANS → back.
//! Runs against a NativeVae::random toy model (no artifacts needed);
//! artifact-backed serving is exercised by `examples/serve_demo.rs`.
//!
//! Every test arms a [`Watchdog`] so a shutdown/join regression aborts
//! the process instead of hanging `cargo test` until the CI timeout.

use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use bbans::bbans::container::HierContainer;
use bbans::bbans::hierarchy::{HierCodec, Schedule};
use bbans::bbans::BbAnsConfig;
use bbans::coordinator::protocol::{Frame, HierSpec};
use bbans::coordinator::{Client, ModelService, RetryPolicy, Server, ServiceParams};
use bbans::model::hierarchy::{HierMeta, HierVae};
use bbans::model::{vae::NativeVae, Backend, Likelihood, ModelMeta};
use bbans::util::rng::Rng;

/// Aborts the process if still armed after `secs` — a hung join is a bug
/// this suite exists to catch, and a hang would otherwise mask it.
struct Watchdog {
    armed: Arc<AtomicBool>,
}

impl Watchdog {
    fn new(secs: u64) -> Watchdog {
        let armed = Arc::new(AtomicBool::new(true));
        let a = armed.clone();
        std::thread::spawn(move || {
            let deadline = Instant::now() + Duration::from_secs(secs);
            while Instant::now() < deadline {
                if !a.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(100));
            }
            eprintln!("server_e2e watchdog expired after {secs}s — aborting");
            std::process::abort();
        });
        Watchdog { armed }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.armed.store(false, Ordering::Relaxed);
    }
}

fn toy_map() -> HashMap<String, Box<dyn Backend>> {
    let meta = ModelMeta {
        name: "toy".into(),
        pixels: 64,
        latent_dim: 8,
        hidden: 16,
        likelihood: Likelihood::Bernoulli,
        test_elbo_bpd: f64::NAN,
    };
    let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
    map.insert("toy".into(), Box::new(NativeVae::random(meta, 2024)));
    map
}

fn toy_service() -> ModelService {
    let params = ServiceParams {
        max_jobs: 8,
        max_batch_delay: Duration::from_millis(10),
        ..Default::default()
    };
    ModelService::spawn_with(params, || Ok(toy_map()))
}

fn sample_images(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..64).map(|_| (rng.f64() < 0.25) as u8).collect())
        .collect()
}

#[test]
fn tcp_compress_decompress_roundtrip() {
    let _wd = Watchdog::new(120);
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let addr = server.addr;

    let mut client = Client::connect(addr).unwrap();
    let images = sample_images(9, 5);
    let container = client.compress("toy", 64, images.clone()).unwrap();
    assert!(!container.is_empty());
    let out = client.decompress(container).unwrap();
    assert_eq!(out, images);

    let stats = client.stats().unwrap();
    let json = bbans::util::json::Json::parse(&stats).unwrap();
    assert_eq!(json.get("images_encoded").unwrap().as_u64(), Some(9));
    assert_eq!(json.get("images_decoded").unwrap().as_u64(), Some(9));

    server.stop();
    svc.shutdown();
}

#[test]
fn many_concurrent_clients_roundtrip_and_batch() {
    let _wd = Watchdog::new(120);
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let addr = server.addr;

    let mut handles = Vec::new();
    for t in 0..8u64 {
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            let images = sample_images(6, 100 + t);
            let c = client.compress("toy", 64, images.clone()).unwrap();
            let out = client.decompress(c).unwrap();
            assert_eq!(out, images);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // Cross-stream batching must have happened with 8 concurrent clients.
    let mbs = svc.metrics.mean_batch_size();
    assert!(mbs > 1.3, "expected batched NN dispatches, got {mbs:.2}");

    server.stop();
    svc.shutdown();
}

#[test]
fn server_reports_errors_cleanly() {
    let _wd = Watchdog::new(120);
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let mut client = Client::connect(server.addr).unwrap();

    // Unknown model.
    let err = client
        .compress("missing", 64, sample_images(1, 1))
        .unwrap_err();
    assert!(err.to_string().contains("unknown model"), "{err}");

    // Garbage container.
    let err = client.decompress(vec![0xde, 0xad]).unwrap_err();
    assert!(err.to_string().contains("bad container"), "{err}");

    // Connection still usable afterwards.
    let images = sample_images(2, 2);
    let c = client.compress("toy", 64, images.clone()).unwrap();
    assert_eq!(client.decompress(c).unwrap(), images);

    server.stop();
    svc.shutdown();
}

#[test]
fn stop_joins_live_connections() {
    let _wd = Watchdog::new(120);
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();

    // A connection that stays open and idle across the shutdown.
    let mut client = Client::connect(server.addr).unwrap();
    client.stats().unwrap();

    // `stop` must join the handler serving `client` (it polls the stop
    // flag between reads) instead of leaking it and returning early.
    server.stop();

    // The handler exited and closed the socket, so the next call fails.
    assert!(client.stats().is_err());
    svc.shutdown();
}

fn raw_roundtrip(addr: SocketAddr, payload: &[u8]) -> Frame {
    let mut s = TcpStream::connect(addr).unwrap();
    let mut msg = (payload.len() as u32).to_le_bytes().to_vec();
    msg.extend_from_slice(payload);
    s.write_all(&msg).unwrap();
    s.flush().unwrap();
    Frame::read_from(&mut s).unwrap()
}

fn assert_error_contains(f: &Frame, needle: &str) {
    match f {
        Frame::Error { message } => assert!(message.contains(needle), "{message}"),
        other => panic!("expected Error frame, got {other:?}"),
    }
}

#[test]
fn malformed_frames_get_error_reply_and_count() {
    let _wd = Watchdog::new(120);
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let addr = server.addr;

    // Unknown frame type: answered with Error, not silently dropped.
    let reply = raw_roundtrip(addr, &[0xee, 1, 2, 3]);
    assert_error_contains(&reply, "protocol error");
    assert_error_contains(&reply, "unknown frame type");

    // Zero-pixel image grid: a 13-byte frame must not demand n image
    // allocations (regression for the `pixels == 0, n > 0` admission bug).
    let mut p = vec![0x01, 3];
    p.extend_from_slice(b"toy");
    p.extend_from_slice(&0u32.to_le_bytes());
    p.extend_from_slice(&4u32.to_le_bytes());
    let reply = raw_roundtrip(addr, &p);
    assert_error_contains(&reply, "zero-pixel");

    // Truncated frame: the length prefix promises more than the peer
    // sends. Must be told apart from a clean close between frames.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&100u32.to_le_bytes()).unwrap();
    s.write_all(&[0x01; 10]).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let reply = Frame::read_from(&mut s).unwrap();
    assert_error_contains(&reply, "peer closed");

    assert_eq!(svc.metrics.protocol_errors.load(Ordering::Relaxed), 3);

    // Clean closes (each `Client` drop above) were NOT counted as
    // protocol errors, and a well-formed connection still works.
    let mut client = Client::connect(addr).unwrap();
    let images = sample_images(2, 3);
    let c = client.compress("toy", 64, images.clone()).unwrap();
    assert_eq!(client.decompress(c).unwrap(), images);

    server.stop();
    svc.shutdown();
}

#[test]
fn overload_rejected_over_tcp() {
    let _wd = Watchdog::new(120);
    // Gate the backend factory so the worker cannot drain the queue yet.
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let params = ServiceParams {
        max_jobs: 8,
        max_batch_delay: Duration::from_millis(1),
        queue_cap: 1,
        ..Default::default()
    };
    let svc = ModelService::spawn_with(params, move || {
        gate_rx.recv().ok();
        Ok(toy_map())
    });
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let addr = server.addr;

    // The first request occupies the only queue slot.
    let occupant = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.compress("toy", 64, sample_images(2, 7))
    });
    while svc.metrics.queue_depth.load(Ordering::Relaxed) < 1 {
        std::thread::sleep(Duration::from_millis(5));
    }

    // Queue full → the next request is rejected at admission, over TCP,
    // instead of stalling the connection.
    let mut c2 = Client::connect(addr).unwrap();
    let err = c2.compress("toy", 64, sample_images(2, 8)).unwrap_err();
    assert!(err.to_string().contains("overloaded"), "{err}");
    assert!(svc.metrics.rejected.load(Ordering::Relaxed) >= 1);

    // Release the gate: the admitted request drains and succeeds.
    gate_tx.send(()).unwrap();
    let out = occupant.join().unwrap();
    assert!(out.is_ok(), "{out:?}");

    server.stop();
    svc.shutdown();
}

/// Satellite: a client with a retry policy rides out an overloaded server.
/// The first attempt is rejected at admission ("overloaded"); the backoff
/// retries land after the queue drains and the request succeeds — no
/// caller-visible error despite the transient rejection.
#[test]
fn overloaded_then_drained_request_succeeds_with_retry() {
    let _wd = Watchdog::new(120);
    // Gate the backend factory so the worker cannot drain the queue yet.
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let params = ServiceParams {
        max_jobs: 8,
        max_batch_delay: Duration::from_millis(1),
        queue_cap: 1,
        ..Default::default()
    };
    let svc = ModelService::spawn_with(params, move || {
        gate_rx.recv().ok();
        Ok(toy_map())
    });
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let addr = server.addr;

    // The first request occupies the only queue slot.
    let occupant = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.compress("toy", 64, sample_images(2, 7))
    });
    while svc.metrics.queue_depth.load(Ordering::Relaxed) < 1 {
        std::thread::sleep(Duration::from_millis(5));
    }

    // Release the gate as soon as the retrying client has been rejected
    // at least once, so its backoff retries meet a drained queue.
    let rejected = {
        let metrics = svc.metrics.clone();
        std::thread::spawn(move || {
            while metrics.rejected.load(Ordering::Relaxed) < 1 {
                std::thread::sleep(Duration::from_millis(5));
            }
            gate_tx.send(()).unwrap();
        })
    };

    let images = sample_images(2, 8);
    let mut c2 = Client::connect_with(
        addr,
        RetryPolicy {
            max_retries: 10,
            base_delay: Duration::from_millis(20),
            max_delay: Duration::from_millis(500),
            ..Default::default()
        },
    )
    .unwrap();
    let container = c2.compress("toy", 64, images.clone()).unwrap();
    assert_eq!(c2.decompress(container).unwrap(), images);

    // The success came *after* at least one admission rejection — the
    // retry path, not a lucky first attempt.
    assert!(svc.metrics.rejected.load(Ordering::Relaxed) >= 1);
    rejected.join().unwrap();
    assert!(occupant.join().unwrap().is_ok());

    server.stop();
    svc.shutdown();
}

/// Health is a distinct wire op (0x07), served handle-side: it answers
/// with liveness, queue depth, and the (empty) quarantine set without
/// touching the admission queue.
#[test]
fn health_probe_over_tcp() {
    let _wd = Watchdog::new(120);
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let mut client = Client::connect(server.addr).unwrap();

    let health = client.health().unwrap();
    let j = bbans::util::json::Json::parse(&health).unwrap();
    assert_eq!(
        j.get("alive"),
        Some(&bbans::util::json::Json::Bool(true)),
        "{health}"
    );
    match j.get("quarantined") {
        Some(bbans::util::json::Json::Arr(keys)) => assert!(keys.is_empty(), "{health}"),
        other => panic!("quarantined missing or not an array: {other:?}"),
    }
    assert!(j.get("queue_depth").is_some(), "{health}");

    // The same connection still serves data traffic.
    let images = sample_images(2, 17);
    let c = client.compress("toy", 64, images.clone()).unwrap();
    assert_eq!(client.decompress(c).unwrap(), images);

    server.stop();
    svc.shutdown();
}

/// A wire TTL (v2 request encoding) is honoured server-side: a request
/// whose deadline passes while queued is shed before any NN dispatch and
/// answered "deadline exceeded", while an un-TTL'd request in the same
/// round succeeds.
#[test]
fn wire_ttl_expires_queued_request() {
    let _wd = Watchdog::new(120);
    // Gate the backend factory so both requests sit queued past the TTL.
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let params = ServiceParams {
        max_jobs: 8,
        max_batch_delay: Duration::from_millis(1),
        ..Default::default()
    };
    let svc = ModelService::spawn_with(params, move || {
        gate_rx.recv().ok();
        Ok(toy_map())
    });
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let addr = server.addr;

    let short = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.compress_with_ttl("toy", 64, sample_images(2, 31), Some(10))
    });
    let long = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.compress("toy", 64, sample_images(2, 32))
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    while svc.metrics.queue_depth.load(Ordering::Relaxed) < 2 {
        assert!(Instant::now() < deadline, "jobs never queued");
        std::thread::sleep(Duration::from_millis(5));
    }
    std::thread::sleep(Duration::from_millis(30));
    gate_tx.send(()).unwrap();

    let err = short.join().unwrap().unwrap_err();
    assert!(err.to_string().contains("deadline exceeded"), "{err}");
    assert!(long.join().unwrap().is_ok());
    assert_eq!(svc.metrics.expired.load(Ordering::Relaxed), 1);

    server.stop();
    svc.shutdown();
}

/// A wire drain request closes the accept loop, lets in-flight
/// connections finish, and reports a clean drain once peers hang up.
#[test]
fn drain_finishes_in_flight_work_then_reports_clean() {
    let _wd = Watchdog::new(120);
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let addr = server.addr;

    let mut client = Client::connect(addr).unwrap();
    let images = sample_images(4, 21);
    let container = client.compress("toy", 64, images.clone()).unwrap();

    // A second client requests a drain over the wire.
    let mut ctl = Client::connect(addr).unwrap();
    ctl.shutdown_server().unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while !server.drain_requested() {
        assert!(Instant::now() < deadline, "drain flag never raised");
        std::thread::sleep(Duration::from_millis(5));
    }

    // Drain is not stop: the established connection still serves.
    assert_eq!(client.decompress(container).unwrap(), images);
    drop(client);
    drop(ctl);

    assert!(
        server.drain(Duration::from_secs(30)),
        "expected a clean drain after peers hung up"
    );
    svc.shutdown();
}

/// An idle peer that never hangs up cannot wedge a drain: the deadline
/// forces the stop flag and the handler is joined anyway.
#[test]
fn drain_deadline_forces_stop_on_idle_peer() {
    let _wd = Watchdog::new(120);
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    client.stats().unwrap();

    assert!(
        !server.drain(Duration::from_millis(200)),
        "idle peer should make the drain unclean"
    );
    // The straggler's handler was stopped; its socket is closed.
    assert!(client.stats().is_err());
    svc.shutdown();
}

/// Tentpole e2e (ISSUE 9): a traced request over TCP leaves a span tree
/// covering admission → queue wait → round → NN phase → ANS phase →
/// reply, retrievable through the `TraceReq` wire op on the same
/// connection — and tracing changes zero payload bytes. The Prometheus
/// listener serves a well-formed text-format scrape over plain HTTP.
#[test]
fn trace_and_metrics_exposition_over_tcp() {
    let _wd = Watchdog::new(120);
    bbans::obs::tracer().set_enabled(true);
    let svc = toy_service();
    let server =
        Server::start_with_metrics("127.0.0.1:0", svc.handle(), Some("127.0.0.1:0")).unwrap();
    let metrics_addr = server.metrics_addr.expect("metrics listener requested");

    let mut client = Client::connect(server.addr).unwrap();
    let images = sample_images(5, 77);
    // An explicit client-supplied trace id, far above the auto-assign
    // counter so concurrent tests in this process cannot collide with it.
    let trace_id = 0xE2E_0001u64;
    let traced = client
        .compress_with_opts("toy", 64, images.clone(), None, Some(trace_id))
        .unwrap();
    let untraced = client.compress("toy", 64, images.clone()).unwrap();
    assert_eq!(traced, untraced, "tracing must not move payload bytes");
    assert_eq!(
        client
            .decompress_with_opts(traced, None, Some(trace_id + 1))
            .unwrap(),
        images
    );

    // TraceReq on the same connection: the span tree for our request.
    let json = client.trace(64).unwrap();
    let j = bbans::util::json::Json::parse(&json).unwrap();
    let traces = j.get("traces").unwrap().as_arr().unwrap();
    let ours = traces
        .iter()
        .find(|t| t.get("trace").and_then(bbans::util::json::Json::as_u64) == Some(trace_id))
        .unwrap_or_else(|| panic!("trace {trace_id} missing from snapshot: {json}"));
    let names: Vec<&str> = ours
        .get("spans")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|s| s.get("name").and_then(bbans::util::json::Json::as_str))
        .collect();
    for need in ["admission", "queue", "nn", "ans", "round", "reply", "request"] {
        assert!(names.contains(&need), "span '{need}' missing from {names:?}");
    }

    // Prometheus scrape: plain HTTP GET against the side listener.
    let mut s = TcpStream::connect(metrics_addr).unwrap();
    s.write_all(b"GET /metrics HTTP/1.0\r\nHost: test\r\n\r\n")
        .unwrap();
    s.flush().unwrap();
    let mut reply = String::new();
    use std::io::Read as _;
    s.read_to_string(&mut reply).unwrap();
    assert!(reply.starts_with("HTTP/1.0 200 OK\r\n"), "{reply}");
    assert!(
        reply.contains("Content-Type: text/plain; version=0.0.4; charset=utf-8"),
        "{reply}"
    );
    let body = reply.split("\r\n\r\n").nth(1).expect("header/body split");
    for line in body.lines() {
        assert_prometheus_line_ok(line);
    }
    for metric in [
        "bbans_requests_total",
        "bbans_images_encoded_total",
        "bbans_images_decoded_total",
        "bbans_request_latency_us_bucket",
        "bbans_trace_spans_recorded_total",
        "bbans_build_info",
    ] {
        assert!(body.contains(metric), "scrape missing {metric}:\n{body}");
    }

    server.stop();
    svc.shutdown();
}

/// Lint one line of Prometheus text exposition format: a comment
/// (`# HELP` / `# TYPE`), or `name[{labels}] value` with a bare metric
/// name and a float-parsable value.
fn assert_prometheus_line_ok(line: &str) {
    if line.is_empty() || line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
        return;
    }
    let (name_part, value) = line
        .rsplit_once(' ')
        .unwrap_or_else(|| panic!("no value on exposition line: {line:?}"));
    let metric = name_part.split('{').next().unwrap();
    assert!(
        !metric.is_empty()
            && !metric.starts_with(|c: char| c.is_ascii_digit())
            && metric
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
        "bad metric name on exposition line: {line:?}"
    );
    let labels = &name_part[metric.len()..];
    assert!(
        labels.is_empty() || (labels.starts_with('{') && labels.ends_with('}')),
        "bad label set on exposition line: {line:?}"
    );
    assert!(
        value.parse::<f64>().is_ok(),
        "bad sample value on exposition line: {line:?}"
    );
}

/// Acceptance (ISSUE 9): for BOTH schedules, the wire bytes of a
/// hierarchical compress match an offline *ledgered* encode bit-for-bit,
/// the ledger's ELBO decomposition telescopes (residual < 1e-6), and the
/// Bit-Swap chain-startup cost undercuts naive's.
#[test]
fn hier_wire_bytes_match_ledgered_encode_for_both_schedules() {
    let _wd = Watchdog::new(120);
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let mut client = Client::connect(server.addr).unwrap();

    let images = sample_images(6, 13);
    let mut initial = [0.0f64; 2];
    for (i, schedule) in [Schedule::Naive, Schedule::BitSwap].into_iter().enumerate() {
        let spec = HierSpec {
            schedule,
            likelihood: Likelihood::Bernoulli,
            dims: vec![16, 12],
            hidden: 12,
            seed: 979,
            chunks: 2,
        };
        let bytes = client.compress_hier(spec, 64, images.clone()).unwrap();

        let meta = HierMeta {
            name: "hier2".into(),
            pixels: 64,
            dims: vec![16, 12],
            hidden: 12,
            likelihood: Likelihood::Bernoulli,
        };
        let backend = HierVae::random(meta, 979);
        let codec = HierCodec::new(&backend, BbAnsConfig::default(), schedule).unwrap();
        let (reference, ledger) = HierContainer::encode_with_ledger(&codec, &images, 2).unwrap();
        assert_eq!(
            bytes,
            reference.to_bytes(),
            "{schedule:?}: serving bytes must match the ledgered offline encode"
        );
        let s = ledger.summary(64);
        assert!(
            s.max_residual < 1e-6,
            "{schedule:?}: ledger must decompose (residual {} bits)",
            s.max_residual
        );
        initial[i] = s.initial_bits;

        assert_eq!(client.decompress(bytes).unwrap(), images);
    }
    assert!(
        initial[1] < initial[0],
        "bitswap initial bits {} must undercut naive {}",
        initial[1],
        initial[0]
    );

    server.stop();
    svc.shutdown();
}

/// Satellite (ISSUE 10): combined probes ride ONE connection. The CLI's
/// `client --trace --metrics` used to open a probe path that could land
/// on a fresh connection; the snapshot then raced the request it was
/// meant to observe. Regression: a traced request followed by the trace
/// and metrics probes on the *same* `Client` sees the request's spans,
/// and the client performed zero reconnects along the way.
#[test]
fn trace_and_metrics_probes_share_the_request_connection() {
    let _wd = Watchdog::new(120);
    bbans::obs::tracer().set_enabled(true);
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();

    let mut client = Client::connect(server.addr).unwrap();
    let images = sample_images(3, 41);
    let trace_id = 0xE2E_0010u64;
    let container = client
        .compress_with_opts("toy", 64, images.clone(), None, Some(trace_id))
        .unwrap();
    assert_eq!(client.decompress(container).unwrap(), images);

    // Probe 1: the trace snapshot, on the request's connection, must
    // contain the span tree of the request just served.
    let json = client.trace(64).unwrap();
    let j = bbans::util::json::Json::parse(&json).unwrap();
    let traces = j.get("traces").unwrap().as_arr().unwrap();
    assert!(
        traces
            .iter()
            .any(|t| t.get("trace").and_then(bbans::util::json::Json::as_u64) == Some(trace_id)),
        "trace {trace_id} missing from same-connection snapshot: {json}"
    );

    // Probe 2: the metrics snapshot, still on the same connection, has
    // already counted our request.
    let text = client.metrics_text().unwrap();
    assert!(text.contains("bbans_requests_total"), "{text}");
    assert!(text.contains("bbans_images_encoded_total"), "{text}");

    // The whole sequence — request, decompress, trace probe, metrics
    // probe — reused the single original connection.
    assert_eq!(
        client.reconnects(),
        0,
        "probes must not force a reconnect away from the request connection"
    );

    server.stop();
    svc.shutdown();
}

#[test]
fn compress_hier_roundtrips_over_tcp() {
    let _wd = Watchdog::new(120);
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let mut client = Client::connect(server.addr).unwrap();

    let images = sample_images(6, 11);
    let spec = HierSpec {
        schedule: Schedule::BitSwap,
        likelihood: Likelihood::Bernoulli,
        dims: vec![6, 4],
        hidden: 12,
        seed: 4242,
        chunks: 2,
    };
    let bytes = client.compress_hier(spec, 64, images.clone()).unwrap();

    // Wire bytes match the offline encoder exactly: the serving path may
    // not perturb the container format.
    let meta = HierMeta {
        name: "hier2".into(),
        pixels: 64,
        dims: vec![6, 4],
        hidden: 12,
        likelihood: Likelihood::Bernoulli,
    };
    let backend = HierVae::random(meta, 4242);
    let codec = HierCodec::new(&backend, BbAnsConfig::default(), Schedule::BitSwap).unwrap();
    let reference = HierContainer::encode_with_workers(&codec, &images, 2, 1)
        .unwrap()
        .to_bytes();
    assert_eq!(bytes, reference);

    // The same connection decodes it back (BBC3 is self-describing, so
    // no pre-registered model is needed).
    let out = client.decompress(bytes).unwrap();
    assert_eq!(out, images);

    server.stop();
    svc.shutdown();
}
