//! End-to-end server tests: framed TCP → batcher → BB-ANS → back.
//! Runs against a NativeVae::random toy model (no artifacts needed);
//! artifact-backed serving is exercised by `examples/serve_demo.rs`.

use std::collections::HashMap;
use std::time::Duration;

use bbans::coordinator::{Client, ModelService, Server, ServiceParams};
use bbans::model::{vae::NativeVae, Backend, Likelihood, ModelMeta};
use bbans::util::rng::Rng;

fn toy_service() -> ModelService {
    let params = ServiceParams {
        max_jobs: 8,
        batch_window: Duration::from_millis(10),
        ..Default::default()
    };
    ModelService::spawn_with(params, || {
        let meta = ModelMeta {
            name: "toy".into(),
            pixels: 64,
            latent_dim: 8,
            hidden: 16,
            likelihood: Likelihood::Bernoulli,
            test_elbo_bpd: f64::NAN,
        };
        let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
        map.insert("toy".into(), Box::new(NativeVae::random(meta, 2024)));
        Ok(map)
    })
}

fn sample_images(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..64).map(|_| (rng.f64() < 0.25) as u8).collect())
        .collect()
}

#[test]
fn tcp_compress_decompress_roundtrip() {
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let addr = server.addr;

    let mut client = Client::connect(addr).unwrap();
    let images = sample_images(9, 5);
    let container = client.compress("toy", 64, images.clone()).unwrap();
    assert!(!container.is_empty());
    let out = client.decompress(container).unwrap();
    assert_eq!(out, images);

    let stats = client.stats().unwrap();
    let json = bbans::util::json::Json::parse(&stats).unwrap();
    assert_eq!(json.get("images_encoded").unwrap().as_u64(), Some(9));
    assert_eq!(json.get("images_decoded").unwrap().as_u64(), Some(9));

    server.stop();
    svc.shutdown();
}

#[test]
fn many_concurrent_clients_roundtrip_and_batch() {
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let addr = server.addr;

    let mut handles = Vec::new();
    for t in 0..8u64 {
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            let images = sample_images(6, 100 + t);
            let c = client.compress("toy", 64, images.clone()).unwrap();
            let out = client.decompress(c).unwrap();
            assert_eq!(out, images);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // Cross-stream batching must have happened with 8 concurrent clients.
    let mbs = svc.metrics.mean_batch_size();
    assert!(mbs > 1.3, "expected batched NN dispatches, got {mbs:.2}");

    server.stop();
    svc.shutdown();
}

#[test]
fn server_reports_errors_cleanly() {
    let svc = toy_service();
    let server = Server::start("127.0.0.1:0", svc.handle()).unwrap();
    let mut client = Client::connect(server.addr).unwrap();

    // Unknown model.
    let err = client
        .compress("missing", 64, sample_images(1, 1))
        .unwrap_err();
    assert!(err.to_string().contains("unknown model"), "{err}");

    // Garbage container.
    let err = client.decompress(vec![0xde, 0xad]).unwrap_err();
    assert!(err.to_string().contains("bad container"), "{err}");

    // Connection still usable afterwards.
    let images = sample_images(2, 2);
    let c = client.compress("toy", 64, images.clone()).unwrap();
    assert_eq!(client.decompress(c).unwrap(), images);

    server.stop();
    svc.shutdown();
}
