//! # BB-ANS: lossless compression with latent variable models
//!
//! A reproduction of *"Practical lossless compression with latent variables
//! using bits back coding"* (Townsend, Bird & Barber, ICLR 2019) as a
//! three-layer system:
//!
//! * **Layer 1 (Pallas, build time)** — fused dense and beta-binomial-table
//!   kernels inside the VAE graphs (`python/compile/kernels/`).
//! * **Layer 2 (JAX, build time)** — the VAE recognition/generative
//!   networks, trained and AOT-lowered to HLO text (`python/compile/`).
//! * **Layer 3 (this crate, runtime)** — the BB-ANS codec ([`ans`],
//!   [`codecs`], [`bbans`]), the PJRT runtime bridge ([`runtime`]), a
//!   pure-Rust model backend ([`model`]), from-scratch baseline codecs
//!   ([`baselines`]), a batching compression server ([`coordinator`]), an
//!   observability layer ([`obs`]: request tracing, the bits-back rate
//!   ledger, Prometheus exposition), and the data pipeline ([`data`]).
//!
//! Python never runs on the request path: `make artifacts` trains and
//! lowers the models once; the `bbans` binary is self-contained after that.
//!
//! See `DESIGN.md` for the system inventory and per-experiment index, and
//! `EXPERIMENTS.md` for measured paper-vs-reproduction results.

pub mod ans;
pub mod baselines;
pub mod bbans;
pub mod bench;
pub mod codecs;
pub mod coordinator;
pub mod data;
pub mod format;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod simd;
pub mod util;
