//! Recursive bits-back coding over hierarchical latents — the codec half
//! of the Bit-Swap subsystem (Kingma et al. 2019).
//!
//! Two coding schedules over the same Markov top-down model (see
//! [`crate::model::hierarchy`]); both are exact and both reach the same
//! asymptotic rate (the hierarchy's −ELBO), but they differ sharply in the
//! **initial bits** a fresh chain must borrow:
//!
//! ```text
//! naive BB-ANS (pop everything, then push everything):
//!   pop z_0…z_{L-1}  →  push x | push p(z_0|z_1) … push p(z_{L-1})
//!   initial bits ≈ Σ_l H(q_l)           — grows with depth L
//!
//! Bit-Swap (interleave pop/push layer by layer):
//!   pop z_0 | push x | pop z_1 | push z_0 | … | pop z_{L-1} |
//!   push z_{L-2} | push z_{L-1}
//!   initial bits ≈ H(q_0)               — the pushes replenish the stack
//!                                         before the next layer pops
//! ```
//!
//! The interleaving is only valid because the hierarchy is Markov: after
//! `pop z_l` the very next ops (`push z_{l-1}`, `pop z_{l+1}`) depend only
//! on `z_l` — nothing later needs a value that has already been spent.
//! `benches/hierarchy.rs` measures the gap; the schedule is recorded in
//! the `BBC3` container header so decode runs the exact inverse.
//!
//! Every Gaussian conditional (recognition and generative) codes over the
//! same max-entropy buckets as the single-layer codec, at
//! `cfg.posterior_prec`; the top prior is exactly uniform. The pixel step
//! shares the single-layer prepared-symbol hot path and [`CodecScratch`].

use anyhow::{bail, Result};

use super::container::{chunk_seed, ChunkEntry};
use super::{
    chunk_ranges, default_workers, gauss_codec_scratch, pixel_lookup, pixel_prepared,
    pooled_indexed, scale_pixels_into, BbAnsConfig, CodecScratch, ImageStats, NN_CHUNK,
};
use crate::ans::{Ans, EntropyCoder};
use crate::codecs::gaussian::{DiscretizedGaussian, MaxEntropyBuckets};
use crate::codecs::uniform::Uniform;
use crate::codecs::SymbolCodec;
use crate::model::hierarchy::HierBackend;
use crate::model::tensor::Matrix;
use crate::model::{PixelParams, PosteriorBatch};

/// Which coding schedule a `BBC3` stream uses. Both are exact inverses of
/// themselves under decode; they differ only in op interleaving (and
/// therefore in which clean/stack bits each pop consumes, so the two
/// schedules produce different — incompatible — bitstreams).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Pop all layers bottom-up, then push data and all priors.
    Naive,
    /// Interleaved per-layer pop/push (valid for Markov hierarchies).
    BitSwap,
}

impl Schedule {
    /// Wire tag recorded in the `BBC3` header.
    pub fn tag(&self) -> u8 {
        match self {
            Self::Naive => 0,
            Self::BitSwap => 1,
        }
    }

    /// Inverse of [`Schedule::tag`].
    pub fn from_tag(tag: u8) -> Result<Self> {
        match tag {
            0 => Ok(Self::Naive),
            1 => Ok(Self::BitSwap),
            other => bail!("unknown schedule tag {other}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Naive => "naive",
            Self::BitSwap => "bitswap",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "naive" => Ok(Self::Naive),
            "bitswap" | "bit-swap" => Ok(Self::BitSwap),
            other => bail!("unknown schedule '{other}' (want naive|bitswap)"),
        }
    }
}

/// Reusable buffers for the hierarchical coding loops: the shared
/// [`CodecScratch`] (prepared pixels, PMF row, cached Gaussian codec) plus
/// per-layer bucket-index buffers and an f32 staging buffer for the B=1
/// net dispatches.
#[derive(Debug, Default)]
pub struct HierScratch {
    pub codec: CodecScratch,
    /// `z[l]` holds layer `l`'s bucket indices for the image in flight.
    z: Vec<Vec<u32>>,
    /// Staging buffer for net inputs (centres / scaled pixels).
    buf: Vec<f32>,
}

impl HierScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure_layers(&mut self, layers: usize) {
        while self.z.len() < layers {
            self.z.push(Vec::new());
        }
    }
}

/// Per-stream state of the (lock-step capable) hierarchical decoder.
struct DecState {
    ans: Ans,
    remaining: usize,
    out: Vec<Vec<u8>>,
    /// Per-layer bucket indices of the image being decoded.
    z: Vec<Vec<u32>>,
    /// Pixels of the image being decoded (kept until the recognition push
    /// returns the borrowed bits).
    img: Vec<u8>,
    scratch: CodecScratch,
}

impl DecState {
    fn new(ans: Ans, remaining: usize, layers: usize) -> Self {
        Self {
            ans,
            remaining,
            // Grown as images decode, NOT pre-reserved: `remaining` can
            // come from an untrusted container header, and allocation
            // should track work actually done.
            out: Vec::new(),
            z: vec![Vec::new(); layers],
            img: Vec::new(),
            scratch: CodecScratch::new(),
        }
    }
}

/// The hierarchical bits-back codec over a [`HierBackend`].
pub struct HierCodec<'a, B: HierBackend + ?Sized> {
    backend: &'a B,
    pub cfg: BbAnsConfig,
    pub schedule: Schedule,
    buckets: MaxEntropyBuckets,
}

impl<'a, B: HierBackend + ?Sized> HierCodec<'a, B> {
    pub fn new(backend: &'a B, cfg: BbAnsConfig, schedule: Schedule) -> Result<Self> {
        cfg.validate()?;
        let meta = backend.meta();
        if meta.dims.is_empty() {
            bail!("hierarchical model has no latent layers");
        }
        if meta.dims.iter().any(|&d| d == 0) {
            bail!("hierarchical model has a zero-width latent layer");
        }
        Ok(Self {
            backend,
            cfg,
            schedule,
            buckets: MaxEntropyBuckets::new(cfg.latent_bits),
        })
    }

    pub fn backend(&self) -> &B {
        self.backend
    }

    fn centres_into(&self, idx: &[u32], out: &mut Vec<f32>) {
        out.extend(idx.iter().map(|&i| self.buckets.centre(i) as f32));
    }

    // ---- per-vector coding primitives (dim orders mirror the
    // ---- single-layer codec: pops ascending, pushes descending, so each
    // ---- pair is an exact inverse) ----

    fn pop_gauss(
        &self,
        ans: &mut Ans,
        mu: &[f32],
        sigma: &[f32],
        dim: usize,
        idx: &mut Vec<u32>,
        slot: &mut Option<DiscretizedGaussian>,
    ) {
        idx.clear();
        for d in 0..dim {
            let g =
                gauss_codec_scratch(&self.buckets, self.cfg.posterior_prec, mu[d], sigma[d], slot);
            idx.push(g.pop(ans));
        }
    }

    fn push_gauss(
        &self,
        ans: &mut Ans,
        mu: &[f32],
        sigma: &[f32],
        idx: &[u32],
        slot: &mut Option<DiscretizedGaussian>,
    ) {
        for d in (0..idx.len()).rev() {
            gauss_codec_scratch(&self.buckets, self.cfg.posterior_prec, mu[d], sigma[d], slot)
                .push(ans, idx[d]);
        }
    }

    fn push_top(&self, ans: &mut Ans, idx: &[u32]) {
        let prior = Uniform::new(self.cfg.latent_bits);
        for &i in idx {
            prior.push(ans, i);
        }
    }

    fn pop_top(&self, ans: &mut Ans, idx: &mut Vec<u32>) {
        let dim = *self.backend.meta().dims.last().expect("non-empty dims");
        let prior = Uniform::new(self.cfg.latent_bits);
        idx.clear();
        idx.resize(dim, 0);
        for d in (0..dim).rev() {
            idx[d] = prior.pop(ans);
        }
    }

    fn push_pixels(
        &self,
        ans: &mut Ans,
        params: &PixelParams,
        img: &[u8],
        scratch: &mut CodecScratch,
    ) {
        let CodecScratch {
            prepared,
            pmf,
            direct,
            ..
        } = scratch;
        super::prepare_pixel_codecs(params, self.cfg.pixel_prec, direct);
        prepared.clear();
        prepared.extend(
            img.iter()
                .enumerate()
                .map(|(p, &sym)| pixel_prepared(params, p, sym, self.cfg.pixel_prec, pmf, direct)),
        );
        ans.encode_all_prepared(prepared, self.cfg.pixel_prec);
    }

    fn pop_pixels(
        &self,
        ans: &mut Ans,
        params: &PixelParams,
        scratch: &mut CodecScratch,
    ) -> Vec<u8> {
        let pixels = self.backend.meta().pixels;
        let CodecScratch { pmf, direct, .. } = scratch;
        super::prepare_pixel_codecs(params, self.cfg.pixel_prec, direct);
        let mut p = 0usize;
        ans.decode_all(pixels, self.cfg.pixel_prec, |cf| {
            let out = pixel_lookup(params, p, cf, self.cfg.pixel_prec, pmf, &*direct);
            p += 1;
            out
        })
    }

    // ---- B=1 net dispatch helpers (the staging buffer round-trips
    // ---- through the Matrix so steady-state coding allocates nothing) ----

    fn infer1(&self, layer: usize, input: &mut Vec<f32>) -> Result<PosteriorBatch> {
        let w = self.backend.meta().infer_in_dim(layer);
        let m = Matrix::new(1, w, std::mem::take(input));
        let out = self.backend.infer_batch(layer, &m);
        *input = m.data;
        out
    }

    fn gen1(&self, layer: usize, input: &mut Vec<f32>) -> Result<PosteriorBatch> {
        let w = self.backend.meta().dims[layer + 1];
        let m = Matrix::new(1, w, std::mem::take(input));
        let out = self.backend.gen_batch(layer, &m);
        *input = m.data;
        out
    }

    fn like1(&self, input: &mut Vec<f32>) -> Result<PixelParams> {
        let w = self.backend.meta().dims[0];
        let m = Matrix::new(1, w, std::mem::take(input));
        let out = self.backend.likelihood_batch(&m);
        *input = m.data;
        Ok(out?.remove(0))
    }

    // -------------------------------------------------------------- encode

    /// Encode one image given layer 0's already-computed recognition
    /// parameters (the data-dependent call the dataset loops batch).
    /// Returns per-step rate telemetry; `posterior_bits` sums every pop
    /// (negative), `prior_bits` every latent push, however the schedule
    /// interleaves them.
    pub fn encode_image_with_posterior_scratch(
        &self,
        ans: &mut Ans,
        img: &[u8],
        mu0: &[f32],
        sigma0: &[f32],
        scratch: &mut HierScratch,
    ) -> Result<ImageStats> {
        let meta = self.backend.meta();
        if img.len() != meta.pixels {
            bail!("image has {} pixels, model wants {}", img.len(), meta.pixels);
        }
        let layers = meta.layers();
        scratch.ensure_layers(layers);
        // Effective message length (clean words are virtual pre-existing
        // content, exactly as in the single-layer codec).
        let bits_at = |a: &Ans| a.frac_bit_len() - 32.0 * a.clean_words_used() as f64;
        let (mut posterior, mut likelihood, mut prior) = (0.0f64, 0.0f64, 0.0f64);
        // Per-layer ledger entry, built alongside the schedule totals when
        // a sink is installed (pure observer; the coder never sees it).
        let mut entry = scratch
            .codec
            .ledger
            .is_some()
            .then(|| crate::obs::LedgerEntry::new(layers));
        let cw0 = ans.clean_words_used();
        let b0 = bits_at(ans);

        let mut z = std::mem::take(&mut scratch.z);
        // Every schedule starts by sampling the bottom layer from q(z_0|x).
        {
            let before = bits_at(ans);
            self.pop_gauss(ans, mu0, sigma0, meta.dims[0], &mut z[0], &mut scratch.codec.gauss);
            let d = bits_at(ans) - before;
            posterior += d;
            if let Some(e) = entry.as_mut() {
                e.latent_pop_bits[0] += d;
            }
        }

        match self.schedule {
            Schedule::Naive => {
                // Pop the remaining layers bottom-up…
                for layer in 1..layers {
                    scratch.buf.clear();
                    self.centres_into(&z[layer - 1], &mut scratch.buf);
                    let pb = self.infer1(layer, &mut scratch.buf)?;
                    let before = bits_at(ans);
                    self.pop_gauss(
                        ans,
                        pb.mu.row(0),
                        pb.sigma.row(0),
                        meta.dims[layer],
                        &mut z[layer],
                        &mut scratch.codec.gauss,
                    );
                    let d = bits_at(ans) - before;
                    posterior += d;
                    if let Some(e) = entry.as_mut() {
                        e.latent_pop_bits[layer] += d;
                    }
                }
                // …then push the data…
                scratch.buf.clear();
                self.centres_into(&z[0], &mut scratch.buf);
                let params = self.like1(&mut scratch.buf)?;
                let before = bits_at(ans);
                self.push_pixels(ans, &params, img, &mut scratch.codec);
                likelihood += bits_at(ans) - before;
                // …then every generative conditional bottom-up.
                for layer in 0..layers - 1 {
                    scratch.buf.clear();
                    self.centres_into(&z[layer + 1], &mut scratch.buf);
                    let pb = self.gen1(layer, &mut scratch.buf)?;
                    let before = bits_at(ans);
                    self.push_gauss(
                        ans,
                        pb.mu.row(0),
                        pb.sigma.row(0),
                        &z[layer],
                        &mut scratch.codec.gauss,
                    );
                    let d = bits_at(ans) - before;
                    prior += d;
                    if let Some(e) = entry.as_mut() {
                        e.latent_push_bits[layer] += d;
                    }
                }
            }
            Schedule::BitSwap => {
                // Push the data immediately — from here on the stack never
                // runs dry, so only q(z_0|x)'s pop borrows clean bits.
                scratch.buf.clear();
                self.centres_into(&z[0], &mut scratch.buf);
                let params = self.like1(&mut scratch.buf)?;
                let before = bits_at(ans);
                self.push_pixels(ans, &params, img, &mut scratch.codec);
                likelihood += bits_at(ans) - before;
                // Interleave: pop layer l, push layer l−1 under its
                // generative conditional (both depend only on z_{l-1}/z_l —
                // the Markov property that makes this valid).
                for layer in 1..layers {
                    scratch.buf.clear();
                    self.centres_into(&z[layer - 1], &mut scratch.buf);
                    let pb = self.infer1(layer, &mut scratch.buf)?;
                    let before = bits_at(ans);
                    self.pop_gauss(
                        ans,
                        pb.mu.row(0),
                        pb.sigma.row(0),
                        meta.dims[layer],
                        &mut z[layer],
                        &mut scratch.codec.gauss,
                    );
                    let d = bits_at(ans) - before;
                    posterior += d;
                    if let Some(e) = entry.as_mut() {
                        e.latent_pop_bits[layer] += d;
                    }

                    scratch.buf.clear();
                    self.centres_into(&z[layer], &mut scratch.buf);
                    let pb = self.gen1(layer - 1, &mut scratch.buf)?;
                    let before = bits_at(ans);
                    self.push_gauss(
                        ans,
                        pb.mu.row(0),
                        pb.sigma.row(0),
                        &z[layer - 1],
                        &mut scratch.codec.gauss,
                    );
                    let d = bits_at(ans) - before;
                    prior += d;
                    if let Some(e) = entry.as_mut() {
                        e.latent_push_bits[layer - 1] += d;
                    }
                }
            }
        }
        // Both schedules end by pushing the top layer under its exactly
        // uniform discretized prior.
        {
            let before = bits_at(ans);
            self.push_top(ans, &z[layers - 1]);
            let d = bits_at(ans) - before;
            prior += d;
            if let Some(e) = entry.as_mut() {
                e.latent_push_bits[layers - 1] += d;
            }
        }
        scratch.z = z;

        let net = bits_at(ans) - b0;
        if let Some(mut e) = entry {
            e.initial_bits = 32.0 * (ans.clean_words_used() - cw0) as f64;
            e.data_bits = likelihood;
            e.net_bits = net;
            scratch
                .codec
                .ledger
                .as_deref_mut()
                .expect("entry implies ledger")
                .push(e);
        }

        Ok(ImageStats {
            net_bits: net,
            posterior_bits: posterior,
            likelihood_bits: likelihood,
            prior_bits: prior,
        })
    }

    /// Encode one image (computes the layer-0 recognition call itself).
    pub fn encode_image_scratch(
        &self,
        ans: &mut Ans,
        img: &[u8],
        scratch: &mut HierScratch,
    ) -> Result<ImageStats> {
        let meta = self.backend.meta();
        if img.len() != meta.pixels {
            bail!("image has {} pixels, model wants {}", img.len(), meta.pixels);
        }
        scratch.buf.clear();
        scale_pixels_into(meta.likelihood, img, &mut scratch.buf);
        let pb = self.infer1(0, &mut scratch.buf)?;
        self.encode_image_with_posterior_scratch(ans, img, pb.mu.row(0), pb.sigma.row(0), scratch)
    }

    /// Clean bits a fresh chain borrows to encode its first image — the
    /// schedule comparison the subsystem exists to improve (Bit-Swap's is
    /// strictly below the naive schedule's for L ≥ 2).
    pub fn initial_bits(&self, img: &[u8]) -> Result<u64> {
        let mut ans = Ans::new(self.cfg.clean_seed);
        self.encode_image_scratch(&mut ans, img, &mut HierScratch::new())?;
        Ok(ans.clean_bits_used())
    }

    /// Scale a chunk of images into one `[B, pixels]` matrix and run
    /// recognition layer 0 as a single batched dispatch (it depends only
    /// on the data, so both dataset encode paths share it and their
    /// bitstreams are identical by construction).
    pub fn posterior_batch_for(&self, chunk: &[Vec<u8>]) -> Result<PosteriorBatch> {
        let meta = self.backend.meta();
        let pixels = meta.pixels;
        let mut data = Vec::with_capacity(chunk.len() * pixels);
        for img in chunk {
            if img.len() != pixels {
                bail!("image has {} pixels, model wants {pixels}", img.len());
            }
            scale_pixels_into(meta.likelihood, img, &mut data);
        }
        self.backend.infer_batch(0, &Matrix::new(chunk.len(), pixels, data))
    }

    /// Chain `images` onto an existing coder state, batching the layer-0
    /// recognition calls per [`NN_CHUNK`]-image block.
    pub fn encode_dataset_into(
        &self,
        ans: &mut Ans,
        images: &[Vec<u8>],
    ) -> Result<Vec<ImageStats>> {
        self.encode_dataset_into_scratch(ans, images, &mut HierScratch::new())
    }

    /// [`Self::encode_dataset_into`] with a caller-owned scratch — the
    /// hook the ledgered paths use to thread an accounting sink through
    /// the chain without touching the emitted bytes.
    pub fn encode_dataset_into_scratch(
        &self,
        ans: &mut Ans,
        images: &[Vec<u8>],
        scratch: &mut HierScratch,
    ) -> Result<Vec<ImageStats>> {
        let mut stats = Vec::with_capacity(images.len());
        for chunk in images.chunks(NN_CHUNK) {
            let posts = self.posterior_batch_for(chunk)?;
            for (r, img) in chunk.iter().enumerate() {
                stats.push(self.encode_image_with_posterior_scratch(
                    ans,
                    img,
                    posts.mu.row(r),
                    posts.sigma.row(r),
                    scratch,
                )?);
            }
        }
        Ok(stats)
    }

    /// Encode a dataset as one chained stream from a fresh coder.
    pub fn encode_dataset(&self, images: &[Vec<u8>]) -> Result<(Ans, Vec<ImageStats>)> {
        let mut ans = Ans::new(self.cfg.clean_seed);
        let stats = self.encode_dataset_into(&mut ans, images)?;
        Ok((ans, stats))
    }

    /// [`Self::encode_dataset`] with the rate ledger attached: same bytes
    /// (the ledger is a pure observer), plus per-image, per-layer bit
    /// accounting — the decomposition that makes the naive-vs-Bit-Swap
    /// initial-bits gap directly observable.
    pub fn encode_dataset_ledgered(
        &self,
        images: &[Vec<u8>],
    ) -> Result<(Ans, Vec<ImageStats>, crate::obs::Ledger)> {
        let mut ans = Ans::new(self.cfg.clean_seed);
        let mut scratch = HierScratch::new();
        scratch.codec.ledger = Some(Box::default());
        let stats = self.encode_dataset_into_scratch(&mut ans, images, &mut scratch)?;
        let ledger = *scratch.codec.ledger.take().expect("installed above");
        Ok((ans, stats, ledger))
    }

    // -------------------------------------------------------------- decode

    /// Decode `n` chained images; returns them in original encode order.
    /// Runs through the same stream machinery as the lock-step multi-chunk
    /// decoder, so there is exactly ONE implementation of each schedule's
    /// inverse.
    pub fn decode_dataset(&self, ans: &mut Ans, n: usize) -> Result<Vec<Vec<u8>>> {
        let layers = self.backend.meta().layers();
        let taken = std::mem::replace(ans, Ans::new(0));
        let mut streams = vec![DecState::new(taken, n, layers)];
        let res = self.decode_streams(&mut streams);
        let st = streams.pop().expect("one stream");
        *ans = st.ans;
        res?;
        let mut out = st.out;
        out.reverse(); // stack order → original order
        Ok(out)
    }

    /// Decode the independent chains of a `BBC3` container **in lock
    /// step**: every chain advances one image per round, and each round's
    /// net evaluations run as single cross-chain batched dispatches — the
    /// coordinator's serving loop for hierarchical containers. Identical
    /// output to decoding each chunk separately (net results are
    /// row-independent and batch-invariant).
    pub fn decode_chunks_lockstep(&self, chunks: &[ChunkEntry]) -> Result<Vec<Vec<u8>>> {
        let layers = self.backend.meta().layers();
        let mut streams: Vec<DecState> = chunks
            .iter()
            .enumerate()
            .map(|(ci, c)| {
                DecState::new(
                    Ans::from_message(&c.message, chunk_seed(self.cfg.clean_seed, ci)),
                    c.num_images as usize,
                    layers,
                )
            })
            .collect();
        self.decode_streams(&mut streams)?;
        let mut out = Vec::new();
        for st in streams {
            let mut imgs = st.out;
            imgs.reverse();
            out.extend(imgs);
        }
        Ok(out)
    }

    /// Advance every stream to completion, one image per stream per round,
    /// with each net call batched across the active streams. The per-op
    /// order within each stream is exactly the inverse of the encode
    /// schedule.
    fn decode_streams(&self, streams: &mut [DecState]) -> Result<()> {
        let meta = self.backend.meta();
        let layers = meta.layers();
        let top = layers - 1;
        let mut buf: Vec<f32> = Vec::new();
        loop {
            let active: Vec<usize> = streams
                .iter()
                .enumerate()
                .filter(|(_, s)| s.remaining > 0)
                .map(|(i, _)| i)
                .collect();
            if active.is_empty() {
                return Ok(());
            }

            // Gather one layer's centres (or the scaled pixels) across the
            // active streams into a [|active|, width] matrix.
            let gather_z = |streams: &[DecState], layer: usize, buf: &mut Vec<f32>| -> Matrix {
                buf.clear();
                for &si in &active {
                    self.centres_into(&streams[si].z[layer], buf);
                }
                Matrix::new(active.len(), meta.dims[layer], std::mem::take(buf))
            };

            // (inverse of the final encode op) pop the top layer from the
            // uniform prior — no net call.
            for &si in &active {
                let s = &mut streams[si];
                self.pop_top(&mut s.ans, &mut s.z[top]);
            }

            match self.schedule {
                Schedule::Naive => {
                    // Pop the generative conditionals top-down.
                    for layer in (0..top).rev() {
                        let m = gather_z(streams, layer + 1, &mut buf);
                        let pb = self.backend.gen_batch(layer, &m)?;
                        buf = m.data;
                        for (r, &si) in active.iter().enumerate() {
                            let s = &mut streams[si];
                            let mut zl = std::mem::take(&mut s.z[layer]);
                            self.pop_gauss(
                                &mut s.ans,
                                pb.mu.row(r),
                                pb.sigma.row(r),
                                meta.dims[layer],
                                &mut zl,
                                &mut s.scratch.gauss,
                            );
                            s.z[layer] = zl;
                        }
                    }
                    // Pop the pixels.
                    let m = gather_z(streams, 0, &mut buf);
                    let params = self.backend.likelihood_batch(&m)?;
                    buf = m.data;
                    for (r, &si) in active.iter().enumerate() {
                        let s = &mut streams[si];
                        s.img = self.pop_pixels(&mut s.ans, &params[r], &mut s.scratch);
                    }
                    // Push the recognition conditionals top-down (exact
                    // inverse of the bottom-up pops), returning the
                    // borrowed bits.
                    for layer in (1..layers).rev() {
                        let m = gather_z(streams, layer - 1, &mut buf);
                        let pb = self.backend.infer_batch(layer, &m)?;
                        buf = m.data;
                        for (r, &si) in active.iter().enumerate() {
                            let s = &mut streams[si];
                            let zl = std::mem::take(&mut s.z[layer]);
                            self.push_gauss(
                                &mut s.ans,
                                pb.mu.row(r),
                                pb.sigma.row(r),
                                &zl,
                                &mut s.scratch.gauss,
                            );
                            s.z[layer] = zl;
                        }
                    }
                }
                Schedule::BitSwap => {
                    // Un-interleave: pop p(z_{l-1}|z_l), push q(z_l|z_{l-1}),
                    // top-down.
                    for layer in (1..layers).rev() {
                        let m = gather_z(streams, layer, &mut buf);
                        let pb = self.backend.gen_batch(layer - 1, &m)?;
                        buf = m.data;
                        for (r, &si) in active.iter().enumerate() {
                            let s = &mut streams[si];
                            let mut zl = std::mem::take(&mut s.z[layer - 1]);
                            self.pop_gauss(
                                &mut s.ans,
                                pb.mu.row(r),
                                pb.sigma.row(r),
                                meta.dims[layer - 1],
                                &mut zl,
                                &mut s.scratch.gauss,
                            );
                            s.z[layer - 1] = zl;
                        }

                        let m = gather_z(streams, layer - 1, &mut buf);
                        let pb = self.backend.infer_batch(layer, &m)?;
                        buf = m.data;
                        for (r, &si) in active.iter().enumerate() {
                            let s = &mut streams[si];
                            let zl = std::mem::take(&mut s.z[layer]);
                            self.push_gauss(
                                &mut s.ans,
                                pb.mu.row(r),
                                pb.sigma.row(r),
                                &zl,
                                &mut s.scratch.gauss,
                            );
                            s.z[layer] = zl;
                        }
                    }
                    // Pop the pixels.
                    let m = gather_z(streams, 0, &mut buf);
                    let params = self.backend.likelihood_batch(&m)?;
                    buf = m.data;
                    for (r, &si) in active.iter().enumerate() {
                        let s = &mut streams[si];
                        s.img = self.pop_pixels(&mut s.ans, &params[r], &mut s.scratch);
                    }
                }
            }

            // (inverse of the first encode op) push z_0 back under q(z_0|x).
            buf.clear();
            for &si in &active {
                scale_pixels_into(meta.likelihood, &streams[si].img, &mut buf);
            }
            let m = Matrix::new(active.len(), meta.pixels, std::mem::take(&mut buf));
            let pb = self.backend.infer_batch(0, &m)?;
            buf = m.data;
            for (r, &si) in active.iter().enumerate() {
                let s = &mut streams[si];
                let z0 = std::mem::take(&mut s.z[0]);
                self.push_gauss(
                    &mut s.ans,
                    pb.mu.row(r),
                    pb.sigma.row(r),
                    &z0,
                    &mut s.scratch.gauss,
                );
                s.z[0] = z0;
                s.out.push(std::mem::take(&mut s.img));
                s.remaining -= 1;
            }
        }
    }
}

/// Chunk-parallel and pipelined hierarchical coding (the PR 3 machinery
/// applied to the deeper chain). Requires a `Sync` backend — the pure-Rust
/// [`crate::model::hierarchy::HierVae`] qualifies.
impl<B: HierBackend + Sync + ?Sized> HierCodec<'_, B> {
    /// Encode one sequential chain with the layer-0 recognition batches
    /// precomputed by worker threads (they depend only on the data) while
    /// this thread runs the strictly sequential chain
    /// ([`pipelined_blocks`], the skeleton shared with the single-layer
    /// codec). Bit-identical to [`Self::encode_dataset_into`] for every
    /// worker count.
    pub fn encode_dataset_pipelined(
        &self,
        ans: &mut Ans,
        images: &[Vec<u8>],
        workers: usize,
    ) -> Result<Vec<ImageStats>> {
        let mut scratch = HierScratch::new();
        let mut stats = Vec::with_capacity(images.len());
        super::pipelined_blocks(
            images,
            workers,
            |block: &[Vec<u8>]| self.posterior_batch_for(block),
            |block: &[Vec<u8>], posts: PosteriorBatch| {
                for (r, img) in block.iter().enumerate() {
                    stats.push(self.encode_image_with_posterior_scratch(
                        ans,
                        img,
                        posts.mu.row(r),
                        posts.sigma.row(r),
                        &mut scratch,
                    )?);
                }
                Ok(())
            },
        )?;
        Ok(stats)
    }

    /// Encode `images` as `n_chunks` independent chains on a bounded
    /// worker pool; chunk `i` seeds its clean-bit supply from
    /// [`chunk_seed`]`(cfg.clean_seed, i)`, so the result depends only on
    /// `(images, n_chunks, cfg, schedule)` — never on `workers`.
    pub fn encode_dataset_chunked_with_workers(
        &self,
        images: &[Vec<u8>],
        n_chunks: usize,
        workers: usize,
    ) -> Result<Vec<ChunkEntry>> {
        let ranges = chunk_ranges(images.len(), n_chunks);
        let pool = workers.clamp(1, ranges.len().max(1));
        let inner = (workers / pool).saturating_sub(1).max(1);
        pooled_indexed(ranges.len(), workers, |ci| {
            let chunk = &images[ranges[ci].clone()];
            let mut ans = Ans::new(chunk_seed(self.cfg.clean_seed, ci));
            self.encode_dataset_pipelined(&mut ans, chunk, inner)?;
            Ok(ChunkEntry {
                num_images: chunk.len() as u32,
                message: ans.into_message(),
            })
        })
        .into_iter()
        .collect()
    }

    /// [`Self::encode_dataset_chunked_with_workers`] on the default pool.
    pub fn encode_dataset_chunked(
        &self,
        images: &[Vec<u8>],
        n_chunks: usize,
    ) -> Result<Vec<ChunkEntry>> {
        self.encode_dataset_chunked_with_workers(images, n_chunks, default_workers())
    }

    /// [`Self::encode_dataset_chunked_with_workers`] with the rate ledger
    /// attached: identical chunk bytes (sequential and pipelined encodes
    /// are bit-identical by construction), plus per-image accounting
    /// merged in chunk order — entry order matches dataset order.
    pub fn encode_dataset_chunked_ledgered(
        &self,
        images: &[Vec<u8>],
        n_chunks: usize,
        workers: usize,
    ) -> Result<(Vec<ChunkEntry>, crate::obs::Ledger)> {
        let ranges = chunk_ranges(images.len(), n_chunks);
        let per_chunk = pooled_indexed(ranges.len(), workers, |ci| {
            let chunk = &images[ranges[ci].clone()];
            let mut ans = Ans::new(chunk_seed(self.cfg.clean_seed, ci));
            let mut scratch = HierScratch::new();
            scratch.codec.ledger = Some(Box::default());
            self.encode_dataset_into_scratch(&mut ans, chunk, &mut scratch)?;
            Ok((
                ChunkEntry {
                    num_images: chunk.len() as u32,
                    message: ans.into_message(),
                },
                *scratch.codec.ledger.take().expect("installed above"),
            ))
        });
        let mut chunks = Vec::with_capacity(per_chunk.len());
        let mut ledger = crate::obs::Ledger::new();
        for r in per_chunk {
            let (entry, chunk_ledger): (ChunkEntry, crate::obs::Ledger) = r?;
            chunks.push(entry);
            ledger.merge(chunk_ledger);
        }
        Ok((chunks, ledger))
    }

    /// Decode chunks on a worker pool (each chunk decodes independently;
    /// the lock-step [`Self::decode_chunks_lockstep`] is the batched
    /// single-thread alternative), with the speculative first-image
    /// scheduling of [`super::decode_chunks_speculative`] — chunk `i+1`'s
    /// first image decodes while chunk `i` drains, hiding pool ramp-down.
    /// Images return in original order, bit-identical to whole-chunk
    /// pooling.
    pub fn decode_dataset_chunked_with_workers(
        &self,
        chunks: &[ChunkEntry],
        workers: usize,
    ) -> Result<Vec<Vec<u8>>> {
        super::decode_chunks_speculative(
            chunks.len(),
            workers,
            |ci| {
                (
                    Ans::from_message(&chunks[ci].message, chunk_seed(self.cfg.clean_seed, ci)),
                    chunks[ci].num_images as usize,
                )
            },
            |_ci, ans, n| self.decode_dataset(ans, n),
        )
    }

    /// [`Self::decode_dataset_chunked_with_workers`] on the default pool.
    pub fn decode_dataset_chunked(&self, chunks: &[ChunkEntry]) -> Result<Vec<Vec<u8>>> {
        self.decode_dataset_chunked_with_workers(chunks, default_workers())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::hierarchy::{HierMeta, HierVae};
    use crate::model::Likelihood;
    use crate::util::rng::Rng;

    fn meta(likelihood: Likelihood, pixels: usize, dims: &[usize]) -> HierMeta {
        HierMeta {
            name: "hier-t".into(),
            pixels,
            dims: dims.to_vec(),
            hidden: 12,
            likelihood,
        }
    }

    fn sample_images(n: usize, pixels: usize, levels: u32, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                (0..pixels)
                    .map(|_| {
                        if rng.f64() < 0.7 {
                            0
                        } else {
                            rng.below(levels as u64) as u8
                        }
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn roundtrip_both_schedules_and_depths() {
        for (trial, likelihood) in [Likelihood::Bernoulli, Likelihood::BetaBinomial]
            .into_iter()
            .enumerate()
        {
            let levels = match likelihood {
                Likelihood::Bernoulli => 2u32,
                Likelihood::BetaBinomial => 256,
            };
            for dims in [&[5usize][..], &[5, 4], &[5, 4, 3]] {
                let backend =
                    HierVae::random(meta(likelihood, 24, dims), 100 + trial as u64);
                for schedule in [Schedule::Naive, Schedule::BitSwap] {
                    let codec =
                        HierCodec::new(&backend, BbAnsConfig::default(), schedule).unwrap();
                    let images = sample_images(9, 24, levels, 7 + trial as u64);
                    let (mut ans, stats) = codec.encode_dataset(&images).unwrap();
                    assert_eq!(stats.len(), 9);
                    let decoded = codec.decode_dataset(&mut ans, 9).unwrap();
                    assert_eq!(decoded, images, "{schedule:?} dims={dims:?}");
                }
            }
        }
    }

    #[test]
    fn single_layer_schedules_are_bit_identical() {
        // With L = 1 the two schedules are literally the same op sequence.
        let backend = HierVae::random(meta(Likelihood::Bernoulli, 30, &[6]), 5);
        let images = sample_images(6, 30, 2, 11);
        let naive = HierCodec::new(&backend, BbAnsConfig::default(), Schedule::Naive).unwrap();
        let swap = HierCodec::new(&backend, BbAnsConfig::default(), Schedule::BitSwap).unwrap();
        let (a, _) = naive.encode_dataset(&images).unwrap();
        let (b, _) = swap.encode_dataset(&images).unwrap();
        assert_eq!(a.to_message(), b.to_message());
    }

    #[test]
    fn bitswap_initial_bits_strictly_below_naive() {
        // The subsystem's reason to exist (acceptance criterion): a fresh
        // Bit-Swap chain borrows strictly fewer clean bits than the naive
        // schedule for L >= 2 — the data push after layer 0 replenishes
        // the stack before the higher layers pop.
        for dims in [&[16usize, 12][..], &[16, 12, 8]] {
            let backend = HierVae::random(meta(Likelihood::Bernoulli, 256, dims), 21);
            let img = &sample_images(1, 256, 2, 3)[0];
            let naive = HierCodec::new(&backend, BbAnsConfig::default(), Schedule::Naive)
                .unwrap()
                .initial_bits(img)
                .unwrap();
            let swap = HierCodec::new(&backend, BbAnsConfig::default(), Schedule::BitSwap)
                .unwrap()
                .initial_bits(img)
                .unwrap();
            assert!(
                swap < naive,
                "dims={dims:?}: bitswap {swap} must be < naive {naive}"
            );
        }
    }

    #[test]
    fn decode_returns_clean_bits() {
        // After decoding everything, the stream holds exactly the clean
        // words the encoder borrowed — bits back, layer-recursively.
        for schedule in [Schedule::Naive, Schedule::BitSwap] {
            let backend = HierVae::random(meta(Likelihood::Bernoulli, 24, &[5, 3]), 9);
            let codec = HierCodec::new(&backend, BbAnsConfig::default(), schedule).unwrap();
            let images = sample_images(8, 24, 2, 13);
            let (mut ans, _) = codec.encode_dataset(&images).unwrap();
            let borrowed = ans.clean_words_used();
            let _ = codec.decode_dataset(&mut ans, 8).unwrap();
            assert_eq!(ans.stream_len() as u64, borrowed, "{schedule:?}");
            let msg = ans.to_message();
            let mut fresh = Rng::new(codec.cfg.clean_seed);
            let expect: Vec<u32> = (0..borrowed).map(|_| fresh.next_u32()).collect();
            let mut got = msg.stream.clone();
            got.reverse();
            assert_eq!(got, expect, "{schedule:?}");
        }
    }

    #[test]
    fn lockstep_decode_matches_per_chunk_decode() {
        let backend = HierVae::random(meta(Likelihood::Bernoulli, 24, &[5, 4, 3]), 31);
        for schedule in [Schedule::Naive, Schedule::BitSwap] {
            let codec = HierCodec::new(&backend, BbAnsConfig::default(), schedule).unwrap();
            let images = sample_images(23, 24, 2, 17);
            let chunks = codec.encode_dataset_chunked_with_workers(&images, 4, 2).unwrap();
            let lockstep = codec.decode_chunks_lockstep(&chunks).unwrap();
            let pooled = codec.decode_dataset_chunked_with_workers(&chunks, 3).unwrap();
            assert_eq!(lockstep, images, "{schedule:?}");
            assert_eq!(pooled, images, "{schedule:?}");
        }
    }

    #[test]
    fn stats_components_are_consistent() {
        for schedule in [Schedule::Naive, Schedule::BitSwap] {
            let backend = HierVae::random(meta(Likelihood::Bernoulli, 24, &[5, 4]), 15);
            let codec = HierCodec::new(&backend, BbAnsConfig::default(), schedule).unwrap();
            let images = sample_images(5, 24, 2, 19);
            let (_, stats) = codec.encode_dataset(&images).unwrap();
            for s in &stats {
                assert!(
                    (s.net_bits - (s.posterior_bits + s.likelihood_bits + s.prior_bits)).abs()
                        < 1e-6
                );
                assert!(s.posterior_bits < 0.0, "{schedule:?}");
                assert!(s.likelihood_bits > 0.0, "{schedule:?}");
                assert!(s.prior_bits > 0.0, "{schedule:?}");
            }
        }
    }

    #[test]
    fn wrong_image_size_rejected() {
        let backend = HierVae::random(meta(Likelihood::Bernoulli, 24, &[5]), 3);
        let codec = HierCodec::new(&backend, BbAnsConfig::default(), Schedule::BitSwap).unwrap();
        let mut ans = Ans::new(0);
        assert!(codec
            .encode_image_scratch(&mut ans, &[0u8; 23], &mut HierScratch::new())
            .is_err());
    }
}
