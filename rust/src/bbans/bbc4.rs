//! BBC4 — the integrity-checked paged container format.
//!
//! BBC1–BBC3 squander the independence of their chunk chains for
//! robustness: the ANS state carries no integrity signal (every bit
//! pattern is a decodable state), so one flipped bit or truncated tail
//! anywhere silently corrupts the *entire* dataset on decode. BBC4 spends
//! a few bytes per page to fix that:
//!
//! * each chunk's ANS stream rides in a self-delimiting, CRC-32-checked
//!   [`PageFrame`] (see [`crate::format`]), so corruption is **detected**
//!   and **isolated to a page**;
//! * a trailer carries a redundant page index (offset/length/CRC per
//!   page, itself CRC-protected), so a reader can locate pages even when
//!   the forward scan is interrupted — including pages whose resync magic
//!   was itself damaged;
//! * pages are independently seeded chains ([`chunk_seed`]), so every
//!   intact page decodes **bit-exactly** no matter what happened to its
//!   neighbours.
//!
//! [`Bbc4Container::from_bytes`] is the strict reader (fail fast on the
//! first bad byte — the serving default); [`Bbc4Container::salvage`] is
//! the recovery reader (skip damaged regions, keep everything provably
//! intact, and say exactly what was lost in a [`RecoveryReport`]).
//!
//! File layout (all little-endian):
//!
//! ```text
//! magic "BBC4" | version u8 | kind u8 (1 = VAE, 2 = hierarchical)
//! latent_bits u8 | posterior_prec u8 | pixel_prec u8 | clean_seed u64
//! pixels u32 | num_images u32 | n_pages u32
//! kind VAE : model str | backend_id str
//! kind hier: model str | backend_id str | schedule u8 | likelihood u8
//!            hidden u32 | weight_seed u64 | n_layers u8 | dims u32 each
//! header_crc u32                      (CRC-32 over all bytes above)
//! n_pages page frames                 (see crate::format)
//! trailer: INDEX_MAGIC | n_pages u32
//!          per page: offset u64 | frame_len u32 | first_image u32
//!                    | num_images u32 | page_crc u32
//!          index_crc u32 | trailer_len u32
//! ```
//!
//! Pages tile the dataset by the deterministic [`chunk_ranges`] split, and
//! both readers enforce that tiling — a crafted page cannot claim an
//! overlapping or out-of-place image range.

use std::collections::BTreeMap;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::container::{
    check_decode_budget, chunk_seed, push_str, read_str, ChunkEntry, HierContainer,
};
use super::hierarchy::{HierCodec, HierScratch, Schedule};
use super::{BbAnsConfig, CodecScratch, VaeCodec};
use crate::ans::{Ans, AnsMessage};
use crate::format::stream::{
    journal_path, journal_prefix, FileMedium, JournalRecord, StreamMedium,
};
use crate::format::{self, FrameRead, PageFrame};
use crate::model::hierarchy::{HierBackend, HierVae};
use crate::model::{Backend, Likelihood};
use crate::obs::Ledger;
use crate::util::chunk_ranges;
use crate::util::crc32;

/// Magic of the paged, integrity-checked container format.
pub const MAGIC_BBC4: &[u8; 4] = b"BBC4";

/// Resync magic of the trailer index (non-ASCII like the page magic).
pub const INDEX_MAGIC: [u8; 4] = [0xB4, 0x49, 0x58, 0x1A]; // ´IX␚

/// Bytes per trailer index entry: offset u64 + frame_len, first_image,
/// num_images, crc (u32 each).
const INDEX_ENTRY_LEN: usize = 24;

/// Trailer bytes beyond the entries: magic + count + index_crc +
/// trailer_len.
const TRAILER_FIXED: usize = 16;

/// Which codec family produced the page chains.
#[derive(Debug, Clone, PartialEq)]
pub enum Bbc4Model {
    /// Single-layer VAE chains (the BBC2 coding process); the decoder
    /// loads the named model from its artifact bundle.
    Vae { model: String, backend_id: String },
    /// Hierarchical chains (the BBC3 coding process); self-describing —
    /// the decoder rebuilds the backend from the recorded geometry.
    Hier {
        model: String,
        backend_id: String,
        schedule: Schedule,
        likelihood: Likelihood,
        hidden: u32,
        weight_seed: u64,
        /// Latent widths bottom-up (`dims[0]` next to the data).
        dims: Vec<u32>,
    },
}

impl Bbc4Model {
    fn kind_tag(&self) -> u8 {
        match self {
            Bbc4Model::Vae { .. } => 1,
            Bbc4Model::Hier { .. } => 2,
        }
    }

    /// Model name recorded in the header.
    pub fn name(&self) -> &str {
        match self {
            Bbc4Model::Vae { model, .. } | Bbc4Model::Hier { model, .. } => model,
        }
    }

    /// Backend id recorded in the header.
    pub fn backend_id(&self) -> &str {
        match self {
            Bbc4Model::Vae { backend_id, .. } | Bbc4Model::Hier { backend_id, .. } => backend_id,
        }
    }

    /// The header model a single-layer codec encodes under.
    pub fn for_vae<B: Backend + ?Sized>(codec: &VaeCodec<'_, B>) -> Self {
        Bbc4Model::Vae {
            model: codec.backend().meta().name.clone(),
            backend_id: codec.backend().backend_id(),
        }
    }

    /// The header model a hierarchical codec encodes under
    /// (self-describing, like BBC3).
    pub fn for_hier<B: HierBackend + ?Sized>(codec: &HierCodec<'_, B>) -> Self {
        let meta = codec.backend().meta();
        Bbc4Model::Hier {
            model: meta.name.clone(),
            backend_id: codec.backend().backend_id(),
            schedule: codec.schedule,
            likelihood: meta.likelihood,
            hidden: meta.hidden as u32,
            weight_seed: codec.backend().weight_seed(),
            dims: meta.dims.iter().map(|&d| d as u32).collect(),
        }
    }
}

/// One recovered (or encoded) page: chunk `index`'s ANS chain covering
/// images `[first_image, first_image + num_images)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Bbc4Page {
    pub index: u32,
    pub first_image: u32,
    pub num_images: u32,
    pub message: AnsMessage,
}

/// The paged container. After [`Bbc4Container::from_bytes`] `pages` holds
/// all `n_pages` pages; after [`Bbc4Container::salvage`] it holds the
/// recovered subset (sorted by index).
#[derive(Debug, Clone, PartialEq)]
pub struct Bbc4Container {
    pub cfg: BbAnsConfig,
    pub pixels: u32,
    /// Total images the *intact* container codes (header field — lost
    /// pages do not shrink it).
    pub num_images: u32,
    /// Total pages the intact container carries (header field).
    pub n_pages: u32,
    pub model: Bbc4Model,
    pub pages: Vec<Bbc4Page>,
}

/// What a salvage pass recovered and what it had to give up.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecoveryReport {
    pub pages_total: u32,
    pub pages_recovered: u32,
    /// Page indices that could not be recovered, ascending.
    pub pages_lost: Vec<u32>,
    pub images_total: u32,
    /// Global image indices that are gone with the lost pages, ascending.
    pub images_lost: Vec<u32>,
    /// Byte ranges `[start, end)` not covered by the header, a valid
    /// page, or the intact trailer index — the damage footprint.
    pub damaged_ranges: Vec<(usize, usize)>,
    /// Whether the redundant trailer index validated.
    pub index_intact: bool,
    /// When the trailer index is gone, the byte range `[start, end)` of
    /// the torn tail: everything past the last recovered structure. An
    /// empty range (`start == end == len`) means the file was cut
    /// cleanly at a page boundary with only the trailer missing.
    pub truncated_tail: Option<(usize, usize)>,
}

impl RecoveryReport {
    /// True iff the container verified end to end with nothing lost.
    pub fn is_clean(&self) -> bool {
        self.pages_lost.is_empty() && self.damaged_ranges.is_empty() && self.index_intact
    }

    /// One-line operator summary.
    pub fn summary(&self) -> String {
        let tail = match self.truncated_tail {
            Some((s, e)) if e > s => format!(", torn tail bytes [{s}, {e})"),
            Some((s, _)) => format!(", truncated at {s}"),
            None => String::new(),
        };
        format!(
            "pages {}/{} recovered, {} of {} images lost, {} damaged byte range(s), index {}{tail}",
            self.pages_recovered,
            self.pages_total,
            self.images_lost.len(),
            self.images_total,
            self.damaged_ranges.len(),
            if self.index_intact { "intact" } else { "damaged" },
        )
    }
}

/// Result of a salvage pass: whatever was recoverable, plus the report.
#[derive(Debug, Clone)]
pub struct Salvage {
    pub container: Bbc4Container,
    pub report: RecoveryReport,
}

/// One parsed trailer index entry.
struct IndexEntry {
    offset: u64,
    frame_len: u32,
    first_image: u32,
    num_images: u32,
    crc: u32,
}

impl Bbc4Container {
    /// Encode `images` into `n_chunks` independently seeded single-layer
    /// chains, one page per chunk.
    pub fn encode_vae_with_workers<B: Backend + Sync + ?Sized>(
        codec: &VaeCodec<'_, B>,
        images: &[Vec<u8>],
        n_chunks: usize,
        workers: usize,
    ) -> Result<Self> {
        let meta = codec.backend().meta();
        let chunks = codec.encode_dataset_chunked_with_workers(images, n_chunks, workers)?;
        Ok(Self::assemble(
            Bbc4Model::for_vae(codec),
            codec.cfg,
            meta.pixels as u32,
            chunks,
        ))
    }

    /// [`Self::encode_vae_with_workers`] on the default pool.
    pub fn encode_vae<B: Backend + Sync + ?Sized>(
        codec: &VaeCodec<'_, B>,
        images: &[Vec<u8>],
        n_chunks: usize,
    ) -> Result<Self> {
        Self::encode_vae_with_workers(codec, images, n_chunks, super::default_workers())
    }

    /// Encode `images` into `n_chunks` hierarchical chains, one page per
    /// chunk; the header is self-describing like BBC3.
    pub fn encode_hier_with_workers<B: HierBackend + Sync + ?Sized>(
        codec: &HierCodec<'_, B>,
        images: &[Vec<u8>],
        n_chunks: usize,
        workers: usize,
    ) -> Result<Self> {
        let meta = codec.backend().meta();
        let chunks = codec.encode_dataset_chunked_with_workers(images, n_chunks, workers)?;
        Ok(Self::assemble(
            Bbc4Model::for_hier(codec),
            codec.cfg,
            meta.pixels as u32,
            chunks,
        ))
    }

    /// [`Self::encode_hier_with_workers`] on the default pool.
    pub fn encode_hier<B: HierBackend + Sync + ?Sized>(
        codec: &HierCodec<'_, B>,
        images: &[Vec<u8>],
        n_chunks: usize,
    ) -> Result<Self> {
        Self::encode_hier_with_workers(codec, images, n_chunks, super::default_workers())
    }

    fn assemble(
        model: Bbc4Model,
        cfg: BbAnsConfig,
        pixels: u32,
        chunks: Vec<ChunkEntry>,
    ) -> Self {
        let mut pages = Vec::with_capacity(chunks.len());
        let mut first = 0u32;
        for (i, c) in chunks.into_iter().enumerate() {
            pages.push(Bbc4Page {
                index: i as u32,
                first_image: first,
                num_images: c.num_images,
                message: c.message,
            });
            first += c.num_images;
        }
        Self {
            cfg,
            pixels,
            num_images: first,
            n_pages: pages.len() as u32,
            model,
            pages,
        }
    }

    /// Total images recovered across the pages currently held.
    pub fn images_present(&self) -> u32 {
        self.pages.iter().map(|p| p.num_images).sum()
    }

    /// Total serialized size in bytes.
    pub fn byte_len(&self) -> usize {
        self.to_bytes().len()
    }

    /// Compression rate in bits per pixel-dimension over the whole
    /// container (CRC and index overhead included).
    pub fn bits_per_dim(&self) -> f64 {
        (self.byte_len() as f64 * 8.0) / (self.num_images as f64 * self.pixels as f64)
    }

    /// The CRC-protected header (everything before the first page).
    fn header_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_BBC4);
        out.push(1u8); // version
        out.push(self.model.kind_tag());
        out.push(self.cfg.latent_bits as u8);
        out.push(self.cfg.posterior_prec as u8);
        out.push(self.cfg.pixel_prec as u8);
        out.extend_from_slice(&self.cfg.clean_seed.to_le_bytes());
        out.extend_from_slice(&self.pixels.to_le_bytes());
        out.extend_from_slice(&self.num_images.to_le_bytes());
        out.extend_from_slice(&self.n_pages.to_le_bytes());
        match &self.model {
            Bbc4Model::Vae { model, backend_id } => {
                push_str(&mut out, model);
                push_str(&mut out, backend_id);
            }
            Bbc4Model::Hier {
                model,
                backend_id,
                schedule,
                likelihood,
                hidden,
                weight_seed,
                dims,
            } => {
                push_str(&mut out, model);
                push_str(&mut out, backend_id);
                out.push(schedule.tag());
                out.push(likelihood.tag());
                out.extend_from_slice(&hidden.to_le_bytes());
                out.extend_from_slice(&weight_seed.to_le_bytes());
                assert!(
                    !dims.is_empty() && dims.len() <= 255,
                    "layer count out of range"
                );
                out.push(dims.len() as u8);
                for &d in dims {
                    out.extend_from_slice(&d.to_le_bytes());
                }
            }
        }
        let crc = crc32::hash(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        debug_assert_eq!(self.pages.len() as u32, self.n_pages, "incomplete container");
        let mut out = self.header_bytes();
        let mut entries = Vec::with_capacity(self.pages.len());
        for p in &self.pages {
            let frame = PageFrame {
                index: p.index,
                first_image: p.first_image,
                num_images: p.num_images,
                payload: p.message.to_bytes(),
            };
            entries.push(IndexEntry {
                offset: out.len() as u64,
                frame_len: frame.byte_len() as u32,
                first_image: p.first_image,
                num_images: p.num_images,
                crc: frame.crc(),
            });
            frame.write_to(&mut out);
        }
        // Redundant page index: lets a reader locate every page from the
        // tail even when the forward scan is interrupted.
        out.extend_from_slice(&trailer_bytes(&entries));
        out
    }

    /// Parse and CRC-check the header; returns the container shell (no
    /// pages yet) and the offset of the first page frame.
    fn parse_header(b: &[u8]) -> Result<(Self, usize)> {
        let mut pos = 0usize;
        // `pos <= b.len()` is an invariant, so the bounds check cannot
        // wrap (see ParallelContainer::from_bytes).
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if n > b.len() - *pos {
                bail!("BBC4 header truncated at {} (+{n})", *pos);
            }
            let s = &b[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let magic = take(&mut pos, 4)?;
        if magic != MAGIC_BBC4 {
            bail!("bad BBC4 container magic {magic:02x?} (want {MAGIC_BBC4:02x?} = \"BBC4\")");
        }
        let version = take(&mut pos, 1)?[0];
        if version != 1 {
            bail!("unsupported BBC4 container version {version} (this build reads version 1)");
        }
        let kind = take(&mut pos, 1)?[0];
        let latent_bits = take(&mut pos, 1)?[0] as u32;
        let posterior_prec = take(&mut pos, 1)?[0] as u32;
        let pixel_prec = take(&mut pos, 1)?[0] as u32;
        let clean_seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let pixels = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let num_images = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let n_pages = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let model = match kind {
            1 => {
                let model = read_str(b, &mut pos).context("model name")?;
                let backend_id = read_str(b, &mut pos).context("backend id")?;
                Bbc4Model::Vae { model, backend_id }
            }
            2 => {
                let model = read_str(b, &mut pos).context("model name")?;
                let backend_id = read_str(b, &mut pos).context("backend id")?;
                let schedule = Schedule::from_tag(take(&mut pos, 1)?[0])?;
                let likelihood = Likelihood::from_tag(take(&mut pos, 1)?[0])?;
                let hidden = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
                let weight_seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
                if hidden == 0 || hidden > 1 << 20 {
                    bail!("implausible hidden width {hidden}");
                }
                let n_layers = take(&mut pos, 1)?[0] as usize;
                if n_layers == 0 || n_layers > 16 {
                    bail!("implausible layer count {n_layers}");
                }
                let mut dims = Vec::with_capacity(n_layers);
                for _ in 0..n_layers {
                    let d = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
                    if d == 0 || d > 1 << 16 {
                        bail!("implausible latent width {d}");
                    }
                    dims.push(d);
                }
                Bbc4Model::Hier {
                    model,
                    backend_id,
                    schedule,
                    likelihood,
                    hidden,
                    weight_seed,
                    dims,
                }
            }
            other => bail!("unknown BBC4 model kind {other} (want 1 = VAE or 2 = hierarchical)"),
        };
        let computed = crc32::hash(&b[..pos]);
        let stored = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        if computed != stored {
            bail!("BBC4 header CRC mismatch: stored {stored:#010x}, computed {computed:#010x}");
        }
        // Untrusted-header admission, as for every other container.
        if pixels == 0 || pixels > 1 << 24 {
            bail!("implausible pixel count {pixels}");
        }
        check_decode_budget(num_images as u64, pixels as u64)?;
        if n_pages > 1 << 20 {
            bail!("implausible page count {n_pages}");
        }
        let tiling = chunk_ranges(num_images as usize, n_pages as usize);
        if tiling.len() as u32 != n_pages {
            bail!("page count {n_pages} is inconsistent with {num_images} images");
        }
        let cfg = BbAnsConfig {
            latent_bits,
            posterior_prec,
            pixel_prec,
            clean_seed,
        };
        cfg.validate()?;
        Ok((
            Self {
                cfg,
                pixels,
                num_images,
                n_pages,
                model,
                pages: Vec::new(),
            },
            pos,
        ))
    }

    /// Validate one frame against the header's deterministic page tiling
    /// and parse its payload. `None` means the frame is internally valid
    /// but does not belong (crafted index, wrong range, garbage payload).
    fn admit_page(&self, frame: &PageFrame) -> Option<Bbc4Page> {
        if frame.index >= self.n_pages {
            return None;
        }
        let want = chunk_ranges(self.num_images as usize, self.n_pages as usize);
        let r = &want[frame.index as usize];
        if frame.first_image as usize != r.start || frame.num_images as usize != r.len() {
            return None;
        }
        let message = AnsMessage::from_bytes(&frame.payload).ok()?;
        Some(Bbc4Page {
            index: frame.index,
            first_image: frame.first_image,
            num_images: frame.num_images,
            message,
        })
    }

    /// Strict reader: every page and the trailer index must verify, in
    /// order, with nothing missing and nothing trailing. Fails fast on
    /// the first bad byte — the serving-path default, where a damaged
    /// container should be rejected, not half-decoded.
    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        let (mut c, mut pos) = Self::parse_header(b)?;
        let tiling = chunk_ranges(c.num_images as usize, c.n_pages as usize);
        let mut entries = Vec::with_capacity(c.n_pages as usize);
        for i in 0..c.n_pages {
            let at = pos;
            match format::read_frame(b, at) {
                FrameRead::Ok { frame, next } => {
                    if frame.index != i {
                        bail!("page {i} at offset {at} carries page index {}", frame.index);
                    }
                    let r = &tiling[i as usize];
                    if frame.first_image as usize != r.start
                        || frame.num_images as usize != r.len()
                    {
                        bail!(
                            "page {i} claims images [{}, +{}), expected [{}, +{})",
                            frame.first_image,
                            frame.num_images,
                            r.start,
                            r.len()
                        );
                    }
                    let message = AnsMessage::from_bytes(&frame.payload)
                        .with_context(|| format!("page {i} payload"))?;
                    entries.push(IndexEntry {
                        offset: at as u64,
                        frame_len: (next - at) as u32,
                        first_image: frame.first_image,
                        num_images: frame.num_images,
                        crc: frame.crc(),
                    });
                    c.pages.push(Bbc4Page {
                        index: frame.index,
                        first_image: frame.first_image,
                        num_images: frame.num_images,
                        message,
                    });
                    pos = next;
                }
                FrameRead::NoMagic => bail!("page {i} missing at offset {at}: no frame magic"),
                FrameRead::Truncated { need, have } => {
                    bail!("page {i} truncated: frame needs {need} bytes, container has {have}")
                }
                FrameRead::Damaged { detail } => bail!("page {i} at offset {at}: {detail}"),
            }
        }
        let (index, index_range) = read_trailer_index(b)
            .ok_or_else(|| anyhow!("BBC4 trailer index missing or damaged"))?;
        if index_range.0 != pos {
            bail!(
                "BBC4 trailer index starts at offset {} but pages end at {pos}",
                index_range.0
            );
        }
        if index_range.1 != b.len() {
            bail!("BBC4 container has {} trailing bytes", b.len() - index_range.1);
        }
        if index.len() != entries.len() {
            bail!(
                "trailer index lists {} pages, container has {}",
                index.len(),
                entries.len()
            );
        }
        for (i, (got, want)) in index.iter().zip(&entries).enumerate() {
            if got.offset != want.offset
                || got.frame_len != want.frame_len
                || got.first_image != want.first_image
                || got.num_images != want.num_images
                || got.crc != want.crc
            {
                bail!("trailer index entry {i} does not match page {i}'s frame");
            }
        }
        Ok(c)
    }

    /// Recovery reader: parse the header (the one unrecoverable piece),
    /// then keep every page that proves itself — via the forward resync
    /// scan and, for pages the scan misses, via the redundant trailer
    /// index. Returns the recovered subset plus an exact damage report.
    pub fn salvage(b: &[u8]) -> Result<Salvage> {
        let (mut c, header_end) = Self::parse_header(b)
            .context("BBC4 header is damaged; nothing is recoverable without it")?;
        let index = read_trailer_index(b);
        let index_range = index.as_ref().map(|(_, r)| *r);
        let scan_end = index_range.map(|(s, _)| s).unwrap_or(b.len());

        // Forward scan with resync: walk frames from the header; after a
        // damaged or unparseable region, hunt for the next page magic.
        let mut found: BTreeMap<u32, (Bbc4Page, (usize, usize))> = BTreeMap::new();
        let mut pos = header_end;
        while pos < scan_end {
            let advance = match format::read_frame(b, pos) {
                FrameRead::Ok { frame, next } => match c.admit_page(&frame) {
                    Some(page) => {
                        found.entry(page.index).or_insert((page, (pos, next)));
                        Some(next)
                    }
                    None => None,
                },
                _ => None,
            };
            match advance {
                Some(next) => pos = next,
                // Resync: the bytes at `pos` are not a valid page.
                None => match format::find_magic(b, pos + 1) {
                    Some(p) if p < scan_end => pos = p,
                    _ => break,
                },
            }
        }

        // Index-guided recovery: the trailer knows where every page
        // lives and what its CRC is, so pages the scan missed (e.g. a
        // damaged resync magic) can still be validated in place.
        if let Some((entries, _)) = &index {
            for (i, e) in entries.iter().enumerate() {
                let i = i as u32;
                if found.contains_key(&i) || i >= c.n_pages {
                    continue;
                }
                let at = e.offset as usize;
                let end = at.saturating_add(e.frame_len as usize);
                if end > b.len() {
                    continue;
                }
                if let FrameRead::Ok { frame, next } = format::read_frame_body(b, at) {
                    if frame.index == i && frame.crc() == e.crc && next == end {
                        if let Some(page) = c.admit_page(&frame) {
                            found.insert(i, (page, (at, next)));
                        }
                    }
                }
            }
        }

        // Damage footprint: every byte not covered by the header, a
        // recovered page, or the intact index.
        let mut covered: Vec<(usize, usize)> = vec![(0, header_end)];
        covered.extend(found.values().map(|(_, r)| *r));
        if let Some(r) = index_range {
            covered.push(r);
        }
        covered.sort_unstable();
        let mut damaged_ranges = Vec::new();
        let mut cur = 0usize;
        for (s, e) in covered {
            if s > cur {
                damaged_ranges.push((cur, s));
            }
            cur = cur.max(e);
        }
        if cur < b.len() {
            damaged_ranges.push((cur, b.len()));
        }

        let pages_lost: Vec<u32> = (0..c.n_pages).filter(|i| !found.contains_key(i)).collect();
        let tiling = chunk_ranges(c.num_images as usize, c.n_pages as usize);
        let images_lost: Vec<u32> = pages_lost
            .iter()
            .flat_map(|&i| tiling[i as usize].clone())
            .map(|i| i as u32)
            .collect();
        // With the trailer gone the file ends in a torn tail: everything
        // past the last byte the header or a recovered page vouches for.
        let truncated_tail = if index.is_some() {
            None
        } else {
            let covered_end = found
                .values()
                .map(|(_, r)| r.1)
                .max()
                .unwrap_or(header_end)
                .min(b.len());
            Some((covered_end, b.len()))
        };
        let report = RecoveryReport {
            pages_total: c.n_pages,
            pages_recovered: found.len() as u32,
            pages_lost,
            images_total: c.num_images,
            images_lost,
            damaged_ranges,
            index_intact: index.is_some(),
            truncated_tail,
        };
        c.pages = found.into_values().map(|(p, _)| p).collect();
        Ok(Salvage {
            container: c,
            report,
        })
    }

    /// A header-equivalent chunkless [`HierContainer`] for code that keys
    /// on BBC3 header identity (backend rebuild, the coordinator's
    /// backend cache). Errors on `kind = vae` headers.
    pub fn hier_shell(&self) -> Result<HierContainer> {
        let Bbc4Model::Hier {
            model,
            backend_id,
            schedule,
            likelihood,
            hidden,
            weight_seed,
            dims,
        } = &self.model
        else {
            bail!("container codes a single-layer model; no hierarchical backend to build");
        };
        Ok(HierContainer {
            model: model.clone(),
            backend_id: backend_id.clone(),
            schedule: *schedule,
            cfg: self.cfg,
            likelihood: *likelihood,
            hidden: *hidden,
            weight_seed: *weight_seed,
            pixels: self.pixels,
            dims: dims.clone(),
            chunks: Vec::new(),
        })
    }

    /// Rebuild the hierarchical backend a `kind = hier` header describes
    /// (same admission budget as BBC3's self-describing decode path).
    pub fn build_hier_backend(&self) -> Result<HierVae> {
        self.hier_shell()?.build_backend()
    }

    fn validate_common(&self, pixels: usize, cfg: &BbAnsConfig) -> Result<()> {
        if self.pixels as usize != pixels {
            bail!(
                "container has {}-pixel images, model wants {pixels}",
                self.pixels
            );
        }
        if &self.cfg != cfg {
            bail!("decode codec config does not match the container header");
        }
        Ok(())
    }

    /// Decode the held pages into per-image slots: `slots[i]` is `None`
    /// iff image `i` rode a page this container no longer holds. On a
    /// strict parse every slot is `Some`; after salvage the gaps are
    /// exactly `RecoveryReport::images_lost`.
    pub fn decode_slots_vae<B: Backend + ?Sized>(
        &self,
        codec: &VaeCodec<'_, B>,
    ) -> Result<Vec<Option<Vec<u8>>>> {
        if !matches!(self.model, Bbc4Model::Vae { .. }) {
            bail!("container codes a hierarchical model; decode it with a HierCodec");
        }
        self.validate_common(codec.backend().meta().pixels, &codec.cfg)?;
        let mut slots = vec![None; self.num_images as usize];
        for p in &self.pages {
            let mut ans =
                Ans::from_message(&p.message, chunk_seed(self.cfg.clean_seed, p.index as usize));
            let imgs = codec
                .decode_dataset(&mut ans, p.num_images as usize)
                .with_context(|| format!("page {}", p.index))?;
            for (k, img) in imgs.into_iter().enumerate() {
                slots[p.first_image as usize + k] = Some(img);
            }
        }
        Ok(slots)
    }

    /// [`Self::decode_slots_vae`] for hierarchical pages.
    pub fn decode_slots_hier<B: HierBackend + ?Sized>(
        &self,
        codec: &HierCodec<'_, B>,
    ) -> Result<Vec<Option<Vec<u8>>>> {
        let Bbc4Model::Hier { schedule, .. } = &self.model else {
            bail!("container codes a single-layer model; decode it with a VaeCodec");
        };
        if *schedule != codec.schedule {
            bail!(
                "container was coded with the {} schedule, codec uses {}",
                schedule.name(),
                codec.schedule.name()
            );
        }
        self.validate_common(codec.backend().meta().pixels, &codec.cfg)?;
        let mut slots = vec![None; self.num_images as usize];
        for p in &self.pages {
            let mut ans =
                Ans::from_message(&p.message, chunk_seed(self.cfg.clean_seed, p.index as usize));
            let imgs = codec
                .decode_dataset(&mut ans, p.num_images as usize)
                .with_context(|| format!("page {}", p.index))?;
            for (k, img) in imgs.into_iter().enumerate() {
                slots[p.first_image as usize + k] = Some(img);
            }
        }
        Ok(slots)
    }

    /// Strict full decode (every page present).
    pub fn decode_vae<B: Backend + ?Sized>(
        &self,
        codec: &VaeCodec<'_, B>,
    ) -> Result<Vec<Vec<u8>>> {
        collect_complete(self.decode_slots_vae(codec)?)
    }

    /// Strict full decode (every page present), hierarchical.
    pub fn decode_hier<B: HierBackend + ?Sized>(
        &self,
        codec: &HierCodec<'_, B>,
    ) -> Result<Vec<Vec<u8>>> {
        collect_complete(self.decode_slots_hier(codec)?)
    }
}

fn collect_complete(slots: Vec<Option<Vec<u8>>>) -> Result<Vec<Vec<u8>>> {
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.ok_or_else(|| anyhow!("image {i} is missing (its page was lost)")))
        .collect()
}

/// Serialize the redundant trailer index for `entries` — the single
/// source of the trailer layout, shared by the one-shot serializer and
/// the streaming writer's finalize step (byte-identity by construction).
fn trailer_bytes(entries: &[IndexEntry]) -> Vec<u8> {
    let mut t = Vec::with_capacity(TRAILER_FIXED + entries.len() * INDEX_ENTRY_LEN);
    t.extend_from_slice(&INDEX_MAGIC);
    t.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for e in entries {
        t.extend_from_slice(&e.offset.to_le_bytes());
        t.extend_from_slice(&e.frame_len.to_le_bytes());
        t.extend_from_slice(&e.first_image.to_le_bytes());
        t.extend_from_slice(&e.num_images.to_le_bytes());
        t.extend_from_slice(&e.crc.to_le_bytes());
    }
    let index_crc = crc32::hash(&t);
    t.extend_from_slice(&index_crc.to_le_bytes());
    let trailer_len = (t.len() + 4) as u32;
    t.extend_from_slice(&trailer_len.to_le_bytes());
    t
}

/// Validate one complete trailer block (`[start, end)` bytes of a file,
/// magic through trailer_len). `None` if any part fails validation.
fn parse_trailer_block(block: &[u8]) -> Option<Vec<IndexEntry>> {
    if block.len() < TRAILER_FIXED || block[..4] != INDEX_MAGIC {
        return None;
    }
    let n = u32::from_le_bytes(block[4..8].try_into().unwrap()) as usize;
    // Checked arithmetic: a crafted count must not overflow the length
    // formula (and the block length itself bounds any allocation).
    let want = n
        .checked_mul(INDEX_ENTRY_LEN)
        .and_then(|e| e.checked_add(TRAILER_FIXED))?;
    if block.len() != want {
        return None;
    }
    let crc_at = 8 + n * INDEX_ENTRY_LEN;
    let stored = u32::from_le_bytes(block[crc_at..crc_at + 4].try_into().unwrap());
    if crc32::hash(&block[..crc_at]) != stored {
        return None;
    }
    let declared =
        u32::from_le_bytes(block[crc_at + 4..crc_at + 8].try_into().unwrap()) as usize;
    if declared != block.len() {
        return None;
    }
    let mut entries = Vec::with_capacity(n);
    let mut at = 8;
    for _ in 0..n {
        entries.push(IndexEntry {
            offset: u64::from_le_bytes(block[at..at + 8].try_into().unwrap()),
            frame_len: u32::from_le_bytes(block[at + 8..at + 12].try_into().unwrap()),
            first_image: u32::from_le_bytes(block[at + 12..at + 16].try_into().unwrap()),
            num_images: u32::from_le_bytes(block[at + 16..at + 20].try_into().unwrap()),
            crc: u32::from_le_bytes(block[at + 20..at + 24].try_into().unwrap()),
        });
        at += INDEX_ENTRY_LEN;
    }
    Some(entries)
}

/// Locate and validate the redundant trailer index from the tail of the
/// file. Returns the entries and the byte range `[start, end)` the
/// trailer occupies, or `None` if any part of it fails validation — in
/// particular when `trailer_len` claims more bytes than the file holds
/// (a truncated tail must degrade to "index missing", never panic).
fn read_trailer_index(b: &[u8]) -> Option<(Vec<IndexEntry>, (usize, usize))> {
    if b.len() < TRAILER_FIXED {
        return None;
    }
    let trailer_len =
        u32::from_le_bytes(b[b.len() - 4..].try_into().unwrap()) as usize;
    if trailer_len < TRAILER_FIXED || trailer_len > b.len() {
        return None;
    }
    let start = b.len() - trailer_len;
    let entries = parse_trailer_block(&b[start..])?;
    Some((entries, (start, b.len())))
}

// ---------------------------------------------------------------------------
// Crash-consistent streaming: incremental journaled writer, reopen-and-
// resume recovery, bounded-memory page reader. See `format::stream` for
// the journal record format and the durability ordering invariant.
// ---------------------------------------------------------------------------

/// Longest valid prefix of a streamed (possibly torn or still-growing)
/// BBC4 file: the CRC-checked header plus every consecutive leading page
/// that validates against the header's tiling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamPrefix {
    /// Consecutive intact leading pages.
    pub pages: u32,
    /// Images those pages code.
    pub images: u32,
    /// Byte length of the intact prefix (header + intact pages);
    /// everything past it is a torn tail.
    pub keep: usize,
    /// True iff the bytes are a strict-valid complete container
    /// (every page present plus the matching trailer index).
    pub complete: bool,
}

/// Scan detail the resume planner needs beyond the public numbers.
struct PrefixDetail {
    shell: Bbc4Container,
    header_len: usize,
    entries: Vec<IndexEntry>,
    /// End offset of each intact frame, in page order.
    ends: Vec<usize>,
    images: u32,
    complete: bool,
}

fn scan_stream_prefix(b: &[u8]) -> Result<PrefixDetail> {
    let (shell, header_len) = Bbc4Container::parse_header(b)?;
    let mut entries: Vec<IndexEntry> = Vec::new();
    let mut ends = Vec::new();
    let mut images = 0u32;
    let mut pos = header_len;
    for i in 0..shell.n_pages {
        match format::read_frame(b, pos) {
            FrameRead::Ok { frame, next }
                if frame.index == i && shell.admit_page(&frame).is_some() =>
            {
                entries.push(IndexEntry {
                    offset: pos as u64,
                    frame_len: (next - pos) as u32,
                    first_image: frame.first_image,
                    num_images: frame.num_images,
                    crc: frame.crc(),
                });
                images += frame.num_images;
                ends.push(next);
                pos = next;
            }
            _ => break,
        }
    }
    // Complete iff every page is present and the remainder is exactly the
    // matching trailer index (the strict reader's acceptance condition).
    let complete = entries.len() as u32 == shell.n_pages
        && match read_trailer_index(b) {
            Some((tentries, (s, e))) => {
                s == pos
                    && e == b.len()
                    && tentries.len() == entries.len()
                    && tentries.iter().zip(&entries).all(|(a, w)| {
                        a.offset == w.offset
                            && a.frame_len == w.frame_len
                            && a.first_image == w.first_image
                            && a.num_images == w.num_images
                            && a.crc == w.crc
                    })
            }
            None => false,
        };
    Ok(PrefixDetail {
        shell,
        header_len,
        entries,
        ends,
        images,
        complete,
    })
}

impl Bbc4Container {
    /// Validated empty shell for a streaming encode: same admission
    /// checks as [`Self::parse_header`], so a stream started from it
    /// always produces a strict-parseable file.
    pub fn new_shell(
        model: Bbc4Model,
        cfg: BbAnsConfig,
        pixels: u32,
        num_images: u32,
        n_pages: u32,
    ) -> Result<Self> {
        if pixels == 0 || pixels > 1 << 24 {
            bail!("implausible pixel count {pixels}");
        }
        check_decode_budget(num_images as u64, pixels as u64)?;
        if n_pages == 0 || n_pages > 1 << 20 {
            bail!("implausible page count {n_pages}");
        }
        let tiling = chunk_ranges(num_images as usize, n_pages as usize);
        if tiling.len() as u32 != n_pages {
            bail!("page count {n_pages} is inconsistent with {num_images} images");
        }
        cfg.validate()?;
        Ok(Self {
            cfg,
            pixels,
            num_images,
            n_pages,
            model,
            pages: Vec::new(),
        })
    }

    /// Scan the longest valid prefix of a streamed, fetched, or torn
    /// file. The wire-fetch client uses this to restart a dropped
    /// transfer at the last intact page; `resume` builds on the same
    /// scan. Errors only when the header itself does not validate.
    pub fn scan_prefix(b: &[u8]) -> Result<(Self, StreamPrefix)> {
        let d = scan_stream_prefix(b)?;
        let prefix = StreamPrefix {
            pages: d.entries.len() as u32,
            images: d.images,
            keep: d.ends.last().copied().unwrap_or(d.header_len),
            complete: d.complete,
        };
        Ok((d.shell, prefix))
    }
}

/// What `resume` decided to do with an interrupted file.
struct ResumePlan {
    /// Truncate the data medium to this many bytes (0 ⇒ rewrite the
    /// header from scratch).
    keep: usize,
    /// Truncate the journal to this many bytes (its valid-record prefix).
    journal_keep: usize,
    entries: Vec<IndexEntry>,
    images: u32,
    complete: bool,
}

/// Validate an interrupted `(data, journal)` pair against the encode we
/// expect to continue, and decide where to pick up. The data scan is the
/// source of truth; the journal is a cross-check that must agree (see
/// `format::stream` for why it can lag but never lead).
fn plan_stream_resume(shell: &Bbc4Container, data: &[u8], journal: &[u8]) -> Result<ResumePlan> {
    let expected = shell.header_bytes();
    let hl = expected.len();
    let (journal_keep, last) = journal_prefix(journal);

    if data.len() < hl {
        // Cut mid-header: nothing durable was claimed yet. Any byte that
        // is present must match the encode we are resuming.
        if data != &expected[..data.len()] {
            bail!("existing file was written by a different encode (header mismatch)");
        }
        if let Some(rec) = last {
            if rec.pages_done > 0 || rec.bytes_written > data.len() as u64 {
                bail!(
                    "journal records {} durable page(s) but the data file holds only a partial \
                     header — data was lost; run `salvage` on what remains",
                    rec.pages_done
                );
            }
        }
        return Ok(ResumePlan {
            keep: 0,
            journal_keep: 0,
            entries: Vec::new(),
            images: 0,
            complete: false,
        });
    }
    if data[..hl] != expected[..] {
        bail!("existing file was written by a different encode (header mismatch)");
    }

    let d = scan_stream_prefix(data)?;
    let pages = d.entries.len() as u32;
    match last {
        None => {
            if pages > 0 {
                bail!(
                    "data file holds {pages} intact page(s) but the journal has no valid \
                     record — the sidecar journal is missing or corrupt; run `salvage` instead"
                );
            }
        }
        Some(rec) => {
            if rec.pages_done > pages {
                bail!(
                    "journal records {} durable page(s) but only {pages} are intact on disk — \
                     data was lost beyond a torn tail; run `salvage` instead",
                    rec.pages_done
                );
            }
            // Validate the last journal record against the page frames it
            // claims: length, frame CRC, and image count must all agree.
            let p = rec.pages_done as usize;
            let want_bytes = if p == 0 { hl } else { d.ends[p - 1] } as u64;
            let want_crc = if p == 0 {
                crc32::hash(&expected)
            } else {
                d.entries[p - 1].crc
            };
            let want_images: u32 = d.entries[..p].iter().map(|e| e.num_images).sum();
            if rec.bytes_written != want_bytes
                || rec.last_crc != want_crc
                || rec.images_done != want_images
            {
                bail!(
                    "journal record (pages {}, bytes {}) does not match the data file \
                     (pages {pages}, bytes {want_bytes}) — mismatched sidecar journal?",
                    rec.pages_done,
                    rec.bytes_written
                );
            }
        }
    }
    Ok(ResumePlan {
        keep: d.ends.last().copied().unwrap_or(hl),
        journal_keep,
        entries: d.entries,
        images: d.images,
        complete: d.complete,
    })
}

/// Outcome of [`Bbc4StreamWriter::resume`]: either the file already
/// holds a complete strict-valid container (nothing to re-encode), or a
/// writer positioned at the exact next page.
pub enum Resumed<D: StreamMedium, J: StreamMedium> {
    /// The data file is already a strict-valid complete container; the
    /// file-backed path has removed the leftover journal.
    Complete,
    /// Continue encoding from `writer.pages_done()`.
    Writer(Box<Bbc4StreamWriter<D, J>>),
}

/// Crash-consistent incremental BBC4 encoder: appends one self-
/// delimiting CRC'd page frame per chunk to the data medium, commits a
/// durable journal record after every page (data synced first), and
/// finalizes the redundant trailer index in a single append on
/// [`Bbc4StreamWriter::finish`]. Uninterrupted output is byte-identical
/// to [`Bbc4Container::to_bytes`] of the one-shot encoder.
pub struct Bbc4StreamWriter<D: StreamMedium, J: StreamMedium> {
    shell: Bbc4Container,
    tiling: Vec<std::ops::Range<usize>>,
    header_crc: u32,
    data: D,
    journal: J,
    entries: Vec<IndexEntry>,
    images_done: u32,
    ledger: Option<Ledger>,
}

impl<D: StreamMedium, J: StreamMedium> Bbc4StreamWriter<D, J> {
    /// Start a fresh stream: truncates both media, writes the header to
    /// the data medium, syncs it, and commits the page-0 journal record.
    pub fn start(mut data: D, mut journal: J, shell: Bbc4Container) -> Result<Self> {
        let shell = Bbc4Container::new_shell(
            shell.model,
            shell.cfg,
            shell.pixels,
            shell.num_images,
            shell.n_pages,
        )?;
        data.truncate(0).context("truncate data medium")?;
        journal.truncate(0).context("truncate journal medium")?;
        let header = shell.header_bytes();
        data.append(&header).context("write header")?;
        data.sync().context("sync header")?;
        let tiling = chunk_ranges(shell.num_images as usize, shell.n_pages as usize);
        let mut w = Self {
            header_crc: crc32::hash(&header),
            shell,
            tiling,
            data,
            journal,
            entries: Vec::new(),
            images_done: 0,
            ledger: None,
        };
        w.commit_journal()?;
        Ok(w)
    }

    /// Resume an interrupted stream from its current `(data, journal)`
    /// bytes: validates both against the expected encode, truncates the
    /// torn tails off both media, and returns a writer positioned at the
    /// exact next page (or [`Resumed::Complete`]).
    pub fn resume_media(
        mut data: D,
        mut journal: J,
        data_bytes: &[u8],
        journal_bytes: &[u8],
        shell: Bbc4Container,
    ) -> Result<Resumed<D, J>> {
        let shell = Bbc4Container::new_shell(
            shell.model,
            shell.cfg,
            shell.pixels,
            shell.num_images,
            shell.n_pages,
        )?;
        let plan = plan_stream_resume(&shell, data_bytes, journal_bytes)?;
        if plan.complete {
            return Ok(Resumed::Complete);
        }
        if plan.keep == 0 {
            return Ok(Resumed::Writer(Box::new(Self::start(data, journal, shell)?)));
        }
        data.truncate(plan.keep as u64).context("truncate torn tail")?;
        data.sync().context("sync truncated data")?;
        journal
            .truncate(plan.journal_keep as u64)
            .context("truncate journal tail")?;
        let header_crc = crc32::hash(&shell.header_bytes());
        let tiling = chunk_ranges(shell.num_images as usize, shell.n_pages as usize);
        let mut w = Self {
            shell,
            tiling,
            header_crc,
            data,
            journal,
            entries: plan.entries,
            images_done: plan.images,
            ledger: None,
        };
        // Re-anchor the journal with one fresh record describing the
        // validated state (the old tail may have lagged the data).
        w.commit_journal()?;
        Ok(Resumed::Writer(Box::new(w)))
    }

    fn commit_journal(&mut self) -> Result<()> {
        let rec = JournalRecord {
            pages_done: self.entries.len() as u32,
            images_done: self.images_done,
            bytes_written: self.data.len(),
            last_crc: self.entries.last().map(|e| e.crc).unwrap_or(self.header_crc),
        };
        self.journal
            .append(&rec.to_bytes())
            .context("append journal record")?;
        self.journal.sync().context("sync journal")
    }

    /// Pages already durable (and journaled) on the data medium.
    pub fn pages_done(&self) -> u32 {
        self.entries.len() as u32
    }

    /// Images those pages code.
    pub fn images_done(&self) -> u32 {
        self.images_done
    }

    /// Durable data-medium length in bytes.
    pub fn bytes_written(&self) -> u64 {
        self.data.len()
    }

    /// True when every page has been encoded (only `finish` remains).
    pub fn is_done(&self) -> bool {
        self.pages_done() == self.shell.n_pages
    }

    /// The header shell this stream encodes under.
    pub fn shell(&self) -> &Bbc4Container {
        &self.shell
    }

    /// Attach a rate ledger: subsequent pages record per-image bit
    /// accounting ([`Ledger`] entries survive a resume by construction —
    /// a resumed writer's ledger covers exactly the pages it encodes, so
    /// merging the interrupted and resumed ledgers reproduces the
    /// uninterrupted encode's entries).
    pub fn enable_ledger(&mut self) {
        if self.ledger.is_none() {
            self.ledger = Some(Ledger::new());
        }
    }

    /// Take the accumulated ledger (entries for pages encoded by *this*
    /// writer instance, in page order).
    pub fn take_ledger(&mut self) -> Option<Ledger> {
        self.ledger.take()
    }

    fn check_encode_inputs(&self, pixels: usize, cfg: &BbAnsConfig, n: usize) -> Result<()> {
        if self.shell.pixels as usize != pixels {
            bail!(
                "stream holds {}-pixel images, model wants {pixels}",
                self.shell.pixels
            );
        }
        if &self.shell.cfg != cfg {
            bail!("codec config does not match the stream header");
        }
        if n != self.shell.num_images as usize {
            bail!(
                "stream encodes {} images, caller supplied {n}",
                self.shell.num_images
            );
        }
        Ok(())
    }

    /// Frame the message as the next page, make it durable, then commit
    /// its journal record (strictly in that order — the resume
    /// invariant).
    fn append_page(&mut self, message: AnsMessage) -> Result<()> {
        let i = self.entries.len();
        let r = &self.tiling[i];
        let frame = PageFrame {
            index: i as u32,
            first_image: r.start as u32,
            num_images: r.len() as u32,
            payload: message.to_bytes(),
        };
        let offset = self.data.len();
        let mut buf = Vec::with_capacity(frame.byte_len());
        frame.write_to(&mut buf);
        self.data
            .append(&buf)
            .with_context(|| format!("append page {i}"))?;
        self.data.sync().with_context(|| format!("sync page {i}"))?;
        self.entries.push(IndexEntry {
            offset,
            frame_len: buf.len() as u32,
            first_image: frame.first_image,
            num_images: frame.num_images,
            crc: frame.crc(),
        });
        self.images_done += frame.num_images;
        self.commit_journal()
    }

    /// Encode the next page with a single-layer codec. `images` is the
    /// full dataset; the page's chunk is selected by the deterministic
    /// tiling, and its chain is seeded exactly like the one-shot chunked
    /// encoder's — bit-identity by construction. Returns `false` when
    /// every page is already written.
    pub fn encode_next_vae<B: Backend + ?Sized>(
        &mut self,
        codec: &VaeCodec<'_, B>,
        images: &[Vec<u8>],
    ) -> Result<bool> {
        if self.is_done() {
            return Ok(false);
        }
        if !matches!(self.shell.model, Bbc4Model::Vae { .. }) {
            bail!("stream codes a hierarchical model; use encode_next_hier");
        }
        self.check_encode_inputs(codec.backend().meta().pixels, &codec.cfg, images.len())?;
        let ci = self.entries.len();
        let chunk = &images[self.tiling[ci].clone()];
        let mut ans = Ans::new(chunk_seed(self.shell.cfg.clean_seed, ci));
        let mut scratch = CodecScratch::new();
        if self.ledger.is_some() {
            scratch.ledger = Some(Box::default());
        }
        codec
            .encode_dataset_into_scratch(&mut ans, chunk, &mut scratch)
            .with_context(|| format!("page {ci}"))?;
        if let Some(l) = &mut self.ledger {
            l.merge(*scratch.ledger.take().expect("installed above"));
        }
        self.append_page(ans.into_message())?;
        Ok(true)
    }

    /// [`Self::encode_next_vae`] for hierarchical chains.
    pub fn encode_next_hier<B: HierBackend + ?Sized>(
        &mut self,
        codec: &HierCodec<'_, B>,
        images: &[Vec<u8>],
    ) -> Result<bool> {
        if self.is_done() {
            return Ok(false);
        }
        let Bbc4Model::Hier { schedule, .. } = &self.shell.model else {
            bail!("stream codes a single-layer model; use encode_next_vae");
        };
        if *schedule != codec.schedule {
            bail!(
                "stream was started with the {} schedule, codec uses {}",
                schedule.name(),
                codec.schedule.name()
            );
        }
        self.check_encode_inputs(codec.backend().meta().pixels, &codec.cfg, images.len())?;
        let ci = self.entries.len();
        let chunk = &images[self.tiling[ci].clone()];
        let mut ans = Ans::new(chunk_seed(self.shell.cfg.clean_seed, ci));
        let mut scratch = HierScratch::new();
        if self.ledger.is_some() {
            scratch.codec.ledger = Some(Box::default());
        }
        codec
            .encode_dataset_into_scratch(&mut ans, chunk, &mut scratch)
            .with_context(|| format!("page {ci}"))?;
        if let Some(l) = &mut self.ledger {
            l.merge(*scratch.codec.ledger.take().expect("installed above"));
        }
        self.append_page(ans.into_message())?;
        Ok(true)
    }

    /// Atomically finalize: append the redundant trailer index in ONE
    /// write and sync. The file becomes strict-valid at that instant;
    /// the caller then retires the journal (file-backed: delete it).
    pub fn finish(mut self) -> Result<(D, J)> {
        if !self.is_done() {
            bail!(
                "stream has {} of {} pages; cannot finalize",
                self.entries.len(),
                self.shell.n_pages
            );
        }
        self.data
            .append(&trailer_bytes(&self.entries))
            .context("append trailer index")?;
        self.data.sync().context("sync trailer")?;
        Ok((self.data, self.journal))
    }
}

impl Bbc4StreamWriter<FileMedium, FileMedium> {
    /// Start a fresh file-backed stream at `path`, with the progress
    /// journal in the `<path>.journal` sidecar.
    pub fn create(path: &Path, shell: Bbc4Container) -> Result<Self> {
        let data =
            FileMedium::create(path).with_context(|| format!("create {}", path.display()))?;
        let jp = journal_path(path);
        let journal =
            FileMedium::create(&jp).with_context(|| format!("create {}", jp.display()))?;
        Self::start(data, journal, shell)
    }

    /// Reopen an interrupted file-backed stream: scans `path`, validates
    /// the last journal record against the page frames, truncates any
    /// torn tail, and continues at the exact next image. If the file is
    /// already complete the leftover journal is removed.
    pub fn resume(path: &Path, shell: Bbc4Container) -> Result<Resumed<FileMedium, FileMedium>> {
        let jp = journal_path(path);
        let mut data =
            FileMedium::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut journal =
            FileMedium::open(&jp).with_context(|| format!("open {}", jp.display()))?;
        let db = data
            .read_all()
            .with_context(|| format!("read {}", path.display()))?;
        let jb = journal
            .read_all()
            .with_context(|| format!("read {}", jp.display()))?;
        match Self::resume_media(data, journal, &db, &jb, shell)? {
            Resumed::Complete => {
                std::fs::remove_file(&jp)
                    .with_context(|| format!("remove {}", jp.display()))?;
                Ok(Resumed::Complete)
            }
            w => Ok(w),
        }
    }

    /// [`Self::finish`] plus journal retirement: the sidecar is deleted
    /// once the trailer is durable, marking the encode complete.
    pub fn finish_file(self) -> Result<()> {
        let (_data, journal) = self.finish()?;
        journal.remove().context("remove journal sidecar")?;
        Ok(())
    }
}

/// Upper bound on the header bytes [`Bbc4StreamReader::open`] reads up
/// front (real headers are well under 2 KiB).
const MAX_HEADER_SCAN: usize = 1 << 16;

/// Bounded-memory page reader: decodes a BBC4 file page-at-a-time from
/// any `Read + Seek` without materializing the file. Requires the intact
/// trailer index (it is the seek map); damaged files go through
/// [`Bbc4Container::salvage`] instead.
pub struct Bbc4StreamReader<R: Read + Seek> {
    src: R,
    shell: Bbc4Container,
    entries: Vec<IndexEntry>,
    header_len: usize,
    trailer: Vec<u8>,
    next: usize,
}

impl<R: Read + Seek> Bbc4StreamReader<R> {
    /// Validate header, trailer index, and page layout (offsets, tiling,
    /// contiguity) without reading any page payload.
    pub fn open(mut src: R) -> Result<Self> {
        let file_len = src.seek(SeekFrom::End(0)).context("seek to end")?;
        let head_take = (file_len as usize).min(MAX_HEADER_SCAN);
        src.rewind().context("rewind")?;
        let mut head = vec![0u8; head_take];
        src.read_exact(&mut head).context("read header")?;
        let (shell, header_len) = Bbc4Container::parse_header(&head)?;
        if file_len < TRAILER_FIXED as u64 {
            bail!("BBC4 trailer index missing or damaged (file is {file_len} bytes)");
        }
        src.seek(SeekFrom::End(-4)).context("seek to trailer_len")?;
        let mut lenb = [0u8; 4];
        src.read_exact(&mut lenb).context("read trailer_len")?;
        let trailer_len = u32::from_le_bytes(lenb) as u64;
        if trailer_len < TRAILER_FIXED as u64 || trailer_len > file_len {
            bail!(
                "BBC4 trailer index missing or damaged \
                 (trailer_len {trailer_len}, file {file_len} bytes)"
            );
        }
        let trailer_start = file_len - trailer_len;
        src.seek(SeekFrom::Start(trailer_start)).context("seek to trailer")?;
        let mut trailer = vec![0u8; trailer_len as usize];
        src.read_exact(&mut trailer).context("read trailer")?;
        let entries = parse_trailer_block(&trailer)
            .ok_or_else(|| anyhow!("BBC4 trailer index missing or damaged"))?;
        if entries.len() != shell.n_pages as usize {
            bail!(
                "trailer index lists {} pages, header declares {}",
                entries.len(),
                shell.n_pages
            );
        }
        let tiling = chunk_ranges(shell.num_images as usize, shell.n_pages as usize);
        let mut pos = header_len as u64;
        for (i, e) in entries.iter().enumerate() {
            if e.offset != pos {
                bail!(
                    "trailer entry {i} puts its page at offset {}, but pages are \
                     contiguous from {pos}",
                    e.offset
                );
            }
            let flen = e.frame_len as usize;
            if !(format::FRAME_OVERHEAD..=format::MAX_BODY + format::FRAME_OVERHEAD)
                .contains(&flen)
            {
                bail!("trailer entry {i} has implausible frame length {flen}");
            }
            let r = &tiling[i];
            if e.first_image as usize != r.start || e.num_images as usize != r.len() {
                bail!(
                    "trailer entry {i} claims images [{}, +{}), expected [{}, +{})",
                    e.first_image,
                    e.num_images,
                    r.start,
                    r.len()
                );
            }
            pos += e.frame_len as u64;
        }
        if pos != trailer_start {
            bail!("pages end at {pos} but the trailer starts at {trailer_start}");
        }
        Ok(Self {
            src,
            shell,
            entries,
            header_len,
            trailer,
            next: 0,
        })
    }

    /// The parsed header shell (no pages held — that is the point).
    pub fn shell(&self) -> &Bbc4Container {
        &self.shell
    }

    /// Total pages in the file.
    pub fn n_pages(&self) -> u32 {
        self.shell.n_pages
    }

    /// Header byte length.
    pub fn header_len(&self) -> usize {
        self.header_len
    }

    /// Raw header bytes (the wire-fetch server sends these verbatim).
    pub fn header_raw(&mut self) -> Result<Vec<u8>> {
        self.src.rewind().context("rewind to header")?;
        let mut buf = vec![0u8; self.header_len];
        self.src.read_exact(&mut buf).context("read header")?;
        Ok(buf)
    }

    /// Raw trailer-index bytes.
    pub fn trailer_raw(&self) -> &[u8] {
        &self.trailer
    }

    fn frame_at(&mut self, i: usize) -> Result<(Vec<u8>, PageFrame)> {
        let e = self
            .entries
            .get(i)
            .ok_or_else(|| anyhow!("page {i} out of range"))?;
        let (offset, len, crc) = (e.offset, e.frame_len as usize, e.crc);
        self.src
            .seek(SeekFrom::Start(offset))
            .with_context(|| format!("seek to page {i}"))?;
        let mut buf = vec![0u8; len];
        self.src
            .read_exact(&mut buf)
            .with_context(|| format!("read page {i}"))?;
        match format::read_frame(&buf, 0) {
            FrameRead::Ok { frame, next }
                if next == buf.len() && frame.index == i as u32 && frame.crc() == crc =>
            {
                Ok((buf, frame))
            }
            FrameRead::Ok { .. } => bail!("page {i} does not match its trailer index entry"),
            FrameRead::NoMagic => bail!("page {i}: no frame magic at the indexed offset"),
            FrameRead::Truncated { need, have } => {
                bail!("page {i} truncated: frame needs {need} bytes, read {have}")
            }
            FrameRead::Damaged { detail } => bail!("page {i}: {detail}"),
        }
    }

    /// Raw frame bytes for page `i` plus the CRC the trailer index (and
    /// the wire protocol's per-page echo) records for it.
    pub fn raw_frame(&mut self, i: usize) -> Result<(Vec<u8>, u32)> {
        let crc = self.entries[..]
            .get(i)
            .map(|e| e.crc)
            .ok_or_else(|| anyhow!("page {i} out of range"))?;
        let (buf, _) = self.frame_at(i)?;
        Ok((buf, crc))
    }

    /// Validated page `i` (admitted against the header tiling).
    pub fn page(&mut self, i: usize) -> Result<Bbc4Page> {
        let (_, frame) = self.frame_at(i)?;
        self.shell
            .admit_page(&frame)
            .ok_or_else(|| anyhow!("page {i} fails admission against the header tiling"))
    }

    /// Sequential page cursor; `None` after the last page.
    pub fn next_page(&mut self) -> Result<Option<Bbc4Page>> {
        if self.next >= self.entries.len() {
            return Ok(None);
        }
        let p = self.page(self.next)?;
        self.next += 1;
        Ok(Some(p))
    }

    /// Decode the next page's images with a single-layer codec. Returns
    /// `(first_image, images)`; memory high-water is one page.
    pub fn decode_next_vae<B: Backend + ?Sized>(
        &mut self,
        codec: &VaeCodec<'_, B>,
    ) -> Result<Option<(u32, Vec<Vec<u8>>)>> {
        if !matches!(self.shell.model, Bbc4Model::Vae { .. }) {
            bail!("container codes a hierarchical model; decode it with a HierCodec");
        }
        self.shell
            .validate_common(codec.backend().meta().pixels, &codec.cfg)?;
        let Some(p) = self.next_page()? else {
            return Ok(None);
        };
        let mut ans =
            Ans::from_message(&p.message, chunk_seed(self.shell.cfg.clean_seed, p.index as usize));
        let imgs = codec
            .decode_dataset(&mut ans, p.num_images as usize)
            .with_context(|| format!("page {}", p.index))?;
        Ok(Some((p.first_image, imgs)))
    }

    /// [`Self::decode_next_vae`] for hierarchical pages.
    pub fn decode_next_hier<B: HierBackend + ?Sized>(
        &mut self,
        codec: &HierCodec<'_, B>,
    ) -> Result<Option<(u32, Vec<Vec<u8>>)>> {
        let Bbc4Model::Hier { schedule, .. } = &self.shell.model else {
            bail!("container codes a single-layer model; decode it with a VaeCodec");
        };
        if *schedule != codec.schedule {
            bail!(
                "container was coded with the {} schedule, codec uses {}",
                schedule.name(),
                codec.schedule.name()
            );
        }
        self.shell
            .validate_common(codec.backend().meta().pixels, &codec.cfg)?;
        let Some(p) = self.next_page()? else {
            return Ok(None);
        };
        let mut ans =
            Ans::from_message(&p.message, chunk_seed(self.shell.cfg.clean_seed, p.index as usize));
        let imgs = codec
            .decode_dataset(&mut ans, p.num_images as usize)
            .with_context(|| format!("page {}", p.index))?;
        Ok(Some((p.first_image, imgs)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ans::RANS_L;
    use crate::model::hierarchy::HierMeta;
    use crate::model::vae::NativeVae;
    use crate::model::ModelMeta;
    use crate::util::rng::Rng;

    fn sample_bbc4() -> Bbc4Container {
        Bbc4Container {
            cfg: BbAnsConfig {
                latent_bits: 12,
                posterior_prec: 24,
                pixel_prec: 16,
                clean_seed: 7,
            },
            pixels: 4,
            num_images: 1,
            n_pages: 1,
            model: Bbc4Model::Vae {
                model: "m".into(),
                backend_id: "native".into(),
            },
            pages: vec![Bbc4Page {
                index: 0,
                first_image: 0,
                num_images: 1,
                message: AnsMessage {
                    head: RANS_L + 3,
                    stream: vec![0xAABB_CCDD],
                    clean_words_used: 2,
                },
            }],
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let c = sample_bbc4();
        let bytes = c.to_bytes();
        let c2 = Bbc4Container::from_bytes(&bytes).unwrap();
        assert_eq!(c, c2);
    }

    fn toy_backend() -> NativeVae {
        NativeVae::random(
            ModelMeta {
                name: "toy".into(),
                pixels: 16,
                latent_dim: 4,
                hidden: 8,
                likelihood: Likelihood::Bernoulli,
                test_elbo_bpd: f64::NAN,
            },
            2024,
        )
    }

    fn toy_images(n: usize, pixels: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..pixels).map(|_| (rng.f64() < 0.3) as u8).collect())
            .collect()
    }

    #[test]
    fn vae_end_to_end_roundtrip() {
        let backend = toy_backend();
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = toy_images(11, 16, 5);
        let c = Bbc4Container::encode_vae_with_workers(&codec, &images, 3, 2).unwrap();
        assert_eq!(c.n_pages, 3);
        assert_eq!(c.num_images, 11);
        let bytes = c.to_bytes();
        let parsed = Bbc4Container::from_bytes(&bytes).unwrap();
        assert_eq!(parsed, c);
        assert_eq!(parsed.decode_vae(&codec).unwrap(), images);
        // The strict bytes also salvage cleanly with a clean report.
        let s = Bbc4Container::salvage(&bytes).unwrap();
        assert!(s.report.is_clean(), "{:?}", s.report);
        assert_eq!(s.container, parsed);
    }

    #[test]
    fn hier_end_to_end_roundtrip() {
        let meta = HierMeta {
            name: "hier2".into(),
            pixels: 9,
            dims: vec![4, 3],
            hidden: 8,
            likelihood: Likelihood::Bernoulli,
        };
        let backend = HierVae::random(meta, 42);
        let images = toy_images(7, 9, 9);
        for schedule in [Schedule::Naive, Schedule::BitSwap] {
            let codec = HierCodec::new(&backend, BbAnsConfig::default(), schedule).unwrap();
            let c = Bbc4Container::encode_hier_with_workers(&codec, &images, 2, 2).unwrap();
            let bytes = c.to_bytes();
            let parsed = Bbc4Container::from_bytes(&bytes).unwrap();
            // Self-describing: rebuild the backend from the header alone.
            let rebuilt = parsed.build_hier_backend().unwrap();
            assert_eq!(rebuilt.backend_id(), backend.backend_id());
            let codec2 = HierCodec::new(&rebuilt, parsed.cfg, schedule).unwrap();
            assert_eq!(parsed.decode_hier(&codec2).unwrap(), images);
        }
    }

    /// Find the byte range of page `i`'s frame in a serialized container
    /// (via the trailer index, which tests may then damage).
    fn page_range(bytes: &[u8], i: usize) -> (usize, usize) {
        let (entries, _) = read_trailer_index(bytes).expect("intact trailer");
        let e = &entries[i];
        (e.offset as usize, e.offset as usize + e.frame_len as usize)
    }

    #[test]
    fn salvage_skips_damaged_page_and_reports_it() {
        let backend = toy_backend();
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = toy_images(12, 16, 6);
        let c = Bbc4Container::encode_vae_with_workers(&codec, &images, 4, 1).unwrap();
        let clean = c.to_bytes();
        let (s1, _e1) = page_range(&clean, 1);

        // Flip one payload bit inside page 1.
        let mut bad = clean.clone();
        bad[s1 + format::FRAME_OVERHEAD - 4] ^= 0x10;
        assert!(Bbc4Container::from_bytes(&bad).is_err());
        let s = Bbc4Container::salvage(&bad).unwrap();
        assert_eq!(s.report.pages_lost, vec![1]);
        assert_eq!(s.report.pages_recovered, 3);
        assert!(s.report.index_intact);
        let tiling = chunk_ranges(12, 4);
        let want_lost: Vec<u32> = tiling[1].clone().map(|i| i as u32).collect();
        assert_eq!(s.report.images_lost, want_lost);
        // Damage footprint covers the damaged page and nothing else.
        assert_eq!(s.report.damaged_ranges.len(), 1);

        // Every intact image decodes bit-exactly.
        let slots = s.container.decode_slots_vae(&codec).unwrap();
        for (i, slot) in slots.iter().enumerate() {
            if want_lost.contains(&(i as u32)) {
                assert!(slot.is_none(), "image {i} should be lost");
            } else {
                assert_eq!(slot.as_deref(), Some(&images[i][..]), "image {i}");
            }
        }
    }

    #[test]
    fn salvage_recovers_smashed_magic_via_index() {
        let backend = toy_backend();
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = toy_images(9, 16, 7);
        let c = Bbc4Container::encode_vae_with_workers(&codec, &images, 3, 1).unwrap();
        let clean = c.to_bytes();
        let (s1, _) = page_range(&clean, 1);

        // Destroy page 1's resync magic: the forward scan cannot find it,
        // but the trailer index still locates and validates the body.
        let mut bad = clean.clone();
        bad[s1..s1 + 4].copy_from_slice(&[0; 4]);
        let s = Bbc4Container::salvage(&bad).unwrap();
        assert!(s.report.pages_lost.is_empty(), "{:?}", s.report);
        assert_eq!(s.container.decode_vae(&codec).unwrap(), images);
    }

    #[test]
    fn salvage_survives_truncation_and_dead_index() {
        let backend = toy_backend();
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = toy_images(10, 16, 8);
        let c = Bbc4Container::encode_vae_with_workers(&codec, &images, 5, 1).unwrap();
        let clean = c.to_bytes();
        let (s3, _) = page_range(&clean, 3);

        // Truncate mid-page-3: the index and pages 3..5 are gone; pages
        // 0..3 must still come back through the forward scan alone.
        let bad = &clean[..s3 + 10];
        let s = Bbc4Container::salvage(bad).unwrap();
        assert!(!s.report.index_intact);
        assert_eq!(s.report.pages_lost, vec![3, 4]);
        let slots = s.container.decode_slots_vae(&codec).unwrap();
        let tiling = chunk_ranges(10, 5);
        for i in 0..10 {
            let lost = tiling[3].contains(&i) || tiling[4].contains(&i);
            assert_eq!(slots[i].is_none(), lost, "image {i}");
            if !lost {
                assert_eq!(slots[i].as_deref(), Some(&images[i][..]));
            }
        }
    }

    #[test]
    fn salvage_resyncs_over_zero_filled_region() {
        let backend = toy_backend();
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = toy_images(12, 16, 11);
        let c = Bbc4Container::encode_vae_with_workers(&codec, &images, 4, 1).unwrap();
        let clean = c.to_bytes();
        let (s0, e0) = page_range(&clean, 0);

        // Zero-fill page 0 entirely (magic included) — the scanner must
        // resync at page 1 and the index adds nothing for page 0.
        let mut bad = clean.clone();
        bad[s0..e0].fill(0);
        let s = Bbc4Container::salvage(&bad).unwrap();
        assert_eq!(s.report.pages_lost, vec![0]);
        assert_eq!(s.report.pages_recovered, 3);
        assert_eq!(s.report.damaged_ranges, vec![(s0, e0)]);
    }

    #[test]
    fn damaged_header_is_unrecoverable_but_clean() {
        let c = sample_bbc4();
        let mut bytes = c.to_bytes();
        bytes[8] ^= 0xFF; // inside the CRC-protected header
        let err = Bbc4Container::salvage(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("header"), "{err:#}");
    }

    #[test]
    fn strict_reader_rejects_crafted_page_ranges() {
        // A page claiming a range outside the deterministic tiling must
        // be rejected even though its own CRC is valid.
        let c = sample_bbc4();
        let mut tampered = c.clone();
        tampered.pages[0].first_image = 1;
        let bytes = tampered.to_bytes();
        let err = Bbc4Container::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("claims images"), "{err:#}");
    }

    #[test]
    fn kind_mismatch_is_rejected_at_decode() {
        let backend = toy_backend();
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = toy_images(3, 16, 13);
        let c = Bbc4Container::encode_vae_with_workers(&codec, &images, 1, 1).unwrap();
        assert!(c.build_hier_backend().is_err());
        let meta = HierMeta {
            name: "hier2".into(),
            pixels: 16,
            dims: vec![4, 3],
            hidden: 8,
            likelihood: Likelihood::Bernoulli,
        };
        let hb = HierVae::random(meta, 5);
        let hcodec = HierCodec::new(&hb, BbAnsConfig::default(), Schedule::BitSwap).unwrap();
        assert!(c.decode_slots_hier(&hcodec).is_err());
    }
}
