//! BB-ANS — the paper's contribution: bits-back coding chained through the
//! LIFO structure of ANS (paper §2.3–2.4, Table 1).
//!
//! Encoding one image `s` with a latent-variable model:
//!
//! 1. **pop**  `y ~ q(y|s)` — "decode" the latent from the stack (this is
//!    the bits-back step: it *consumes* stack bits, using them as the
//!    random source for the posterior sample);
//! 2. **push** `s` under `p(s|y)` — code the pixels with the likelihood;
//! 3. **push** `y` under `p(y)` — code the latent with the prior.
//!
//! Decoding runs the exact inverse (pop prior, pop likelihood, push
//! posterior), which also *returns* the borrowed bits — so chaining images
//! costs `−ELBO` bits each with zero per-image overhead. That zero-overhead
//! chaining is exactly what ANS's stack discipline buys over arithmetic
//! coding (Frey's AC-based chaining paid a flush per image).
//!
//! The latent is continuous; it is discretized into max-entropy buckets of
//! the prior (paper §2.5.1 + Appendix B): under the prior the bucket index
//! is exactly uniform, so prior coding is lossless-in-rate, and the
//! posterior is coded over the *same* buckets via
//! [`crate::codecs::gaussian::DiscretizedGaussian`].

pub mod bbc4;
pub mod container;
pub mod hierarchy;
pub mod timeseries;

use anyhow::{bail, Result};

use crate::ans::{Ans, EntropyCoder, Interval, PreparedInterval};
use crate::codecs::beta_binomial::{BetaBinomial, BetaBinomialDirect};
use crate::codecs::categorical::Bernoulli;
use crate::codecs::gaussian::{DiscretizedGaussian, MaxEntropyBuckets};
use crate::codecs::uniform::Uniform;
use crate::codecs::SymbolCodec;
use crate::model::tensor::Matrix;
use crate::model::{Backend, Likelihood, ModelMeta, PixelParams, PosteriorBatch};

/// Images per recognition-net dispatch in the dataset loops: one
/// [`Backend::encode_batch`] call covers this many rows. Both the
/// sequential and the pipelined encode paths chunk identically, so their
/// NN inputs — and therefore their bitstreams — are identical.
pub const NN_CHUNK: usize = 64;

/// Reusable buffers for the per-image coding loops (ISSUE 2): one scratch
/// per chain/thread removes every per-pixel and per-image heap allocation
/// from the hot path — the prepared-symbol vector that used to be
/// `collect()`ed fresh per image, and the f64 PMF row the table-backed
/// beta-binomial codec used to allocate per *pixel*.
#[derive(Debug, Default)]
pub struct CodecScratch {
    /// Per-pixel prepared symbols for the likelihood encode.
    prepared: Vec<PreparedInterval>,
    /// Widened f64 PMF row for `BetaBinomial::from_pmf_row_scratch`.
    pmf: Vec<f64>,
    /// Per-pixel direct beta-binomial codecs, batch-built once per image
    /// by the SIMD lane-parallel [`BetaBinomialDirect::new_batch`]
    /// (ISSUE 5); empty for non-`BetaBinomialAb` params.
    direct: Vec<BetaBinomialDirect>,
    /// Latent bucket-index buffer for the posterior/prior steps. Public
    /// (like `gauss`) so multi-stream callers such as the coordinator can
    /// `mem::take` it around the batched NN dispatches.
    pub idx: Vec<u32>,
    /// Cached posterior codec: built once, then only `(mu, sigma)` change
    /// per dimension — no `MaxEntropyBuckets` clone or
    /// `DiscretizedGaussian` construction per latent (ISSUE 3).
    pub gauss: Option<DiscretizedGaussian>,
    /// Optional rate-ledger sink (ISSUE 9): when set, every encoded image
    /// appends a [`crate::obs::LedgerEntry`]. A pure observer of the
    /// effective-length measure — it never touches the coder, so ledgered
    /// encodes emit byte-identical containers (pinned by golden tests in
    /// [`container`]). `None` costs one pointer check per image.
    pub ledger: Option<Box<crate::obs::Ledger>>,
}

impl CodecScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Scale raw pixel bytes into the f32 input of a recognition net,
/// appending to `out`. Shared by the single-layer and hierarchical codecs
/// (the scaling depends only on the likelihood family).
pub(crate) fn scale_pixels_into(likelihood: Likelihood, img: &[u8], out: &mut Vec<f32>) {
    match likelihood {
        Likelihood::Bernoulli => out.extend(img.iter().map(|&v| (v != 0) as u32 as f32)),
        Likelihood::BetaBinomial => out.extend(img.iter().map(|&v| v as f32 / 255.0)),
    }
}

/// Reusable-codec constructor for a discretized Gaussian over max-entropy
/// buckets: `slot` caches one `DiscretizedGaussian` whose `(mu, sigma)`
/// are updated in place per dimension (validity of the updated fields
/// matches what `DiscretizedGaussian::new` asserts — sanitized here).
/// Shared by the posterior path of [`VaeCodec`] and every Gaussian
/// conditional of [`hierarchy::HierCodec`].
pub(crate) fn gauss_codec_scratch<'g>(
    buckets: &MaxEntropyBuckets,
    prec: u32,
    mu: f32,
    sigma: f32,
    slot: &'g mut Option<DiscretizedGaussian>,
) -> &'g DiscretizedGaussian {
    // Guard against degenerate network outputs.
    let mu = if mu.is_finite() { mu as f64 } else { 0.0 };
    let sigma = if sigma.is_finite() && sigma > 0.0 {
        sigma as f64
    } else {
        1.0
    };
    match slot {
        // Reuse only if the cached geometry matches this codec (a scratch
        // may migrate between codecs with different configs).
        Some(g) if g.buckets.latent_bits == buckets.latent_bits && g.prec == prec => {
            g.mu = mu;
            g.sigma = sigma;
        }
        _ => {
            *slot = Some(DiscretizedGaussian::new(buckets.clone(), mu, sigma, prec));
        }
    }
    slot.as_ref().expect("slot populated above")
}

/// Batch-build the per-pixel direct codecs for one image's likelihood
/// params into a reusable buffer (ISSUE 5): for the analytic
/// beta-binomial head this is the SIMD lane-parallel
/// [`BetaBinomialDirect::new_batch`] — four pixels' normalization
/// recurrences per vector step, bit-identical to per-pixel construction —
/// and a cleared buffer otherwise ([`pixel_prepared`]/[`pixel_lookup`]
/// fall back to their per-pixel constructors when `direct` is empty).
pub(crate) fn prepare_pixel_codecs(
    params: &PixelParams,
    prec: u32,
    direct: &mut Vec<BetaBinomialDirect>,
) {
    match params {
        PixelParams::BetaBinomialAb { alpha, beta } => {
            BetaBinomialDirect::new_batch(255, alpha, beta, prec, direct)
        }
        _ => direct.clear(),
    }
}

/// Prepared (division-free) interval of pixel `p` taking value `sym` under
/// the likelihood params, at precision `prec`. `pmf` is the reusable f64
/// row buffer for the table path; `direct` the batch-built per-pixel
/// codecs from [`prepare_pixel_codecs`] (empty ⇒ construct per pixel,
/// bit-identical either way).
pub(crate) fn pixel_prepared(
    params: &PixelParams,
    p: usize,
    sym: u8,
    prec: u32,
    pmf: &mut Vec<f64>,
    direct: &[BetaBinomialDirect],
) -> PreparedInterval {
    match params {
        PixelParams::Bernoulli(probs) => {
            // Allocation-free fast path (§Perf #5), bit-identical to
            // Categorical::bernoulli.
            let c = Bernoulli::new(probs[p] as f64, prec);
            c.prepared_interval((sym != 0) as usize)
        }
        PixelParams::BetaBinomialAb { alpha, beta } => {
            // Lazy direct codec: O(sym) work, O(1) for the black
            // background pixels that dominate MNIST (§Perf #3); the
            // construction itself comes from the SIMD batch when the
            // caller prepared one.
            let c = direct.get(p).copied().unwrap_or_else(|| {
                BetaBinomialDirect::new(255, alpha[p] as f64, beta[p] as f64, prec)
            });
            c.prepared_interval(sym as u32)
        }
        PixelParams::BetaBinomialTable(table) => {
            let c = BetaBinomial::from_pmf_row_scratch(&table[p * 256..(p + 1) * 256], prec, pmf);
            let q = c.quantized();
            PreparedInterval::new(q.start(sym as usize), q.freq(sym as usize), prec)
        }
    }
}

/// Inverse of [`pixel_prepared`]: classify a cumulative value. Lookup is
/// O(1)/O(sym) for the Bernoulli and direct beta-binomial paths; the
/// per-pixel table path keeps the short binary search (a LUT would cost
/// more to build than the ~8 probes it saves on a single-lookup codec —
/// see `QuantizedCdf::build_lut`).
pub(crate) fn pixel_lookup(
    params: &PixelParams,
    p: usize,
    cf: u32,
    prec: u32,
    pmf: &mut Vec<f64>,
    direct: &[BetaBinomialDirect],
) -> (u8, Interval) {
    match params {
        PixelParams::Bernoulli(probs) => {
            let c = Bernoulli::new(probs[p] as f64, prec);
            let (sym, start, freq) = c.lookup(cf);
            (sym as u8, Interval { start, freq })
        }
        PixelParams::BetaBinomialAb { alpha, beta } => {
            let c = direct.get(p).copied().unwrap_or_else(|| {
                BetaBinomialDirect::new(255, alpha[p] as f64, beta[p] as f64, prec)
            });
            let (sym, start, freq) = c.lookup(cf);
            (sym as u8, Interval { start, freq })
        }
        PixelParams::BetaBinomialTable(table) => {
            let c = BetaBinomial::from_pmf_row_scratch(&table[p * 256..(p + 1) * 256], prec, pmf);
            let q = c.quantized();
            let sym = q.lookup(cf);
            (
                sym as u8,
                Interval {
                    start: q.start(sym),
                    freq: q.freq(sym),
                },
            )
        }
    }
}

/// Coding hyper-parameters (recorded in the container header; encoder and
/// decoder must agree).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BbAnsConfig {
    /// Latent discretization: 2^latent_bits buckets per dimension
    /// (paper §2.5.1: gains saturate by ~16 bits; we default to 12 and
    /// sweep 8..=16 in `benches/ablations.rs`).
    pub latent_bits: u32,
    /// Precision for coding the discretized posterior.
    pub posterior_prec: u32,
    /// Precision for coding pixels under the likelihood.
    pub pixel_prec: u32,
    /// Seed of the clean-bit supply that starts the chain.
    pub clean_seed: u64,
}

impl Default for BbAnsConfig {
    fn default() -> Self {
        Self {
            latent_bits: 12,
            posterior_prec: 24,
            pixel_prec: 16,
            clean_seed: 0xB_BA45_5EED,
        }
    }
}

impl BbAnsConfig {
    pub fn validate(&self) -> Result<()> {
        if self.latent_bits < 1 || self.latent_bits > 24 {
            bail!("latent_bits {} out of range 1..=24", self.latent_bits);
        }
        if self.posterior_prec <= self.latent_bits {
            bail!(
                "posterior_prec {} must exceed latent_bits {}",
                self.posterior_prec,
                self.latent_bits
            );
        }
        if self.posterior_prec > 32 || self.pixel_prec > 28 || self.pixel_prec < 10 {
            bail!("precision out of range");
        }
        Ok(())
    }
}

/// Per-image rate telemetry (drives Fig. 3 and the §3.2 accounting).
#[derive(Debug, Clone, Copy)]
pub struct ImageStats {
    /// Net message growth from this image, in bits (can be < 0 early in
    /// the chain when posterior pops consume clean bits).
    pub net_bits: f64,
    /// Bits consumed sampling the latent from q(y|s) (step 1; negative).
    pub posterior_bits: f64,
    /// Bits added coding pixels under p(s|y) (step 2).
    pub likelihood_bits: f64,
    /// Bits added coding the latent under p(y) (step 3).
    pub prior_bits: f64,
}

/// The backend-free stepwise core of the BB-ANS codec: the latent bucket
/// geometry, the coding hyper-parameters, and every per-stream ANS
/// primitive. None of these touch the network — they need only the
/// model's *shape* ([`ModelMeta`]) — so the core is plain `Send + Sync`
/// data even when the backend it was derived from is thread-bound (PJRT
/// handles are neither `Send` nor `Sync`). The coordinator's executors
/// rely on exactly that split: per-stream phase closures capture a
/// `&CodecCore` and may fan out across pool threads, while the batched
/// NN dispatches stay wherever the backend lives.
pub struct CodecCore {
    meta: ModelMeta,
    pub cfg: BbAnsConfig,
    buckets: MaxEntropyBuckets,
}

impl CodecCore {
    pub fn new(meta: ModelMeta, cfg: BbAnsConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Self {
            meta,
            cfg,
            buckets: MaxEntropyBuckets::new(cfg.latent_bits),
        })
    }

    /// Shape of the model this core codes for.
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn scale_image(&self, img: &[u8]) -> Vec<f32> {
        let mut out = Vec::with_capacity(img.len());
        self.scale_image_into(img, &mut out);
        out
    }

    /// [`Self::scale_image`] appending to a caller-owned buffer — the
    /// batch builders pack many images into one flat matrix this way.
    pub fn scale_image_into(&self, img: &[u8], out: &mut Vec<f32>) {
        scale_pixels_into(self.meta.likelihood, img, out)
    }

    /// Latent bucket centres → the f32 latent vector fed to the decoder.
    fn centres(&self, idx: &[u32]) -> Vec<f32> {
        let mut out = Vec::with_capacity(idx.len());
        self.centres_into(idx, &mut out);
        out
    }

    fn centres_into(&self, idx: &[u32], out: &mut Vec<f32>) {
        out.extend(idx.iter().map(|&i| self.buckets.centre(i) as f32));
    }

    /// Reusable-codec variant of the posterior-codec constructor (thin
    /// wrapper over the module-level [`gauss_codec_scratch`], pinned to
    /// this codec's buckets and posterior precision).
    fn posterior_codec_scratch<'g>(
        &self,
        mu: f32,
        sigma: f32,
        slot: &'g mut Option<DiscretizedGaussian>,
    ) -> &'g DiscretizedGaussian {
        gauss_codec_scratch(&self.buckets, self.cfg.posterior_prec, mu, sigma, slot)
    }

    // ---- stepwise primitives (public so the coordinator can interleave
    // ---- the ANS work of many streams between batched NN calls) ----

    /// Step 1 of encode: pop the latent bucket indices from q(y|s).
    pub fn pop_posterior(&self, ans: &mut Ans, mu: &[f32], sigma: &[f32]) -> Vec<u32> {
        let mut idx = Vec::with_capacity(self.meta.latent_dim);
        self.pop_posterior_into(ans, mu, sigma, &mut idx, &mut None);
        idx
    }

    /// [`Self::pop_posterior`] with reusable buffers: `idx` is cleared and
    /// refilled, `slot` caches the posterior codec across dims and images
    /// (the `CodecScratch` fields the dataset loops thread through).
    pub fn pop_posterior_into(
        &self,
        ans: &mut Ans,
        mu: &[f32],
        sigma: &[f32],
        idx: &mut Vec<u32>,
        slot: &mut Option<DiscretizedGaussian>,
    ) {
        idx.clear();
        for d in 0..self.meta.latent_dim {
            let g = self.posterior_codec_scratch(mu[d], sigma[d], slot);
            idx.push(g.pop(ans));
        }
    }

    /// Step 2 of encode: push all pixels under the likelihood. Thin
    /// wrapper over the coder-generic [`Self::push_pixels_coder`].
    pub fn push_pixels(&self, ans: &mut Ans, params: &PixelParams, img: &[u8]) {
        self.push_pixels_coder(ans, params, img)
    }

    /// Coder-generic likelihood encode: codes the whole image through any
    /// [`EntropyCoder`] — the stack coder on the bits-back path, the
    /// interleaved multi-lane coder on the fully-observed fast path
    /// (paper §4.2). Allocates a fresh scratch; loops should use
    /// [`Self::push_pixels_coder_scratch`].
    pub fn push_pixels_coder<C: EntropyCoder>(
        &self,
        coder: &mut C,
        params: &PixelParams,
        img: &[u8],
    ) {
        self.push_pixels_coder_scratch(coder, params, img, &mut CodecScratch::new())
    }

    /// [`Self::push_pixels_coder`] with reusable buffers: the whole image
    /// is gathered as prepared symbols (division-free encode) with zero
    /// heap allocation after the first image on a scratch.
    pub fn push_pixels_coder_scratch<C: EntropyCoder>(
        &self,
        coder: &mut C,
        params: &PixelParams,
        img: &[u8],
        scratch: &mut CodecScratch,
    ) {
        let CodecScratch {
            prepared,
            pmf,
            direct,
            ..
        } = scratch;
        prepare_pixel_codecs(params, self.cfg.pixel_prec, direct);
        prepared.clear();
        prepared.extend(
            img.iter()
                .enumerate()
                .map(|(p, &sym)| pixel_prepared(params, p, sym, self.cfg.pixel_prec, pmf, direct)),
        );
        coder.encode_all_prepared(prepared, self.cfg.pixel_prec);
    }

    /// Step 3 of encode: push the latent under the uniform prior.
    pub fn push_prior(&self, ans: &mut Ans, idx: &[u32]) {
        let prior = Uniform::new(self.cfg.latent_bits);
        for &i in idx {
            prior.push(ans, i);
        }
    }

    /// Step 3⁻¹ of decode: pop the latent from the prior.
    pub fn pop_prior(&self, ans: &mut Ans) -> Vec<u32> {
        let mut idx = Vec::new();
        self.pop_prior_into(ans, &mut idx);
        idx
    }

    /// [`Self::pop_prior`] into a reusable buffer.
    pub fn pop_prior_into(&self, ans: &mut Ans, idx: &mut Vec<u32>) {
        let l = self.meta.latent_dim;
        let prior = Uniform::new(self.cfg.latent_bits);
        idx.clear();
        idx.resize(l, 0);
        for d in (0..l).rev() {
            idx[d] = prior.pop(ans);
        }
    }

    /// Step 2⁻¹ of decode: pop all pixels under the likelihood. Thin
    /// wrapper over the coder-generic [`Self::pop_pixels_coder`].
    pub fn pop_pixels(&self, ans: &mut Ans, params: &PixelParams) -> Vec<u8> {
        self.pop_pixels_coder(ans, params)
    }

    /// Coder-generic likelihood decode (inverse of
    /// [`Self::push_pixels_coder`]; pixels come back in raster order).
    pub fn pop_pixels_coder<C: EntropyCoder>(
        &self,
        coder: &mut C,
        params: &PixelParams,
    ) -> Vec<u8> {
        self.pop_pixels_coder_scratch(coder, params, &mut CodecScratch::new())
    }

    /// [`Self::pop_pixels_coder`] with reusable buffers (the table-path
    /// PMF row; the decoded image itself is the return value).
    pub fn pop_pixels_coder_scratch<C: EntropyCoder>(
        &self,
        coder: &mut C,
        params: &PixelParams,
        scratch: &mut CodecScratch,
    ) -> Vec<u8> {
        let pixels = self.meta.pixels;
        let CodecScratch { pmf, direct, .. } = scratch;
        prepare_pixel_codecs(params, self.cfg.pixel_prec, direct);
        let mut p = 0usize;
        coder.decode_all(pixels, self.cfg.pixel_prec, |cf| {
            let out = pixel_lookup(params, p, cf, self.cfg.pixel_prec, pmf, &*direct);
            p += 1;
            out
        })
    }

    /// Step 1⁻¹ of decode: push the latent back under q(y|s).
    pub fn push_posterior(&self, ans: &mut Ans, mu: &[f32], sigma: &[f32], idx: &[u32]) {
        self.push_posterior_scratch(ans, mu, sigma, idx, &mut None)
    }

    /// [`Self::push_posterior`] with the cached posterior codec.
    pub fn push_posterior_scratch(
        &self,
        ans: &mut Ans,
        mu: &[f32],
        sigma: &[f32],
        idx: &[u32],
        slot: &mut Option<DiscretizedGaussian>,
    ) {
        for d in (0..self.meta.latent_dim).rev() {
            self.posterior_codec_scratch(mu[d], sigma[d], slot)
                .push(ans, idx[d]);
        }
    }

    /// Bucket indices → the latent vector fed to the generative net.
    pub fn latent_centres(&self, idx: &[u32]) -> Vec<f32> {
        self.centres(idx)
    }

    /// [`Self::latent_centres`] appending to a caller-owned buffer (the
    /// coordinator packs many streams' latents into one matrix).
    pub fn latent_centres_into(&self, idx: &[u32], out: &mut Vec<f32>) {
        self.centres_into(idx, out)
    }
}

/// The BB-ANS codec over a VAE [`Backend`]: a [`CodecCore`] plus the
/// backend that runs the recognition/generative nets. Derefs to the
/// core, so every stepwise primitive is callable directly on the codec.
pub struct VaeCodec<'a, B: Backend + ?Sized> {
    backend: &'a B,
    core: CodecCore,
}

impl<B: Backend + ?Sized> std::ops::Deref for VaeCodec<'_, B> {
    type Target = CodecCore;

    fn deref(&self) -> &CodecCore {
        &self.core
    }
}

impl<'a, B: Backend + ?Sized> VaeCodec<'a, B> {
    pub fn new(backend: &'a B, cfg: BbAnsConfig) -> Result<Self> {
        Ok(Self {
            backend,
            core: CodecCore::new(backend.meta().clone(), cfg)?,
        })
    }

    pub fn backend(&self) -> &B {
        self.backend
    }

    /// Borrow the backend-free stepwise core (what the coordinator's
    /// executors thread through their phase closures).
    pub fn core(&self) -> &CodecCore {
        &self.core
    }

    /// Encode one image onto the stack (paper Table 1), given its already-
    /// computed posterior parameters. Returns per-step rate telemetry.
    pub fn encode_image_with_posterior(
        &self,
        ans: &mut Ans,
        img: &[u8],
        mu: &[f32],
        sigma: &[f32],
    ) -> Result<ImageStats> {
        self.encode_image_with_posterior_scratch(ans, img, mu, sigma, &mut CodecScratch::new())
    }

    /// [`Self::encode_image_with_posterior`] with reusable buffers — the
    /// form the dataset loops use so chained encoding allocates nothing
    /// per image.
    pub fn encode_image_with_posterior_scratch(
        &self,
        ans: &mut Ans,
        img: &[u8],
        mu: &[f32],
        sigma: &[f32],
        scratch: &mut CodecScratch,
    ) -> Result<ImageStats> {
        let meta = self.backend.meta();
        if img.len() != meta.pixels {
            bail!("image has {} pixels, model wants {}", img.len(), meta.pixels);
        }
        // Effective message length: actual content minus the clean words
        // drawn so far. Treating the clean supply as virtual pre-existing
        // stack content makes a posterior pop cost exactly -log q and a
        // push cost exactly -log p, so per-image net = -ELBO estimate.
        let bits_at = |a: &Ans| a.frac_bit_len() - 32.0 * a.clean_words_used() as f64;
        let cw0 = ans.clean_words_used();

        // (1) pop y ~ q(y|s): dims in increasing order. The bucket-index
        // buffer is borrowed out of the scratch so the pixel step below
        // can borrow the rest of it.
        let mut idx = std::mem::take(&mut scratch.idx);
        let b0 = bits_at(ans);
        self.pop_posterior_into(ans, mu, sigma, &mut idx, &mut scratch.gauss);
        let b1 = bits_at(ans);

        // (2) push s under p(s|y). The decoder net is inherently B=1 on
        // this path: the latent depends on the coder state, so chunk-level
        // batching happens on the posterior side only (see
        // `encode_dataset_pipelined`).
        let y = self.centres(&idx);
        let params = self.backend.likelihood(&[&y])?.remove(0);
        self.push_pixels_coder_scratch(ans, &params, img, scratch);
        let b2 = bits_at(ans);

        // (3) push y under the (exactly uniform) discretized prior.
        self.push_prior(ans, &idx);
        let b3 = bits_at(ans);
        scratch.idx = idx;

        if let Some(ledger) = scratch.ledger.as_deref_mut() {
            let mut e = crate::obs::LedgerEntry::new(1);
            e.initial_bits = 32.0 * (ans.clean_words_used() - cw0) as f64;
            e.latent_pop_bits[0] = b1 - b0;
            e.latent_push_bits[0] = b3 - b2;
            e.data_bits = b2 - b1;
            e.net_bits = b3 - b0;
            ledger.push(e);
        }

        Ok(ImageStats {
            net_bits: b3 - b0,
            posterior_bits: b1 - b0, // negative: pops consume
            likelihood_bits: b2 - b1,
            prior_bits: b3 - b2,
        })
    }

    /// Encode one image (computes the posterior itself).
    pub fn encode_image(&self, ans: &mut Ans, img: &[u8]) -> Result<ImageStats> {
        let x = self.scale_image(img);
        let (mu, sigma) = self.backend.posterior(&[&x])?.remove(0);
        self.encode_image_with_posterior(ans, img, &mu, &sigma)
    }

    /// Decode one image from the stack — the exact inverse of
    /// [`Self::encode_image`].
    pub fn decode_image(&self, ans: &mut Ans) -> Result<Vec<u8>> {
        self.decode_image_scratch(ans, &mut CodecScratch::new())
    }

    /// [`Self::decode_image`] with reusable buffers.
    pub fn decode_image_scratch(
        &self,
        ans: &mut Ans,
        scratch: &mut CodecScratch,
    ) -> Result<Vec<u8>> {
        // (3 inverse) pop y from the prior.
        let mut idx = std::mem::take(&mut scratch.idx);
        self.pop_prior_into(ans, &mut idx);

        // (2 inverse) pop s under p(s|y).
        let y = self.centres(&idx);
        let params = self.backend.likelihood(&[&y])?.remove(0);
        let img = self.pop_pixels_coder_scratch(ans, &params, scratch);

        // (1 inverse) push y back under q(y|s) — returns the borrowed bits.
        let x = self.scale_image(&img);
        let (mu, sigma) = self.backend.posterior(&[&x])?.remove(0);
        self.push_posterior_scratch(ans, &mu, &sigma, &idx, &mut scratch.gauss);
        scratch.idx = idx;
        Ok(img)
    }

    /// Encode a dataset by chaining (paper §2.3): every image's compressed
    /// form seeds the next one's posterior sample. Posterior network calls
    /// are batched upfront (they depend only on the data).
    ///
    /// Returns (final coder, per-image stats in encode order).
    pub fn encode_dataset(&self, images: &[Vec<u8>]) -> Result<(Ans, Vec<ImageStats>)> {
        let mut ans = Ans::new(self.cfg.clean_seed);
        let stats = self.encode_dataset_into(&mut ans, images)?;
        Ok((ans, stats))
    }

    /// Scale a chunk of images into one `[B, pixels]` matrix and run the
    /// recognition net as a single batched dispatch. Both dataset encode
    /// paths (sequential and pipelined) share this, so their NN inputs
    /// are identical by construction.
    pub fn posterior_batch_for(&self, chunk: &[Vec<u8>]) -> Result<PosteriorBatch> {
        let pixels = self.backend.meta().pixels;
        let mut data = Vec::with_capacity(chunk.len() * pixels);
        for img in chunk {
            if img.len() != pixels {
                bail!("image has {} pixels, model wants {pixels}", img.len());
            }
            self.scale_image_into(img, &mut data);
        }
        let x = Matrix::new(chunk.len(), pixels, data);
        self.backend.encode_batch(&x)
    }

    /// Chain `images` onto an existing coder state.
    pub fn encode_dataset_into(
        &self,
        ans: &mut Ans,
        images: &[Vec<u8>],
    ) -> Result<Vec<ImageStats>> {
        self.encode_dataset_into_scratch(ans, images, &mut CodecScratch::new())
    }

    /// [`Self::encode_dataset_into`] with a caller-owned scratch — the
    /// hook the ledgered paths use to thread an accounting sink through
    /// the chain without touching the emitted bytes.
    pub fn encode_dataset_into_scratch(
        &self,
        ans: &mut Ans,
        images: &[Vec<u8>],
        scratch: &mut CodecScratch,
    ) -> Result<Vec<ImageStats>> {
        let mut stats = Vec::with_capacity(images.len());
        for chunk in images.chunks(NN_CHUNK) {
            let posts = self.posterior_batch_for(chunk)?;
            for (r, img) in chunk.iter().enumerate() {
                let (mu, sigma) = posts.row(r);
                stats.push(
                    self.encode_image_with_posterior_scratch(ans, img, mu, sigma, scratch)?,
                );
            }
        }
        Ok(stats)
    }

    /// [`Self::encode_dataset`] with the rate ledger attached: same bytes
    /// (the ledger is a pure observer of the effective-length measure),
    /// plus per-image bit accounting for the whole chain.
    pub fn encode_dataset_ledgered(
        &self,
        images: &[Vec<u8>],
    ) -> Result<(Ans, Vec<ImageStats>, crate::obs::Ledger)> {
        let mut ans = Ans::new(self.cfg.clean_seed);
        let mut scratch = CodecScratch::new();
        scratch.ledger = Some(Box::default());
        let stats = self.encode_dataset_into_scratch(&mut ans, images, &mut scratch)?;
        let ledger = *scratch.ledger.take().expect("installed above");
        Ok((ans, stats, ledger))
    }

    /// Decode `n` chained images; returns them in original encode order.
    pub fn decode_dataset(&self, ans: &mut Ans, n: usize) -> Result<Vec<Vec<u8>>> {
        let mut out = Vec::with_capacity(n);
        let mut scratch = CodecScratch::new();
        for _ in 0..n {
            out.push(self.decode_image_scratch(ans, &mut scratch)?);
        }
        out.reverse(); // stack order → original order
        Ok(out)
    }

    /// Deterministic near-even partition of `n` items into `k` chunks
    /// (delegates to the module-level [`chunk_ranges`]; kept on the codec
    /// for API compatibility).
    pub fn chunk_ranges(n: usize, k: usize) -> Vec<std::ops::Range<usize>> {
        chunk_ranges(n, k)
    }
}

/// Deterministic near-even partition of `n` items into `k` chunks (first
/// `n % k` chunks get one extra item). Delegates to the single shared
/// implementation in [`crate::util::chunk_ranges`] — the same split the
/// model layer's row sharding uses — so chunked containers stay
/// reproducible against one partition semantics.
pub fn chunk_ranges(n: usize, k: usize) -> Vec<std::ops::Range<usize>> {
    crate::util::chunk_ranges(n, k)
}

/// Default worker-thread count for the parallel paths.
fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Fan [`NN_CHUNK`]-image blocks of `images` out to `workers` precompute
/// threads and consume each block's result **strictly in block order** on
/// the calling thread — the pipelined-encode skeleton shared by the
/// single-layer and hierarchical codecs. `precompute` must depend only on
/// its block (it runs on worker threads, any order); `consume` runs
/// sequentially, so the coder chain it advances sees exactly the same
/// inputs at every worker count — bit-identity by construction. With one
/// block or one worker everything runs inline on the caller.
pub(crate) fn pipelined_blocks<P, F, G>(
    images: &[Vec<u8>],
    workers: usize,
    precompute: F,
    mut consume: G,
) -> Result<()>
where
    P: Send,
    F: Fn(&[Vec<u8>]) -> Result<P> + Sync,
    G: FnMut(&[Vec<u8>], P) -> Result<()>,
{
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc;

    let blocks: Vec<&[Vec<u8>]> = images.chunks(NN_CHUNK).collect();
    if blocks.len() <= 1 || workers <= 1 {
        for block in blocks {
            let p = precompute(block)?;
            consume(block, p)?;
        }
        return Ok(());
    }
    let workers = workers.min(blocks.len());
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, Result<P>)>();
    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..workers {
            let tx = tx.clone();
            let (next, blocks, precompute) = (&next, &blocks, &precompute);
            scope.spawn(move || loop {
                let bi = next.fetch_add(1, Ordering::Relaxed);
                if bi >= blocks.len() || tx.send((bi, precompute(blocks[bi]))).is_err() {
                    break;
                }
            });
        }
        drop(tx);

        // Consume blocks strictly in order as they land.
        let mut ready: BTreeMap<usize, Result<P>> = BTreeMap::new();
        for (bi, block) in blocks.iter().enumerate() {
            let p = loop {
                if let Some(p) = ready.remove(&bi) {
                    break p;
                }
                let (i, p) = rx.recv().expect("precompute worker exited early");
                ready.insert(i, p);
            }?;
            consume(block, p)?;
        }
        Ok(())
    })
}

/// A one-shot rendezvous slot for handing a value between pool workers
/// (the speculative-decode head → tail handoff; `mpsc` endpoints are not
/// `Sync`, so a `Mutex` + `Condvar` pair stands in).
struct HandoffSlot<T> {
    value: std::sync::Mutex<Option<T>>,
    ready: std::sync::Condvar,
}

impl<T> HandoffSlot<T> {
    fn new() -> Self {
        Self {
            value: std::sync::Mutex::new(None),
            ready: std::sync::Condvar::new(),
        }
    }

    fn put(&self, v: T) {
        *self.value.lock().expect("handoff poisoned") = Some(v);
        self.ready.notify_all();
    }

    fn take(&self) -> T {
        let mut guard = self.value.lock().expect("handoff poisoned");
        loop {
            if let Some(v) = guard.take() {
                return v;
            }
            guard = self.ready.wait(guard).expect("handoff poisoned");
        }
    }
}

/// Pool-decode independent chunks with **speculative first-image
/// scheduling** (ISSUE 5 / ROADMAP): each chunk splits into a cheap
/// *head* job (decode just its first image) and a *tail* job (drain the
/// rest), and all heads are queued before any tail. A free worker
/// therefore starts chunk `i+1`'s first image while chunk `i` is still
/// draining, and the finer job granularity packs uneven chunks with a
/// shorter idle tail (ramp-down) than whole-chunk jobs can.
///
/// Bit-identity is by construction: a chunk's decode is a deterministic
/// sequence of per-image steps on its own coder, so splitting the loop
/// after one image changes nothing — the tail resumes from the exact
/// coder state the head produced (heads never depend on anything, so the
/// head-first queue order also makes the tail's rendezvous deadlock-free
/// at every worker count).
///
/// `start(ci)` yields chunk `ci`'s fresh coder and image count;
/// `decode_n(ci, ans, k)` decodes `k` images and returns them in original
/// (encode) order — exactly the `decode_dataset` contract, so a head of
/// one image holds the chunk's *last* image and `tail ++ head` restores
/// the original order. Results concatenate across chunks in index order.
pub(crate) fn decode_chunks_speculative<F>(
    n_chunks: usize,
    workers: usize,
    start: impl Fn(usize) -> (Ans, usize) + Sync,
    decode_n: F,
) -> Result<Vec<Vec<u8>>>
where
    F: Fn(usize, &mut Ans, usize) -> Result<Vec<Vec<u8>>> + Sync,
{
    type Head = (Result<Vec<Vec<u8>>>, Ans, usize);
    let slots: Vec<HandoffSlot<Head>> = (0..n_chunks).map(|_| HandoffSlot::new()).collect();
    let per_chunk = pooled_indexed(2 * n_chunks, workers, |job| {
        if job < n_chunks {
            // Head: first image only (or nothing for an empty chunk). A
            // panicking head must still fill its slot, otherwise the tail
            // job would block forever and turn the panic into a hang —
            // fill with an error Head, then re-raise.
            let ci = job;
            let head = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let (mut ans, total) = start(ci);
                let head = decode_n(ci, &mut ans, total.min(1));
                (head, ans, total)
            }));
            match head {
                Ok(v) => slots[ci].put(v),
                Err(payload) => {
                    slots[ci].put((
                        Err(anyhow::anyhow!("chunk {ci} head decode panicked")),
                        Ans::new(0),
                        0,
                    ));
                    std::panic::resume_unwind(payload);
                }
            }
            None
        } else {
            // Tail: resume from the head's coder state and drain.
            let ci = job - n_chunks;
            let (head, mut ans, total) = slots[ci].take();
            Some(head.and_then(|head_imgs| {
                let mut out = decode_n(ci, &mut ans, total - head_imgs.len())?;
                out.extend(head_imgs);
                Ok(out)
            }))
        }
    });
    let mut out = Vec::new();
    for r in per_chunk.into_iter().flatten() {
        out.extend(r?);
    }
    Ok(out)
}

/// Run `n_jobs` indexed jobs on a bounded pool of `workers` scoped
/// threads (atomic work-stealing queue) and return the results in job
/// order. The pool shape never affects outputs — only which thread
/// happens to compute each job.
fn pooled_indexed<T: Send, F: Fn(usize) -> T + Sync>(
    n_jobs: usize,
    workers: usize,
    job: F,
) -> Vec<T> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc;

    let workers = workers.clamp(1, n_jobs.max(1));
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let (next, job) = (&next, &job);
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_jobs || tx.send((i, job(i))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n_jobs).map(|_| None).collect();
        for _ in 0..n_jobs {
            let (i, v) = rx.recv().expect("pool worker exited without a result");
            out[i] = Some(v);
        }
        out.into_iter()
            .map(|v| v.expect("every job index delivered once"))
            .collect()
    })
}

/// Chunk-parallel and pipelined coding (paper §4.2: BB-ANS chains are
/// sequential, but *independent* chains parallelize perfectly, and the
/// posterior precompute is data-parallel even within one chain). Requires
/// a `Sync` backend — the pure-Rust [`crate::model::vae::NativeVae`]
/// qualifies; the PJRT backend is deliberately single-threaded and
/// instead parallelizes via the coordinator's cross-stream batcher.
impl<B: Backend + Sync + ?Sized> VaeCodec<'_, B> {
    /// Encode one sequential chain with the recognition net pipelined
    /// against it: worker threads precompute [`PosteriorBatch`]es for
    /// [`NN_CHUNK`]-image blocks (they depend only on the data) while
    /// this thread runs the strictly sequential ANS chain, consuming
    /// blocks in order ([`pipelined_blocks`]). Bit-identical to
    /// [`Self::encode_dataset_into`] for every worker count: the chain
    /// work is untouched and the posterior batches are row-independent
    /// and identically chunked.
    pub fn encode_dataset_pipelined(
        &self,
        ans: &mut Ans,
        images: &[Vec<u8>],
        workers: usize,
    ) -> Result<Vec<ImageStats>> {
        let mut scratch = CodecScratch::new();
        let mut stats = Vec::with_capacity(images.len());
        pipelined_blocks(
            images,
            workers,
            |block: &[Vec<u8>]| self.posterior_batch_for(block),
            |block: &[Vec<u8>], posts: PosteriorBatch| {
                for (r, img) in block.iter().enumerate() {
                    let (mu, sigma) = posts.row(r);
                    stats.push(self.encode_image_with_posterior_scratch(
                        ans,
                        img,
                        mu,
                        sigma,
                        &mut scratch,
                    )?);
                }
                Ok(())
            },
        )?;
        Ok(stats)
    }

    /// Encode `images` as `n_chunks` independent BB-ANS chains on the
    /// default-sized worker pool. Chunk `i` seeds its clean-bit supply
    /// from [`container::chunk_seed`]`(cfg.clean_seed, i)`, so the result
    /// is bit-reproducible for a given `(images, n_chunks, cfg)`
    /// regardless of how many threads actually run.
    pub fn encode_dataset_chunked(
        &self,
        images: &[Vec<u8>],
        n_chunks: usize,
    ) -> Result<Vec<container::ChunkEntry>> {
        self.encode_dataset_chunked_with_workers(images, n_chunks, default_workers())
    }

    /// [`Self::encode_dataset_chunked`] with an explicit worker count:
    /// `n_chunks` (the container format) and `workers` (the machine) are
    /// independent knobs. With more chunks than workers the pool
    /// pipelines — chunk `i+1`'s recognition-net batches run on one
    /// worker while chunk `i`'s ANS chain is still coding on another.
    pub fn encode_dataset_chunked_with_workers(
        &self,
        images: &[Vec<u8>],
        n_chunks: usize,
        workers: usize,
    ) -> Result<Vec<container::ChunkEntry>> {
        let ranges = Self::chunk_ranges(images.len(), n_chunks);
        // Workers left over after one-per-chunk go to each chain's
        // posterior-precompute pipeline, counting the consuming pool
        // thread against the budget so `workers` is a true ceiling:
        // a pipelined chunk costs 1 (consumer) + inner (precompute)
        // threads, so e.g. 8 workers / 2 chunks → inner = 3 (2·(1+3) = 8
        // live threads); with chunks ≥ workers, inner = 1 and the
        // pipelined path degrades to the sequential one.
        let pool = workers.clamp(1, ranges.len().max(1));
        let inner = (workers / pool).saturating_sub(1).max(1);
        pooled_indexed(ranges.len(), workers, |ci| {
            let chunk = &images[ranges[ci].clone()];
            let mut ans = Ans::new(container::chunk_seed(self.cfg.clean_seed, ci));
            self.encode_dataset_pipelined(&mut ans, chunk, inner)?;
            Ok(container::ChunkEntry {
                num_images: chunk.len() as u32,
                message: ans.into_message(),
            })
        })
        .into_iter()
        .collect()
    }

    /// [`Self::encode_dataset_chunked_with_workers`] with the rate ledger
    /// attached: identical chunk bytes (each chain's coding ops are
    /// unchanged; sequential and pipelined encodes are bit-identical by
    /// construction), plus per-image accounting merged in chunk order —
    /// entry order matches dataset order.
    pub fn encode_dataset_chunked_ledgered(
        &self,
        images: &[Vec<u8>],
        n_chunks: usize,
        workers: usize,
    ) -> Result<(Vec<container::ChunkEntry>, crate::obs::Ledger)> {
        let ranges = Self::chunk_ranges(images.len(), n_chunks);
        let per_chunk = pooled_indexed(ranges.len(), workers, |ci| {
            let chunk = &images[ranges[ci].clone()];
            let mut ans = Ans::new(container::chunk_seed(self.cfg.clean_seed, ci));
            let mut scratch = CodecScratch::new();
            scratch.ledger = Some(Box::default());
            self.encode_dataset_into_scratch(&mut ans, chunk, &mut scratch)?;
            Ok((
                container::ChunkEntry {
                    num_images: chunk.len() as u32,
                    message: ans.into_message(),
                },
                *scratch.ledger.take().expect("installed above"),
            ))
        });
        let mut chunks = Vec::with_capacity(per_chunk.len());
        let mut ledger = crate::obs::Ledger::new();
        for r in per_chunk {
            let (entry, chunk_ledger): (container::ChunkEntry, crate::obs::Ledger) = r?;
            chunks.push(entry);
            ledger.merge(chunk_ledger);
        }
        Ok((chunks, ledger))
    }

    /// Decode chunks produced by [`Self::encode_dataset_chunked`] on the
    /// default-sized worker pool; images return in original dataset
    /// order. Borrows the chunk messages — no payload copies.
    pub fn decode_dataset_chunked(
        &self,
        chunks: &[container::ChunkEntry],
    ) -> Result<Vec<Vec<u8>>> {
        self.decode_dataset_chunked_with_workers(chunks, default_workers())
    }

    /// [`Self::decode_dataset_chunked`] with an explicit worker count.
    /// Within a chain, decode steps are strictly serial (each image's
    /// decoder-net input is popped from the stream), so decode-side
    /// pipelining is across chunks — with speculative first-image
    /// scheduling ([`decode_chunks_speculative`]): every chunk's first
    /// image is queued ahead of the chunk drains, so chunk `i+1` starts
    /// while chunk `i` is still coding and the pool's ramp-down tail
    /// shrinks. Output is bit-identical to whole-chunk pooling (the split
    /// only relocates a loop boundary).
    pub fn decode_dataset_chunked_with_workers(
        &self,
        chunks: &[container::ChunkEntry],
        workers: usize,
    ) -> Result<Vec<Vec<u8>>> {
        decode_chunks_speculative(
            chunks.len(),
            workers,
            |ci| {
                (
                    Ans::from_message(
                        &chunks[ci].message,
                        container::chunk_seed(self.cfg.clean_seed, ci),
                    ),
                    chunks[ci].num_images as usize,
                )
            },
            |_ci, ans, n| self.decode_dataset(ans, n),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vae::NativeVae;
    use crate::model::ModelMeta;
    use crate::util::rng::Rng;

    fn meta(likelihood: Likelihood, pixels: usize, latent: usize) -> ModelMeta {
        ModelMeta {
            name: "t".into(),
            pixels,
            latent_dim: latent,
            hidden: 12,
            likelihood,
            test_elbo_bpd: f64::NAN,
        }
    }

    fn sample_images(n: usize, pixels: usize, levels: u32, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                (0..pixels)
                    .map(|_| {
                        // Sparse-ish images: mostly zeros like MNIST.
                        if rng.f64() < 0.7 {
                            0
                        } else {
                            rng.below(levels as u64) as u8
                        }
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn roundtrip_bernoulli_model() {
        let backend = NativeVae::random(meta(Likelihood::Bernoulli, 36, 6), 1);
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = sample_images(25, 36, 2, 2);
        let (mut ans, stats) = codec.encode_dataset(&images).unwrap();
        assert_eq!(stats.len(), 25);
        let decoded = codec.decode_dataset(&mut ans, 25).unwrap();
        assert_eq!(decoded, images);
    }

    #[test]
    fn roundtrip_beta_binomial_model() {
        let backend = NativeVae::random(meta(Likelihood::BetaBinomial, 25, 5), 3);
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = sample_images(12, 25, 256, 4);
        let (mut ans, _) = codec.encode_dataset(&images).unwrap();
        let decoded = codec.decode_dataset(&mut ans, 12).unwrap();
        assert_eq!(decoded, images);
    }

    #[test]
    fn decode_returns_clean_bits() {
        // After decoding everything, the stream contains exactly the clean
        // words the encoder borrowed (the bits came back).
        let backend = NativeVae::random(meta(Likelihood::Bernoulli, 36, 6), 5);
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = sample_images(10, 36, 2, 6);
        let (mut ans, _) = codec.encode_dataset(&images).unwrap();
        let borrowed = ans.clean_words_used();
        let _ = codec.decode_dataset(&mut ans, 10).unwrap();
        assert_eq!(ans.stream_len() as u64, borrowed);
        let msg = ans.to_message();
        let mut fresh = Rng::new(codec.cfg.clean_seed);
        let expect: Vec<u32> = (0..borrowed).map(|_| fresh.next_u32()).collect();
        let mut got = msg.stream.clone();
        got.reverse();
        assert_eq!(got, expect, "returned bits must equal the clean supply");
    }

    #[test]
    fn chaining_beats_single_image_rate() {
        // Paper §2.5: the first image costs ~log p(s,y) (no bits to get
        // back); amortized chained rate approaches the ELBO. So encoding
        // N images must cost well under N * (single-image cost).
        let backend = NativeVae::random(meta(Likelihood::Bernoulli, 64, 8), 7);
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = sample_images(40, 64, 2, 8);

        // Total transmitted size = the final message itself (the clean
        // words that were drawn are *inside* it).
        let (ans_all, _) = codec.encode_dataset(&images).unwrap();
        let total_chained = ans_all.frac_bit_len();

        let mut total_single = 0.0;
        for img in &images {
            let (a, _) = codec.encode_dataset(std::slice::from_ref(img)).unwrap();
            total_single += a.frac_bit_len();
        }
        assert!(
            total_chained < total_single * 0.9,
            "chained {total_chained} vs single-sum {total_single}"
        );
    }

    #[test]
    fn stats_components_are_consistent() {
        let backend = NativeVae::random(meta(Likelihood::Bernoulli, 36, 6), 9);
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = sample_images(5, 36, 2, 10);
        let (_, stats) = codec.encode_dataset(&images).unwrap();
        for s in &stats {
            assert!(
                (s.net_bits - (s.posterior_bits + s.likelihood_bits + s.prior_bits)).abs() < 1e-6
            );
            assert!(s.posterior_bits < 0.0, "posterior step must consume bits");
            assert!(s.likelihood_bits > 0.0);
            assert!(s.prior_bits > 0.0);
            // Prior coding of L dims at latent_bits each is exact.
            assert!(
                (s.prior_bits - 6.0 * 12.0).abs() < 1.0,
                "prior bits {}",
                s.prior_bits
            );
        }
    }

    /// The speculative head/tail chunk decode must restore every dataset
    /// exactly at every worker count, including the empty dataset (a
    /// zero-image chunk's head decodes nothing), single-image chunks
    /// (the tail decodes nothing), and more workers than jobs.
    #[test]
    fn speculative_chunk_decode_edge_cases() {
        let backend = NativeVae::random(meta(Likelihood::Bernoulli, 36, 6), 17);
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        for n in [0usize, 1, 3, 7] {
            let images = sample_images(n, 36, 2, 40 + n as u64);
            let chunks = codec
                .encode_dataset_chunked_with_workers(&images, 3, 2)
                .unwrap();
            for workers in [1usize, 2, 8] {
                assert_eq!(
                    codec
                        .decode_dataset_chunked_with_workers(&chunks, workers)
                        .unwrap(),
                    images,
                    "n={n} workers={workers}"
                );
            }
        }
    }

    #[test]
    fn invalid_config_rejected() {
        let backend = NativeVae::random(meta(Likelihood::Bernoulli, 4, 2), 11);
        for cfg in [
            BbAnsConfig {
                latent_bits: 0,
                ..Default::default()
            },
            BbAnsConfig {
                latent_bits: 16,
                posterior_prec: 16,
                ..Default::default()
            },
            BbAnsConfig {
                pixel_prec: 40,
                ..Default::default()
            },
        ] {
            assert!(VaeCodec::new(&backend, cfg).is_err(), "{cfg:?}");
        }
    }

    #[test]
    fn wrong_image_size_rejected() {
        let backend = NativeVae::random(meta(Likelihood::Bernoulli, 36, 6), 13);
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let mut ans = Ans::new(0);
        assert!(codec.encode_image(&mut ans, &[0u8; 35]).is_err());
    }
}
