//! BB-ANS for time-series latent-variable models (paper §4.1 future work).
//!
//! The paper notes that hidden-Markov-style models "could, in principal,
//! be coded with BB-ANS, but the number of 'extra bits' needed in a naive
//! implementation scales with the length of the chain". This module
//! implements that naive scheme for a discrete HMM so the claim can be
//! measured (see `benches/ablations.rs`):
//!
//! * approximate posterior `q(z_t | x) =` exact smoothed marginals from
//!   forward–backward (factorized across time — the source of the ELBO
//!   gap `KL(∏_t q_t ‖ p(z|x))`);
//! * encode: pop `z_t ~ q_t` for `t = 0..T`; push `x_t` under the
//!   emissions; push `z_t` under the Markov prior **in reverse time
//!   order** so that decoding recovers `z_0, z_1, …` forward (each
//!   transition codec needs the previous state).
//!
//! Chaining across sequences amortizes the initial-bits cost exactly as
//! for images; the per-sequence startup cost (≈ Σ_t H(q_t)) is what
//! scales with `T`.

use anyhow::{bail, Result};

use crate::ans::Ans;
use crate::codecs::categorical::Categorical;
use crate::codecs::SymbolCodec;

/// A discrete hidden Markov model with categorical emissions.
#[derive(Debug, Clone)]
pub struct Hmm {
    pub n_states: usize,
    pub n_symbols: usize,
    /// Initial distribution, length `n_states`.
    pub init: Vec<f64>,
    /// Transition matrix, row-major `[from, to]`.
    pub trans: Vec<f64>,
    /// Emission matrix, row-major `[state, symbol]`.
    pub emit: Vec<f64>,
}

impl Hmm {
    pub fn new(init: Vec<f64>, trans: Vec<f64>, emit: Vec<f64>, n_symbols: usize) -> Result<Self> {
        let k = init.len();
        if trans.len() != k * k || emit.len() != k * n_symbols {
            bail!("inconsistent HMM shapes");
        }
        for row in 0..k {
            let s: f64 = trans[row * k..(row + 1) * k].iter().sum();
            if (s - 1.0).abs() > 1e-9 {
                bail!("transition row {row} sums to {s}");
            }
            let e: f64 = emit[row * n_symbols..(row + 1) * n_symbols].iter().sum();
            if (e - 1.0).abs() > 1e-9 {
                bail!("emission row {row} sums to {e}");
            }
        }
        Ok(Self {
            n_states: k,
            n_symbols,
            init,
            trans,
            emit,
        })
    }

    #[inline]
    fn trans_row(&self, from: usize) -> &[f64] {
        &self.trans[from * self.n_states..(from + 1) * self.n_states]
    }

    #[inline]
    fn emit_row(&self, state: usize) -> &[f64] {
        &self.emit[state * self.n_symbols..(state + 1) * self.n_symbols]
    }

    /// Forward–backward smoothed marginals `p(z_t | x)` plus the exact
    /// log-evidence `log p(x)` (nats → returned in bits).
    pub fn smoothed_marginals(&self, x: &[usize]) -> (Vec<Vec<f64>>, f64) {
        let (k, t_len) = (self.n_states, x.len());
        let mut alpha = vec![vec![0.0f64; k]; t_len];
        let mut scale = vec![0.0f64; t_len];
        // Forward (scaled).
        for z in 0..k {
            alpha[0][z] = self.init[z] * self.emit_row(z)[x[0]];
        }
        scale[0] = alpha[0].iter().sum();
        for z in 0..k {
            alpha[0][z] /= scale[0];
        }
        for t in 1..t_len {
            for z in 0..k {
                let mut a = 0.0;
                for zp in 0..k {
                    a += alpha[t - 1][zp] * self.trans_row(zp)[z];
                }
                alpha[t][z] = a * self.emit_row(z)[x[t]];
            }
            scale[t] = alpha[t].iter().sum();
            for z in 0..k {
                alpha[t][z] /= scale[t];
            }
        }
        // Backward (scaled with the same factors).
        let mut beta = vec![vec![1.0f64; k]; t_len];
        for t in (0..t_len - 1).rev() {
            for z in 0..k {
                let mut b = 0.0;
                for zn in 0..k {
                    b += self.trans_row(z)[zn] * self.emit_row(zn)[x[t + 1]] * beta[t + 1][zn];
                }
                beta[t][z] = b / scale[t + 1];
            }
        }
        let mut gamma = vec![vec![0.0f64; k]; t_len];
        for t in 0..t_len {
            let mut norm = 0.0;
            for z in 0..k {
                gamma[t][z] = alpha[t][z] * beta[t][z];
                norm += gamma[t][z];
            }
            for z in 0..k {
                gamma[t][z] /= norm;
            }
        }
        let log_evidence_bits: f64 = scale.iter().map(|s| s.log2()).sum();
        (gamma, log_evidence_bits)
    }
}

/// BB-ANS codec over an [`Hmm`].
pub struct HmmCodec<'a> {
    pub hmm: &'a Hmm,
    pub prec: u32,
}

impl<'a> HmmCodec<'a> {
    pub fn new(hmm: &'a Hmm, prec: u32) -> Self {
        Self { hmm, prec }
    }

    fn cat(&self, pmf: &[f64]) -> Categorical {
        Categorical::from_pmf(pmf, self.prec)
    }

    /// Encode one sequence; returns net bits added.
    pub fn encode_sequence(&self, ans: &mut Ans, x: &[usize]) -> Result<f64> {
        if x.is_empty() {
            return Ok(0.0);
        }
        if x.iter().any(|&s| s >= self.hmm.n_symbols) {
            bail!("symbol out of range");
        }
        let bits_at = |a: &Ans| a.frac_bit_len() - 32.0 * a.clean_words_used() as f64;
        let b0 = bits_at(ans);
        let (q, _) = self.hmm.smoothed_marginals(x);

        // (1) pop z_t ~ q_t, forward order.
        let mut z = Vec::with_capacity(x.len());
        for qt in &q {
            z.push(self.cat(qt).pop(ans));
        }
        // (2) push emissions, forward order.
        for (t, &xt) in x.iter().enumerate() {
            self.cat(self.hmm.emit_row(z[t])).push(ans, xt);
        }
        // (3) push latents under the Markov prior in REVERSE time order so
        // decode pops them forward.
        for t in (0..x.len()).rev() {
            let prior_t = if t == 0 {
                self.cat(&self.hmm.init)
            } else {
                self.cat(self.hmm.trans_row(z[t - 1]))
            };
            prior_t.push(ans, z[t]);
        }
        Ok(bits_at(ans) - b0)
    }

    /// Decode one sequence of known length.
    pub fn decode_sequence(&self, ans: &mut Ans, t_len: usize) -> Result<Vec<usize>> {
        if t_len == 0 {
            return Ok(Vec::new());
        }
        // (3 inverse) pop latents forward.
        let mut z = Vec::with_capacity(t_len);
        for t in 0..t_len {
            let prior_t = if t == 0 {
                self.cat(&self.hmm.init)
            } else {
                self.cat(self.hmm.trans_row(z[t - 1]))
            };
            z.push(prior_t.pop(ans));
        }
        // (2 inverse) pop emissions in reverse push order.
        let mut x = vec![0usize; t_len];
        for t in (0..t_len).rev() {
            x[t] = self.cat(self.hmm.emit_row(z[t])).pop(ans);
        }
        // (1 inverse) push posteriors in reverse pop order.
        let (q, _) = self.hmm.smoothed_marginals(&x);
        for t in (0..t_len).rev() {
            self.cat(&q[t]).push(ans, z[t]);
        }
        Ok(x)
    }
}

impl Hmm {
    /// Baum–Welch (EM) parameter estimation from observation sequences.
    ///
    /// Makes the §4.1 extension a complete pipeline: learn the model from
    /// data, then compress with BB-ANS at a rate near the learned model's
    /// log-likelihood. Returns the mean log-likelihood (bits/symbol) per
    /// iteration for convergence monitoring.
    pub fn baum_welch(&mut self, seqs: &[Vec<usize>], iters: usize) -> Vec<f64> {
        let (k, m) = (self.n_states, self.n_symbols);
        let mut curve = Vec::with_capacity(iters);
        for _ in 0..iters {
            let mut init_acc = vec![1e-8f64; k];
            let mut trans_acc = vec![1e-8f64; k * k];
            let mut emit_acc = vec![1e-8f64; k * m];
            let mut total_ll_bits = 0.0;
            let mut total_syms = 0usize;

            for x in seqs {
                if x.is_empty() {
                    continue;
                }
                let t_len = x.len();
                // Scaled forward/backward (same as smoothed_marginals but
                // we also need pairwise statistics).
                let mut alpha = vec![vec![0.0f64; k]; t_len];
                let mut scale = vec![0.0f64; t_len];
                for z in 0..k {
                    alpha[0][z] = self.init[z] * self.emit_row(z)[x[0]];
                }
                scale[0] = alpha[0].iter().sum::<f64>().max(1e-300);
                for z in 0..k {
                    alpha[0][z] /= scale[0];
                }
                for t in 1..t_len {
                    for z in 0..k {
                        let mut a = 0.0;
                        for zp in 0..k {
                            a += alpha[t - 1][zp] * self.trans_row(zp)[z];
                        }
                        alpha[t][z] = a * self.emit_row(z)[x[t]];
                    }
                    scale[t] = alpha[t].iter().sum::<f64>().max(1e-300);
                    for z in 0..k {
                        alpha[t][z] /= scale[t];
                    }
                }
                let mut beta = vec![vec![1.0f64; k]; t_len];
                for t in (0..t_len - 1).rev() {
                    for z in 0..k {
                        let mut b = 0.0;
                        for zn in 0..k {
                            b += self.trans_row(z)[zn]
                                * self.emit_row(zn)[x[t + 1]]
                                * beta[t + 1][zn];
                        }
                        beta[t][z] = b / scale[t + 1];
                    }
                }
                total_ll_bits += scale.iter().map(|s| s.log2()).sum::<f64>();
                total_syms += t_len;

                // Accumulate expected counts.
                for t in 0..t_len {
                    let mut norm = 0.0;
                    let mut gamma = vec![0.0f64; k];
                    for z in 0..k {
                        gamma[z] = alpha[t][z] * beta[t][z];
                        norm += gamma[z];
                    }
                    for z in 0..k {
                        let g = gamma[z] / norm.max(1e-300);
                        emit_acc[z * m + x[t]] += g;
                        if t == 0 {
                            init_acc[z] += g;
                        }
                    }
                }
                for t in 0..t_len - 1 {
                    let mut norm = 0.0;
                    let mut xi = vec![0.0f64; k * k];
                    for zp in 0..k {
                        for zn in 0..k {
                            let v = alpha[t][zp]
                                * self.trans_row(zp)[zn]
                                * self.emit_row(zn)[x[t + 1]]
                                * beta[t + 1][zn]
                                / scale[t + 1];
                            xi[zp * k + zn] = v;
                            norm += v;
                        }
                    }
                    for i in 0..k * k {
                        trans_acc[i] += xi[i] / norm.max(1e-300);
                    }
                }
            }

            // M-step: normalize counts.
            let init_total: f64 = init_acc.iter().sum();
            for z in 0..k {
                self.init[z] = init_acc[z] / init_total;
            }
            for z in 0..k {
                let row_total: f64 = trans_acc[z * k..(z + 1) * k].iter().sum();
                for zn in 0..k {
                    self.trans[z * k + zn] = trans_acc[z * k + zn] / row_total;
                }
                let e_total: f64 = emit_acc[z * m..(z + 1) * m].iter().sum();
                for s in 0..m {
                    self.emit[z * m + s] = emit_acc[z * m + s] / e_total;
                }
            }
            curve.push(-total_ll_bits / total_syms as f64);
        }
        curve
    }
}

/// A convenient test/bench HMM: sticky 3-state chain over 8 symbols.
pub fn demo_hmm() -> Hmm {
    let k = 3;
    let m = 8;
    let init = vec![1.0 / 3.0; 3];
    let mut trans = vec![0.0; k * k];
    for i in 0..k {
        for j in 0..k {
            trans[i * k + j] = if i == j { 0.8 } else { 0.1 };
        }
    }
    // Each state prefers a different symbol neighbourhood.
    let mut emit = vec![0.0; k * m];
    for i in 0..k {
        let mut total = 0.0;
        for s in 0..m {
            let d = (s as i32 - (i * 3) as i32).abs() as f64;
            let w = (-0.7 * d).exp() + 0.02;
            emit[i * m + s] = w;
            total += w;
        }
        for s in 0..m {
            emit[i * m + s] /= total;
        }
    }
    Hmm::new(init, trans, emit, m).unwrap()
}

/// Sample a sequence from the HMM (for tests/benches).
pub fn sample_sequence(hmm: &Hmm, t_len: usize, rng: &mut crate::util::rng::Rng) -> Vec<usize> {
    let mut draw = |pmf: &[f64]| -> usize {
        let u = rng.f64();
        let mut acc = 0.0;
        for (i, &p) in pmf.iter().enumerate() {
            acc += p;
            if u < acc {
                return i;
            }
        }
        pmf.len() - 1
    };
    let mut z = draw(&hmm.init);
    let mut out = Vec::with_capacity(t_len);
    for _ in 0..t_len {
        out.push(draw(hmm.emit_row(z)));
        z = draw(hmm.trans_row(z));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn marginals_sum_to_one_and_evidence_negative() {
        let hmm = demo_hmm();
        let mut rng = Rng::new(1);
        let x = sample_sequence(&hmm, 100, &mut rng);
        let (q, log_ev_bits) = hmm.smoothed_marginals(&x);
        assert_eq!(q.len(), 100);
        for qt in &q {
            let s: f64 = qt.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
        assert!(log_ev_bits < 0.0, "log p(x) must be negative: {log_ev_bits}");
    }

    #[test]
    fn roundtrip_sequences() {
        let hmm = demo_hmm();
        let codec = HmmCodec::new(&hmm, 16);
        let mut rng = Rng::new(2);
        let seqs: Vec<Vec<usize>> = (0..10)
            .map(|i| sample_sequence(&hmm, 20 + 13 * i, &mut rng))
            .collect();
        let mut ans = Ans::new(5);
        for s in &seqs {
            codec.encode_sequence(&mut ans, s).unwrap();
        }
        for s in seqs.iter().rev() {
            let got = codec.decode_sequence(&mut ans, s.len()).unwrap();
            assert_eq!(&got, s);
        }
    }

    #[test]
    fn chained_rate_close_to_evidence() {
        // With exact smoothed (but factorized) posteriors the rate should
        // be close to -log p(x), within the factorization KL gap (a few %
        // for a sticky chain).
        let hmm = demo_hmm();
        let codec = HmmCodec::new(&hmm, 18);
        let mut rng = Rng::new(3);
        let seqs: Vec<Vec<usize>> = (0..50).map(|_| sample_sequence(&hmm, 200, &mut rng)).collect();
        let mut ans = Ans::new(9);
        let mut net = 0.0;
        let mut ideal = 0.0;
        for s in &seqs {
            net += codec.encode_sequence(&mut ans, s).unwrap();
            let (_, log_ev) = hmm.smoothed_marginals(s);
            ideal += -log_ev;
        }
        assert!(net >= ideal * 0.99, "net {net} below ideal {ideal}?");
        assert!(
            net < ideal * 1.15,
            "factorized-posterior gap too large: net {net} vs ideal {ideal}"
        );
    }

    #[test]
    fn baum_welch_improves_likelihood_and_rate() {
        // Learn from data generated by the demo HMM, starting from a
        // perturbed model; BB-ANS rate with the learned model must beat
        // the rate with the bad initial model.
        let truth = demo_hmm();
        let mut rng = Rng::new(17);
        let seqs: Vec<Vec<usize>> = (0..40)
            .map(|_| sample_sequence(&truth, 150, &mut rng))
            .collect();

        // Perturbed start: near-uniform everything.
        let k = 3;
        let m = 8;
        let mut learned = Hmm::new(
            vec![1.0 / 3.0; 3],
            {
                let mut t = vec![1.0 / 3.0; 9];
                t[0] += 0.02;
                t[1] -= 0.02; // break symmetry
                t
            },
            {
                let mut e = vec![1.0 / 8.0; 24];
                for z in 0..k {
                    e[z * m + z] += 0.03;
                    e[z * m + (z + 1) % m] -= 0.03;
                }
                e
            },
            m,
        )
        .unwrap();

        let rate = |hmm: &Hmm| -> f64 {
            let codec = HmmCodec::new(hmm, 16);
            let mut ans = Ans::new(3);
            let mut bits = 0.0;
            for s in &seqs {
                bits += codec.encode_sequence(&mut ans, s).unwrap();
            }
            bits / seqs.iter().map(|s| s.len()).sum::<usize>() as f64
        };
        let rate_before = rate(&learned);
        let curve = learned.baum_welch(&seqs, 60);
        assert!(
            curve.last().unwrap() < curve.first().unwrap(),
            "EM must improve log-likelihood: {curve:?}"
        );
        // Monotone within tolerance (EM guarantees non-decreasing LL).
        for w in curve.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "EM regressed: {} -> {}", w[0], w[1]);
        }
        let rate_after = rate(&learned);
        assert!(
            rate_after < rate_before - 0.02,
            "learned model should compress better: {rate_before} -> {rate_after}"
        );
        // Roundtrip still exact with the learned model.
        let codec = HmmCodec::new(&learned, 16);
        let mut ans = Ans::new(4);
        codec.encode_sequence(&mut ans, &seqs[0]).unwrap();
        assert_eq!(codec.decode_sequence(&mut ans, seqs[0].len()).unwrap(), seqs[0]);
    }

    #[test]
    fn empty_sequence_costs_nothing() {
        // T = 0 edge case: a no-op on the coder, interleavable anywhere
        // in a chain.
        let hmm = demo_hmm();
        let codec = HmmCodec::new(&hmm, 16);
        let mut ans = Ans::new(1);
        let bits = codec.encode_sequence(&mut ans, &[]).unwrap();
        assert_eq!(bits, 0.0);
        assert!(ans.is_empty());
        assert_eq!(codec.decode_sequence(&mut ans, 0).unwrap(), Vec::<usize>::new());

        let mut rng = Rng::new(2);
        let x = sample_sequence(&hmm, 30, &mut rng);
        codec.encode_sequence(&mut ans, &x).unwrap();
        codec.encode_sequence(&mut ans, &[]).unwrap();
        assert_eq!(codec.decode_sequence(&mut ans, 0).unwrap(), Vec::<usize>::new());
        assert_eq!(codec.decode_sequence(&mut ans, 30).unwrap(), x);
    }

    #[test]
    fn single_state_hmm_codes_at_emission_entropy() {
        // K = 1 edge case: the latent carries zero information (its codec
        // has a single full-mass symbol), so the rate is pure emission
        // coding and the roundtrip must still invert exactly.
        let hmm = Hmm::new(vec![1.0], vec![1.0], vec![0.5, 0.25, 0.125, 0.125], 4).unwrap();
        let codec = HmmCodec::new(&hmm, 16);
        let mut rng = Rng::new(5);
        let seqs: Vec<Vec<usize>> =
            (0..8).map(|_| sample_sequence(&hmm, 100, &mut rng)).collect();
        let mut ans = Ans::new(3);
        let mut net = 0.0;
        for s in &seqs {
            net += codec.encode_sequence(&mut ans, s).unwrap();
        }
        for s in seqs.iter().rev() {
            assert_eq!(codec.decode_sequence(&mut ans, s.len()).unwrap(), *s);
        }
        let mut ideal = 0.0;
        for s in &seqs {
            for &x in s {
                ideal -= hmm.emit[x].log2();
            }
        }
        assert!((net - ideal).abs() < 0.02 * ideal + 8.0, "net={net} ideal={ideal}");
    }

    #[test]
    fn deterministic_transitions_roundtrip() {
        // Identity transition matrix: the state never changes. The
        // factorized posteriors may still sample "impossible" state
        // flips, which the delta transition priors must code (every
        // symbol keeps freq >= 1 under quantization) and invert exactly.
        let (k, m) = (3usize, 5usize);
        let mut trans = vec![0.0; k * k];
        for i in 0..k {
            trans[i * k + i] = 1.0;
        }
        let mut emit = vec![0.0; k * m];
        for (i, row) in emit.chunks_mut(m).enumerate() {
            for (s, e) in row.iter_mut().enumerate() {
                *e = if s == i { 0.6 } else { 0.1 };
            }
        }
        let hmm = Hmm::new(vec![0.25, 0.5, 0.25], trans, emit, m).unwrap();
        let codec = HmmCodec::new(&hmm, 16);
        let mut rng = Rng::new(9);
        let seqs: Vec<Vec<usize>> = (0..6)
            .map(|i| sample_sequence(&hmm, 10 + 7 * i, &mut rng))
            .collect();
        let mut ans = Ans::new(11);
        for s in &seqs {
            codec.encode_sequence(&mut ans, s).unwrap();
        }
        for s in seqs.iter().rev() {
            assert_eq!(codec.decode_sequence(&mut ans, s.len()).unwrap(), *s);
        }
    }

    /// Golden-vector replay (satellite): the serialized bitstream of a
    /// fixed dyadic-parameter HMM is pinned byte-for-byte. Every float on
    /// this model is exact (delta posteriors from deterministic
    /// transitions, dyadic emission PMFs), so the bytes are a pure
    /// function of the coder, the quantizer and the op schedule — if this
    /// test breaks, chained HMM streams in the wild stop decoding and the
    /// format owes a version bump.
    #[test]
    fn golden_bitstream_replay() {
        let hmm = Hmm::new(
            vec![1.0, 0.0],
            vec![1.0, 0.0, 0.0, 1.0],
            vec![0.5, 0.25, 0.125, 0.125, 0.125, 0.125, 0.25, 0.5],
            4,
        )
        .unwrap();
        let codec = HmmCodec::new(&hmm, 16);
        let seqs: Vec<Vec<usize>> = vec![
            vec![0, 2, 1, 0, 3, 1, 0, 0],
            vec![1, 1, 2, 0],
            vec![3, 0, 2, 2, 1, 0],
        ];
        let mut ans = Ans::new(0xD00D);
        for s in &seqs {
            codec.encode_sequence(&mut ans, s).unwrap();
        }
        #[rustfmt::skip]
        let want: Vec<u8> = vec![
            // head (LE u64)
            0xD6, 0x09, 0x71, 0xFF, 0x07, 0x00, 0x00, 0x00,
            // clean_words_used = 1 (LE u64)
            0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // stream len = 2 (LE u64)
            0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // stream words (LE u32 each)
            0x98, 0x94, 0x63, 0x8A, 0xD8, 0xD3, 0x7A, 0x78,
        ];
        assert_eq!(ans.to_message().to_bytes(), want, "HMM bitstream drifted");

        // The pinned bytes replay through a fresh coder.
        let msg = crate::ans::AnsMessage::from_bytes(&want).unwrap();
        let mut ans2 = Ans::from_message(&msg, 0xD00D);
        for s in seqs.iter().rev() {
            assert_eq!(codec.decode_sequence(&mut ans2, s.len()).unwrap(), *s);
        }
    }

    #[test]
    fn startup_bits_scale_with_sequence_length() {
        // The paper's §4.1 concern, measured: clean bits consumed by the
        // FIRST sequence grow with T.
        let hmm = demo_hmm();
        let codec = HmmCodec::new(&hmm, 16);
        let mut used = Vec::new();
        for &t_len in &[10usize, 100, 1000] {
            let mut rng = Rng::new(4);
            let x = sample_sequence(&hmm, t_len, &mut rng);
            let mut ans = Ans::new(7);
            codec.encode_sequence(&mut ans, &x).unwrap();
            used.push(ans.clean_bits_used());
        }
        assert!(used[1] > used[0]);
        assert!(used[2] > used[1] * 4, "startup bits {used:?} should scale ~T");
    }
}
