//! On-disk/wire container for a BB-ANS compressed dataset.
//!
//! The header records everything the decoder needs to rebuild the exact
//! coding process: the model, the backend that produced the distribution
//! parameters (floating-point results differ across backends at ULP
//! level, and BB-ANS needs bit-exact agreement), the coding precisions,
//! the clean-bit seed, and the image count. The payload is the serialized
//! ANS message.

use anyhow::{bail, Context, Result};

use super::BbAnsConfig;
use crate::ans::AnsMessage;

pub const MAGIC: &[u8; 4] = b"BBC1";

#[derive(Debug, Clone, PartialEq)]
pub struct Container {
    pub model: String,
    pub backend_id: String,
    pub cfg: BbAnsConfig,
    pub num_images: u32,
    pub pixels: u32,
    pub message: AnsMessage,
}

impl Container {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(1u8); // version
        push_str(&mut out, &self.model);
        push_str(&mut out, &self.backend_id);
        out.push(self.cfg.latent_bits as u8);
        out.push(self.cfg.posterior_prec as u8);
        out.push(self.cfg.pixel_prec as u8);
        out.extend_from_slice(&self.cfg.clean_seed.to_le_bytes());
        out.extend_from_slice(&self.num_images.to_le_bytes());
        out.extend_from_slice(&self.pixels.to_le_bytes());
        out.extend_from_slice(&self.message.to_bytes());
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > b.len() {
                bail!("container truncated at {} (+{n})", *pos);
            }
            let s = &b[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != MAGIC {
            bail!("bad container magic");
        }
        let version = take(&mut pos, 1)?[0];
        if version != 1 {
            bail!("unsupported container version {version}");
        }
        let model = read_str(b, &mut pos).context("model name")?;
        let backend_id = read_str(b, &mut pos).context("backend id")?;
        let latent_bits = take(&mut pos, 1)?[0] as u32;
        let posterior_prec = take(&mut pos, 1)?[0] as u32;
        let pixel_prec = take(&mut pos, 1)?[0] as u32;
        let clean_seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let num_images = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let pixels = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let message = AnsMessage::from_bytes(&b[pos..]).context("ANS payload")?;
        let cfg = BbAnsConfig {
            latent_bits,
            posterior_prec,
            pixel_prec,
            clean_seed,
        };
        cfg.validate()?;
        Ok(Self {
            model,
            backend_id,
            cfg,
            num_images,
            pixels,
            message,
        })
    }

    /// Total compressed size in bytes (header + payload).
    pub fn byte_len(&self) -> usize {
        self.to_bytes().len()
    }

    /// Compression rate in bits per pixel-dimension, counting the full
    /// container (header amortizes over the dataset).
    pub fn bits_per_dim(&self) -> f64 {
        (self.byte_len() as f64 * 8.0) / (self.num_images as f64 * self.pixels as f64)
    }

    /// Rate counting only the ANS message (what the paper reports; the
    /// model is communicated separately, §4.3).
    pub fn payload_bits_per_dim(&self) -> f64 {
        self.message.bit_len() as f64 / (self.num_images as f64 * self.pixels as f64)
    }
}

fn push_str(out: &mut Vec<u8>, s: &str) {
    assert!(s.len() <= u8::MAX as usize, "string too long for container");
    out.push(s.len() as u8);
    out.extend_from_slice(s.as_bytes());
}

fn read_str(b: &[u8], pos: &mut usize) -> Result<String> {
    if *pos >= b.len() {
        bail!("truncated string length");
    }
    let n = b[*pos] as usize;
    *pos += 1;
    if *pos + n > b.len() {
        bail!("truncated string body");
    }
    let s = std::str::from_utf8(&b[*pos..*pos + n])
        .context("string utf8")?
        .to_string();
    *pos += n;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Container {
        Container {
            model: "bin".into(),
            backend_id: "native".into(),
            cfg: BbAnsConfig::default(),
            num_images: 17,
            pixels: 784,
            message: AnsMessage {
                head: crate::ans::RANS_L + 12345,
                stream: vec![1, 2, 3, 0xdeadbeef],
                clean_words_used: 13,
            },
        }
    }

    #[test]
    fn roundtrip() {
        let c = sample();
        let bytes = c.to_bytes();
        let c2 = Container::from_bytes(&bytes).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn rejects_corruption() {
        let bytes = sample().to_bytes();
        assert!(Container::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Container::from_bytes(&bad).is_err());
        let mut badver = bytes.clone();
        badver[4] = 9;
        assert!(Container::from_bytes(&badver).is_err());
    }

    #[test]
    fn rate_accounting() {
        let c = sample();
        let payload_bits = c.message.bit_len() as f64;
        assert!((c.payload_bits_per_dim() - payload_bits / (17.0 * 784.0)).abs() < 1e-12);
        assert!(c.bits_per_dim() > c.payload_bits_per_dim());
    }
}
