//! On-disk/wire container for a BB-ANS compressed dataset.
//!
//! The header records everything the decoder needs to rebuild the exact
//! coding process: the model, the backend that produced the distribution
//! parameters (floating-point results differ across backends at ULP
//! level, and BB-ANS needs bit-exact agreement), the coding precisions,
//! the clean-bit seed, and the image count. The payload is the serialized
//! ANS message.

use anyhow::{bail, Context, Result};

use super::{BbAnsConfig, VaeCodec};
use crate::ans::AnsMessage;
use crate::model::Backend;

pub const MAGIC: &[u8; 4] = b"BBC1";

/// Magic of the chunk-parallel container format.
pub const MAGIC_PARALLEL: &[u8; 4] = b"BBC2";

#[derive(Debug, Clone, PartialEq)]
pub struct Container {
    pub model: String,
    pub backend_id: String,
    pub cfg: BbAnsConfig,
    pub num_images: u32,
    pub pixels: u32,
    pub message: AnsMessage,
}

impl Container {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(1u8); // version
        push_str(&mut out, &self.model);
        push_str(&mut out, &self.backend_id);
        out.push(self.cfg.latent_bits as u8);
        out.push(self.cfg.posterior_prec as u8);
        out.push(self.cfg.pixel_prec as u8);
        out.extend_from_slice(&self.cfg.clean_seed.to_le_bytes());
        out.extend_from_slice(&self.num_images.to_le_bytes());
        out.extend_from_slice(&self.pixels.to_le_bytes());
        out.extend_from_slice(&self.message.to_bytes());
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if n > b.len() - *pos {
                bail!("container truncated at {} (+{n})", *pos);
            }
            let s = &b[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != MAGIC {
            bail!("bad container magic");
        }
        let version = take(&mut pos, 1)?[0];
        if version != 1 {
            bail!("unsupported container version {version}");
        }
        let model = read_str(b, &mut pos).context("model name")?;
        let backend_id = read_str(b, &mut pos).context("backend id")?;
        let latent_bits = take(&mut pos, 1)?[0] as u32;
        let posterior_prec = take(&mut pos, 1)?[0] as u32;
        let pixel_prec = take(&mut pos, 1)?[0] as u32;
        let clean_seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let num_images = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let pixels = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let message = AnsMessage::from_bytes(&b[pos..]).context("ANS payload")?;
        let cfg = BbAnsConfig {
            latent_bits,
            posterior_prec,
            pixel_prec,
            clean_seed,
        };
        cfg.validate()?;
        Ok(Self {
            model,
            backend_id,
            cfg,
            num_images,
            pixels,
            message,
        })
    }

    /// Total compressed size in bytes (header + payload).
    pub fn byte_len(&self) -> usize {
        self.to_bytes().len()
    }

    /// Compression rate in bits per pixel-dimension, counting the full
    /// container (header amortizes over the dataset).
    pub fn bits_per_dim(&self) -> f64 {
        (self.byte_len() as f64 * 8.0) / (self.num_images as f64 * self.pixels as f64)
    }

    /// Rate counting only the ANS message (what the paper reports; the
    /// model is communicated separately, §4.3).
    pub fn payload_bits_per_dim(&self) -> f64 {
        self.message.bit_len() as f64 / (self.num_images as f64 * self.pixels as f64)
    }
}

/// Clean-bit seed of chunk `chunk` in a chunk-parallel container: the
/// container-level seed diversified per chunk through SplitMix64, so
/// every chain draws an independent clean-bit stream while remaining
/// fully determined by the header.
pub fn chunk_seed(clean_seed: u64, chunk: usize) -> u64 {
    let mut sm = crate::util::rng::SplitMix64::new(clean_seed ^ (((chunk as u64) << 1) | 1));
    sm.next_u64()
}

/// One independent BB-ANS chain of a [`ParallelContainer`].
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkEntry {
    pub num_images: u32,
    pub message: AnsMessage,
}

/// Chunk-parallel container (format `BBC2`): the image stream is split
/// into independently seeded chunks, each its own BB-ANS chain, so
/// encode and decode fan out across a thread pool (paper §4.2's
/// parallelization argument made concrete; `benches/parallel.rs`
/// measures the speedup).
///
/// Header layout (all little-endian):
///
/// ```text
/// magic "BBC2" | version u8 | model str | backend_id str
/// latent_bits u8 | posterior_prec u8 | pixel_prec u8 | clean_seed u64
/// pixels u32 | num_chunks u32
/// per chunk: num_images u32, payload_len u64     (the offset table)
/// concatenated chunk payloads (AnsMessage bytes)
/// ```
///
/// The offset table lets a decoder slice every payload without scanning,
/// so chunk decodes start in parallel immediately.
#[derive(Debug, Clone, PartialEq)]
pub struct ParallelContainer {
    pub model: String,
    pub backend_id: String,
    pub cfg: BbAnsConfig,
    pub pixels: u32,
    pub chunks: Vec<ChunkEntry>,
}

impl ParallelContainer {
    /// Encode `images` into `n_chunks` independent chains using the
    /// codec's thread-parallel path (requires a `Sync` backend, e.g. the
    /// pure-Rust `NativeVae`).
    pub fn encode_with<B: Backend + Sync + ?Sized>(
        codec: &VaeCodec<'_, B>,
        images: &[Vec<u8>],
        n_chunks: usize,
    ) -> Result<Self> {
        Self::encode_with_workers(codec, images, n_chunks, super::default_workers())
    }

    /// [`Self::encode_with`] pinning the worker-pool size (the container
    /// format depends only on `n_chunks`; `workers` is a machine knob and
    /// never changes the produced bytes).
    pub fn encode_with_workers<B: Backend + Sync + ?Sized>(
        codec: &VaeCodec<'_, B>,
        images: &[Vec<u8>],
        n_chunks: usize,
        workers: usize,
    ) -> Result<Self> {
        let meta = codec.backend().meta();
        let chunks = codec.encode_dataset_chunked_with_workers(images, n_chunks, workers)?;
        Ok(Self {
            model: meta.name.clone(),
            backend_id: codec.backend().backend_id(),
            cfg: codec.cfg,
            pixels: meta.pixels as u32,
            chunks,
        })
    }

    /// Thread-parallel decode (inverse of [`Self::encode_with`]).
    pub fn decode_with<B: Backend + Sync + ?Sized>(
        &self,
        codec: &VaeCodec<'_, B>,
    ) -> Result<Vec<Vec<u8>>> {
        self.validate_for(codec)?;
        codec.decode_dataset_chunked(&self.chunks)
    }

    /// [`Self::decode_with`] pinning the worker-pool size.
    pub fn decode_with_workers<B: Backend + Sync + ?Sized>(
        &self,
        codec: &VaeCodec<'_, B>,
        workers: usize,
    ) -> Result<Vec<Vec<u8>>> {
        self.validate_for(codec)?;
        codec.decode_dataset_chunked_with_workers(&self.chunks, workers)
    }

    /// Single-threaded decode for backends that are not `Sync` (the
    /// coordinator's boxed `dyn Backend`); chunk-for-chunk identical to
    /// [`Self::decode_with`].
    pub fn decode_sequential<B: Backend + ?Sized>(
        &self,
        codec: &VaeCodec<'_, B>,
    ) -> Result<Vec<Vec<u8>>> {
        self.validate_for(codec)?;
        let mut out = Vec::with_capacity(self.num_images() as usize);
        for (ci, c) in self.chunks.iter().enumerate() {
            let mut ans =
                crate::ans::Ans::from_message(&c.message, chunk_seed(self.cfg.clean_seed, ci));
            out.extend(codec.decode_dataset(&mut ans, c.num_images as usize)?);
        }
        Ok(out)
    }

    fn validate_for<B: Backend + ?Sized>(&self, codec: &VaeCodec<'_, B>) -> Result<()> {
        let meta = codec.backend().meta();
        if self.pixels as usize != meta.pixels {
            bail!(
                "container has {}-pixel images, model wants {}",
                self.pixels,
                meta.pixels
            );
        }
        if self.cfg != codec.cfg {
            bail!("decode codec config does not match the container header");
        }
        Ok(())
    }

    pub fn num_images(&self) -> u32 {
        self.chunks.iter().map(|c| c.num_images).sum()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_PARALLEL);
        out.push(2u8); // version
        push_str(&mut out, &self.model);
        push_str(&mut out, &self.backend_id);
        out.push(self.cfg.latent_bits as u8);
        out.push(self.cfg.posterior_prec as u8);
        out.push(self.cfg.pixel_prec as u8);
        out.extend_from_slice(&self.cfg.clean_seed.to_le_bytes());
        out.extend_from_slice(&self.pixels.to_le_bytes());
        out.extend_from_slice(&(self.chunks.len() as u32).to_le_bytes());
        // Offset table: (num_images, payload byte length) per chunk.
        let payloads: Vec<Vec<u8>> = self.chunks.iter().map(|c| c.message.to_bytes()).collect();
        for (c, p) in self.chunks.iter().zip(&payloads) {
            out.extend_from_slice(&c.num_images.to_le_bytes());
            out.extend_from_slice(&(p.len() as u64).to_le_bytes());
        }
        for p in &payloads {
            out.extend_from_slice(p);
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        // `pos <= b.len()` is an invariant, so `b.len() - *pos` cannot
        // underflow and an attacker-controlled huge `n` cannot wrap the
        // bounds check.
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if n > b.len() - *pos {
                bail!("parallel container truncated at {} (+{n})", *pos);
            }
            let s = &b[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != MAGIC_PARALLEL {
            bail!("bad parallel-container magic");
        }
        let version = take(&mut pos, 1)?[0];
        if version != 2 {
            bail!("unsupported parallel-container version {version}");
        }
        let model = read_str(b, &mut pos).context("model name")?;
        let backend_id = read_str(b, &mut pos).context("backend id")?;
        let latent_bits = take(&mut pos, 1)?[0] as u32;
        let posterior_prec = take(&mut pos, 1)?[0] as u32;
        let pixel_prec = take(&mut pos, 1)?[0] as u32;
        let clean_seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let pixels = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let n_chunks = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        if n_chunks > 1 << 20 {
            bail!("implausible chunk count {n_chunks}");
        }
        let mut table = Vec::with_capacity(n_chunks);
        for _ in 0..n_chunks {
            let num_images = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
            let len = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
            table.push((num_images, len));
        }
        let mut chunks = Vec::with_capacity(n_chunks);
        for (ci, (num_images, len)) in table.into_iter().enumerate() {
            let payload = take(&mut pos, len)?;
            let message = AnsMessage::from_bytes(payload)
                .with_context(|| format!("chunk {ci} payload"))?;
            chunks.push(ChunkEntry {
                num_images,
                message,
            });
        }
        if pos != b.len() {
            bail!("parallel container has {} trailing bytes", b.len() - pos);
        }
        let cfg = BbAnsConfig {
            latent_bits,
            posterior_prec,
            pixel_prec,
            clean_seed,
        };
        cfg.validate()?;
        Ok(Self {
            model,
            backend_id,
            cfg,
            pixels,
            chunks,
        })
    }

    /// Total compressed size in bytes (header + payloads).
    pub fn byte_len(&self) -> usize {
        self.to_bytes().len()
    }

    /// Compression rate in bits per pixel-dimension over the whole
    /// container.
    pub fn bits_per_dim(&self) -> f64 {
        (self.byte_len() as f64 * 8.0) / (self.num_images() as f64 * self.pixels as f64)
    }
}

fn push_str(out: &mut Vec<u8>, s: &str) {
    assert!(s.len() <= u8::MAX as usize, "string too long for container");
    out.push(s.len() as u8);
    out.extend_from_slice(s.as_bytes());
}

fn read_str(b: &[u8], pos: &mut usize) -> Result<String> {
    if *pos >= b.len() {
        bail!("truncated string length");
    }
    let n = b[*pos] as usize;
    *pos += 1;
    if *pos + n > b.len() {
        bail!("truncated string body");
    }
    let s = std::str::from_utf8(&b[*pos..*pos + n])
        .context("string utf8")?
        .to_string();
    *pos += n;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Container {
        Container {
            model: "bin".into(),
            backend_id: "native".into(),
            cfg: BbAnsConfig::default(),
            num_images: 17,
            pixels: 784,
            message: AnsMessage {
                head: crate::ans::RANS_L + 12345,
                stream: vec![1, 2, 3, 0xdeadbeef],
                clean_words_used: 13,
            },
        }
    }

    #[test]
    fn roundtrip() {
        let c = sample();
        let bytes = c.to_bytes();
        let c2 = Container::from_bytes(&bytes).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn rejects_corruption() {
        let bytes = sample().to_bytes();
        assert!(Container::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Container::from_bytes(&bad).is_err());
        let mut badver = bytes.clone();
        badver[4] = 9;
        assert!(Container::from_bytes(&badver).is_err());
    }

    #[test]
    fn rate_accounting() {
        let c = sample();
        let payload_bits = c.message.bit_len() as f64;
        assert!((c.payload_bits_per_dim() - payload_bits / (17.0 * 784.0)).abs() < 1e-12);
        assert!(c.bits_per_dim() > c.payload_bits_per_dim());
    }

    fn sample_parallel() -> ParallelContainer {
        ParallelContainer {
            model: "m".into(),
            backend_id: "native".into(),
            cfg: BbAnsConfig {
                latent_bits: 12,
                posterior_prec: 24,
                pixel_prec: 16,
                clean_seed: 7,
            },
            pixels: 4,
            chunks: vec![ChunkEntry {
                num_images: 1,
                message: AnsMessage {
                    head: crate::ans::RANS_L + 3,
                    stream: vec![0xAABB_CCDD],
                    clean_words_used: 2,
                },
            }],
        }
    }

    /// Golden vector: the BBC2 wire format is pinned byte-for-byte. If
    /// this test breaks, the container version must be bumped — decoders
    /// in the wild hold bytes produced by this exact layout.
    #[test]
    fn parallel_container_golden_bytes() {
        #[rustfmt::skip]
        let want: Vec<u8> = vec![
            // magic "BBC2", version
            0x42, 0x42, 0x43, 0x32, 0x02,
            // model "m"
            0x01, 0x6D,
            // backend_id "native"
            0x06, 0x6E, 0x61, 0x74, 0x69, 0x76, 0x65,
            // latent_bits, posterior_prec, pixel_prec
            0x0C, 0x18, 0x10,
            // clean_seed = 7 (LE u64)
            0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // pixels = 4 (LE u32)
            0x04, 0x00, 0x00, 0x00,
            // num_chunks = 1 (LE u32)
            0x01, 0x00, 0x00, 0x00,
            // offset table: num_images = 1, payload_len = 28
            0x01, 0x00, 0x00, 0x00,
            0x1C, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // payload: head = 2^32 + 3 (LE u64)
            0x03, 0x00, 0x00, 0x00, 0x01, 0x00, 0x00, 0x00,
            // clean_words_used = 2 (LE u64)
            0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // stream len = 1 (LE u64)
            0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // stream word 0xAABBCCDD (LE u32)
            0xDD, 0xCC, 0xBB, 0xAA,
        ];
        let got = sample_parallel().to_bytes();
        assert_eq!(got, want, "BBC2 wire format drifted");
        // And the pinned bytes parse back to the same container.
        assert_eq!(ParallelContainer::from_bytes(&want).unwrap(), sample_parallel());
    }

    #[test]
    fn parallel_container_rejects_corruption() {
        let bytes = sample_parallel().to_bytes();
        assert!(ParallelContainer::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(ParallelContainer::from_bytes(&bad_magic).is_err());
        let mut bad_version = bytes.clone();
        bad_version[4] = 9;
        assert!(ParallelContainer::from_bytes(&bad_version).is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(ParallelContainer::from_bytes(&trailing).is_err());
    }

    #[test]
    fn chunk_seeds_are_distinct_and_stable() {
        // Chains must draw independent clean bits; seeds are pure
        // functions of (container seed, chunk index).
        let mut seen = std::collections::BTreeSet::new();
        for chunk in 0..64 {
            let s = chunk_seed(0xBBA4_55EE, chunk);
            assert_eq!(s, chunk_seed(0xBBA4_55EE, chunk), "must be deterministic");
            assert!(seen.insert(s), "chunk {chunk} repeats a seed");
        }
    }
}
