//! On-disk/wire container for a BB-ANS compressed dataset.
//!
//! The header records everything the decoder needs to rebuild the exact
//! coding process: the model, the backend that produced the distribution
//! parameters (floating-point results differ across backends at ULP
//! level, and BB-ANS needs bit-exact agreement), the coding precisions,
//! the clean-bit seed, and the image count. The payload is the serialized
//! ANS message.

use anyhow::{bail, Context, Result};

use super::hierarchy::{HierCodec, Schedule};
use super::{BbAnsConfig, VaeCodec};
use crate::ans::AnsMessage;
use crate::model::hierarchy::{HierBackend, HierMeta, HierVae};
use crate::model::{Backend, Likelihood};

pub const MAGIC: &[u8; 4] = b"BBC1";

/// Magic of the chunk-parallel container format.
pub const MAGIC_PARALLEL: &[u8; 4] = b"BBC2";

/// Magic of the hierarchical-latent (Bit-Swap) container format.
pub const MAGIC_HIER: &[u8; 4] = b"BBC3";

/// Admission caps applied when parsing ANY container: headers are
/// untrusted on the serving path, and `num_images`/`pixels` directly size
/// decode work and output memory. Generous for every real dataset (full
/// ImageNet64 is ~1.2M images / ~4.9G pixels) while keeping a crafted
/// header's damage bounded; serving deployments that want tighter
/// admission control should gate above this layer.
const MAX_IMAGES: u64 = 1 << 24;
const MAX_TOTAL_PIXELS: u64 = 1 << 32;

/// Shared header sanity check: total image count and total decoded bytes.
/// `pub(crate)` so the wire protocol can hold untrusted request grids to
/// the same budget as untrusted container headers.
pub(crate) fn check_decode_budget(num_images: u64, pixels: u64) -> Result<()> {
    if num_images > MAX_IMAGES {
        bail!("implausible image count {num_images} (limit {MAX_IMAGES})");
    }
    let total = num_images.saturating_mul(pixels);
    if total > MAX_TOTAL_PIXELS {
        bail!("container would decode {total} pixels (limit {MAX_TOTAL_PIXELS})");
    }
    Ok(())
}

#[derive(Debug, Clone, PartialEq)]
pub struct Container {
    pub model: String,
    pub backend_id: String,
    pub cfg: BbAnsConfig,
    pub num_images: u32,
    pub pixels: u32,
    pub message: AnsMessage,
}

impl Container {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(1u8); // version
        push_str(&mut out, &self.model);
        push_str(&mut out, &self.backend_id);
        out.push(self.cfg.latent_bits as u8);
        out.push(self.cfg.posterior_prec as u8);
        out.push(self.cfg.pixel_prec as u8);
        out.extend_from_slice(&self.cfg.clean_seed.to_le_bytes());
        out.extend_from_slice(&self.num_images.to_le_bytes());
        out.extend_from_slice(&self.pixels.to_le_bytes());
        out.extend_from_slice(&self.message.to_bytes());
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if n > b.len() - *pos {
                bail!("container truncated at {} (+{n})", *pos);
            }
            let s = &b[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let magic = take(&mut pos, 4)?;
        if magic != MAGIC {
            bail!("bad container magic {magic:02x?} (want {MAGIC:02x?} = \"BBC1\")");
        }
        let version = take(&mut pos, 1)?[0];
        if version != 1 {
            bail!("unsupported BBC1 container version {version} (this build reads version 1)");
        }
        let model = read_str(b, &mut pos).context("model name")?;
        let backend_id = read_str(b, &mut pos).context("backend id")?;
        let latent_bits = take(&mut pos, 1)?[0] as u32;
        let posterior_prec = take(&mut pos, 1)?[0] as u32;
        let pixel_prec = take(&mut pos, 1)?[0] as u32;
        let clean_seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let num_images = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let pixels = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        check_decode_budget(num_images as u64, pixels as u64)?;
        let message = AnsMessage::from_bytes(&b[pos..]).context("ANS payload")?;
        let canonical = 24 + 4 * message.stream.len();
        if b.len() - pos != canonical {
            bail!(
                "container has {} trailing bytes after the ANS payload",
                b.len() - pos - canonical
            );
        }
        let cfg = BbAnsConfig {
            latent_bits,
            posterior_prec,
            pixel_prec,
            clean_seed,
        };
        cfg.validate()?;
        Ok(Self {
            model,
            backend_id,
            cfg,
            num_images,
            pixels,
            message,
        })
    }

    /// Total compressed size in bytes (header + payload).
    pub fn byte_len(&self) -> usize {
        self.to_bytes().len()
    }

    /// Compression rate in bits per pixel-dimension, counting the full
    /// container (header amortizes over the dataset).
    pub fn bits_per_dim(&self) -> f64 {
        (self.byte_len() as f64 * 8.0) / (self.num_images as f64 * self.pixels as f64)
    }

    /// Rate counting only the ANS message (what the paper reports; the
    /// model is communicated separately, §4.3).
    pub fn payload_bits_per_dim(&self) -> f64 {
        self.message.bit_len() as f64 / (self.num_images as f64 * self.pixels as f64)
    }
}

/// Clean-bit seed of chunk `chunk` in a chunk-parallel container: the
/// container-level seed diversified per chunk through SplitMix64, so
/// every chain draws an independent clean-bit stream while remaining
/// fully determined by the header.
pub fn chunk_seed(clean_seed: u64, chunk: usize) -> u64 {
    let mut sm = crate::util::rng::SplitMix64::new(clean_seed ^ (((chunk as u64) << 1) | 1));
    sm.next_u64()
}

/// One independent BB-ANS chain of a [`ParallelContainer`].
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkEntry {
    pub num_images: u32,
    pub message: AnsMessage,
}

/// Chunk-parallel container (format `BBC2`): the image stream is split
/// into independently seeded chunks, each its own BB-ANS chain, so
/// encode and decode fan out across a thread pool (paper §4.2's
/// parallelization argument made concrete; `benches/parallel.rs`
/// measures the speedup).
///
/// Header layout (all little-endian):
///
/// ```text
/// magic "BBC2" | version u8 | model str | backend_id str
/// latent_bits u8 | posterior_prec u8 | pixel_prec u8 | clean_seed u64
/// pixels u32 | num_chunks u32
/// per chunk: num_images u32, payload_len u64     (the offset table)
/// concatenated chunk payloads (AnsMessage bytes)
/// ```
///
/// The offset table lets a decoder slice every payload without scanning,
/// so chunk decodes start in parallel immediately.
#[derive(Debug, Clone, PartialEq)]
pub struct ParallelContainer {
    pub model: String,
    pub backend_id: String,
    pub cfg: BbAnsConfig,
    pub pixels: u32,
    pub chunks: Vec<ChunkEntry>,
}

impl ParallelContainer {
    /// Encode `images` into `n_chunks` independent chains using the
    /// codec's thread-parallel path (requires a `Sync` backend, e.g. the
    /// pure-Rust `NativeVae`).
    pub fn encode_with<B: Backend + Sync + ?Sized>(
        codec: &VaeCodec<'_, B>,
        images: &[Vec<u8>],
        n_chunks: usize,
    ) -> Result<Self> {
        Self::encode_with_workers(codec, images, n_chunks, super::default_workers())
    }

    /// [`Self::encode_with`] pinning the worker-pool size (the container
    /// format depends only on `n_chunks`; `workers` is a machine knob and
    /// never changes the produced bytes).
    pub fn encode_with_workers<B: Backend + Sync + ?Sized>(
        codec: &VaeCodec<'_, B>,
        images: &[Vec<u8>],
        n_chunks: usize,
        workers: usize,
    ) -> Result<Self> {
        let meta = codec.backend().meta();
        let chunks = codec.encode_dataset_chunked_with_workers(images, n_chunks, workers)?;
        Ok(Self {
            model: meta.name.clone(),
            backend_id: codec.backend().backend_id(),
            cfg: codec.cfg,
            pixels: meta.pixels as u32,
            chunks,
        })
    }

    /// [`Self::encode_with`] that also returns the per-image rate ledger
    /// (ISSUE 9). The ledger is a pure observer of the coder's effective
    /// length: the produced container is byte-identical to the unledgered
    /// path (pinned by `ledgered_encode_is_byte_identical_and_elbo_consistent`).
    pub fn encode_with_ledger<B: Backend + Sync + ?Sized>(
        codec: &VaeCodec<'_, B>,
        images: &[Vec<u8>],
        n_chunks: usize,
    ) -> Result<(Self, crate::obs::Ledger)> {
        let meta = codec.backend().meta();
        let (chunks, ledger) =
            codec.encode_dataset_chunked_ledgered(images, n_chunks, super::default_workers())?;
        Ok((
            Self {
                model: meta.name.clone(),
                backend_id: codec.backend().backend_id(),
                cfg: codec.cfg,
                pixels: meta.pixels as u32,
                chunks,
            },
            ledger,
        ))
    }

    /// Thread-parallel decode (inverse of [`Self::encode_with`]).
    pub fn decode_with<B: Backend + Sync + ?Sized>(
        &self,
        codec: &VaeCodec<'_, B>,
    ) -> Result<Vec<Vec<u8>>> {
        self.validate_for(codec)?;
        codec.decode_dataset_chunked(&self.chunks)
    }

    /// [`Self::decode_with`] pinning the worker-pool size.
    pub fn decode_with_workers<B: Backend + Sync + ?Sized>(
        &self,
        codec: &VaeCodec<'_, B>,
        workers: usize,
    ) -> Result<Vec<Vec<u8>>> {
        self.validate_for(codec)?;
        codec.decode_dataset_chunked_with_workers(&self.chunks, workers)
    }

    /// Single-threaded decode for backends that are not `Sync` (the
    /// coordinator's boxed `dyn Backend`); chunk-for-chunk identical to
    /// [`Self::decode_with`].
    pub fn decode_sequential<B: Backend + ?Sized>(
        &self,
        codec: &VaeCodec<'_, B>,
    ) -> Result<Vec<Vec<u8>>> {
        self.validate_for(codec)?;
        let mut out = Vec::with_capacity(self.num_images() as usize);
        for (ci, c) in self.chunks.iter().enumerate() {
            let mut ans =
                crate::ans::Ans::from_message(&c.message, chunk_seed(self.cfg.clean_seed, ci));
            out.extend(codec.decode_dataset(&mut ans, c.num_images as usize)?);
        }
        Ok(out)
    }

    fn validate_for<B: Backend + ?Sized>(&self, codec: &VaeCodec<'_, B>) -> Result<()> {
        let meta = codec.backend().meta();
        if self.pixels as usize != meta.pixels {
            bail!(
                "container has {}-pixel images, model wants {}",
                self.pixels,
                meta.pixels
            );
        }
        if self.cfg != codec.cfg {
            bail!("decode codec config does not match the container header");
        }
        Ok(())
    }

    pub fn num_images(&self) -> u32 {
        self.chunks.iter().map(|c| c.num_images).sum()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_PARALLEL);
        out.push(2u8); // version
        push_str(&mut out, &self.model);
        push_str(&mut out, &self.backend_id);
        out.push(self.cfg.latent_bits as u8);
        out.push(self.cfg.posterior_prec as u8);
        out.push(self.cfg.pixel_prec as u8);
        out.extend_from_slice(&self.cfg.clean_seed.to_le_bytes());
        out.extend_from_slice(&self.pixels.to_le_bytes());
        out.extend_from_slice(&(self.chunks.len() as u32).to_le_bytes());
        // Offset table: (num_images, payload byte length) per chunk.
        let payloads: Vec<Vec<u8>> = self.chunks.iter().map(|c| c.message.to_bytes()).collect();
        for (c, p) in self.chunks.iter().zip(&payloads) {
            out.extend_from_slice(&c.num_images.to_le_bytes());
            out.extend_from_slice(&(p.len() as u64).to_le_bytes());
        }
        for p in &payloads {
            out.extend_from_slice(p);
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        // `pos <= b.len()` is an invariant, so `b.len() - *pos` cannot
        // underflow and an attacker-controlled huge `n` cannot wrap the
        // bounds check.
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if n > b.len() - *pos {
                bail!("parallel container truncated at {} (+{n})", *pos);
            }
            let s = &b[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let magic = take(&mut pos, 4)?;
        if magic != MAGIC_PARALLEL {
            bail!(
                "bad parallel-container magic {magic:02x?} (want {MAGIC_PARALLEL:02x?} = \"BBC2\")"
            );
        }
        let version = take(&mut pos, 1)?[0];
        if version != 2 {
            bail!("unsupported BBC2 container version {version} (this build reads version 2)");
        }
        let model = read_str(b, &mut pos).context("model name")?;
        let backend_id = read_str(b, &mut pos).context("backend id")?;
        let latent_bits = take(&mut pos, 1)?[0] as u32;
        let posterior_prec = take(&mut pos, 1)?[0] as u32;
        let pixel_prec = take(&mut pos, 1)?[0] as u32;
        let clean_seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let pixels = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let n_chunks = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        if n_chunks > 1 << 20 {
            bail!("implausible chunk count {n_chunks}");
        }
        let table = read_chunk_table("parallel", b, &mut pos, n_chunks)?;
        let total: u64 = table.iter().map(|&(n, _)| n as u64).sum();
        check_decode_budget(total, pixels as u64)?;
        let chunks = read_chunk_payloads("parallel", b, &mut pos, table)?;
        if pos != b.len() {
            bail!("parallel container has {} trailing bytes", b.len() - pos);
        }
        let cfg = BbAnsConfig {
            latent_bits,
            posterior_prec,
            pixel_prec,
            clean_seed,
        };
        cfg.validate()?;
        Ok(Self {
            model,
            backend_id,
            cfg,
            pixels,
            chunks,
        })
    }

    /// Total compressed size in bytes (header + payloads).
    pub fn byte_len(&self) -> usize {
        self.to_bytes().len()
    }

    /// Compression rate in bits per pixel-dimension over the whole
    /// container.
    pub fn bits_per_dim(&self) -> f64 {
        (self.byte_len() as f64 * 8.0) / (self.num_images() as f64 * self.pixels as f64)
    }
}

/// Hierarchical-latent container (format `BBC3`): chunk-parallel like
/// `BBC2`, but the stream was produced by an L-layer [`HierCodec`] under a
/// recorded coding [`Schedule`]. The header is **self-describing**: it
/// carries the full model geometry (layer dims, hidden width, likelihood)
/// plus the deterministic weight seed, so a decoder can rebuild the exact
/// backend with [`HierContainer::build_backend`] without an artifact
/// bundle (weight seed 0 is reserved for trained artifacts, loaded by
/// model name once those exist).
///
/// Header layout (all little-endian):
///
/// ```text
/// magic "BBC3" | version u8 | model str | backend_id str
/// schedule u8 | latent_bits u8 | posterior_prec u8 | pixel_prec u8
/// clean_seed u64 | likelihood u8 | hidden u32 | weight_seed u64
/// pixels u32 | n_layers u8 | per layer: dim u32
/// num_chunks u32
/// per chunk: num_images u32, payload_len u64     (the offset table)
/// concatenated chunk payloads (AnsMessage bytes)
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct HierContainer {
    pub model: String,
    pub backend_id: String,
    pub schedule: Schedule,
    pub cfg: BbAnsConfig,
    pub likelihood: Likelihood,
    pub hidden: u32,
    pub weight_seed: u64,
    pub pixels: u32,
    /// Latent widths bottom-up (`dims[0]` next to the data).
    pub dims: Vec<u32>,
    pub chunks: Vec<ChunkEntry>,
}

impl HierContainer {
    /// Encode `images` into `n_chunks` independent hierarchical chains on
    /// the default worker pool.
    pub fn encode_with<B: HierBackend + Sync + ?Sized>(
        codec: &HierCodec<'_, B>,
        images: &[Vec<u8>],
        n_chunks: usize,
    ) -> Result<Self> {
        Self::encode_with_workers(codec, images, n_chunks, super::default_workers())
    }

    /// [`Self::encode_with`] pinning the worker-pool size (`workers` is a
    /// machine knob and never changes the produced bytes).
    pub fn encode_with_workers<B: HierBackend + Sync + ?Sized>(
        codec: &HierCodec<'_, B>,
        images: &[Vec<u8>],
        n_chunks: usize,
        workers: usize,
    ) -> Result<Self> {
        let meta = codec.backend().meta();
        let chunks = codec.encode_dataset_chunked_with_workers(images, n_chunks, workers)?;
        Ok(Self {
            model: meta.name.clone(),
            backend_id: codec.backend().backend_id(),
            schedule: codec.schedule,
            cfg: codec.cfg,
            likelihood: meta.likelihood,
            hidden: meta.hidden as u32,
            weight_seed: codec.backend().weight_seed(),
            pixels: meta.pixels as u32,
            dims: meta.dims.iter().map(|&d| d as u32).collect(),
            chunks,
        })
    }

    /// [`Self::encode_with`] that also returns the per-image, per-layer
    /// rate ledger (ISSUE 9). Byte-identical output to the unledgered path
    /// (pinned by `hier_ledger_pins_bytes_and_exposes_initial_bits_gap`).
    pub fn encode_with_ledger<B: HierBackend + Sync + ?Sized>(
        codec: &HierCodec<'_, B>,
        images: &[Vec<u8>],
        n_chunks: usize,
    ) -> Result<(Self, crate::obs::Ledger)> {
        let meta = codec.backend().meta();
        let (chunks, ledger) =
            codec.encode_dataset_chunked_ledgered(images, n_chunks, super::default_workers())?;
        Ok((
            Self {
                model: meta.name.clone(),
                backend_id: codec.backend().backend_id(),
                schedule: codec.schedule,
                cfg: codec.cfg,
                likelihood: meta.likelihood,
                hidden: meta.hidden as u32,
                weight_seed: codec.backend().weight_seed(),
                pixels: meta.pixels as u32,
                dims: meta.dims.iter().map(|&d| d as u32).collect(),
                chunks,
            },
            ledger,
        ))
    }

    /// Rebuild the exact backend this container was encoded with, from the
    /// self-describing header.
    pub fn build_backend(&self) -> Result<HierVae> {
        if self.weight_seed == 0 {
            bail!(
                "container names artifact-backed hierarchical model '{}' (weight seed 0); \
                 loading trained hierarchical artifacts is not wired yet",
                self.model
            );
        }
        // Bound the total weight allocation before constructing anything:
        // the header fields are attacker-controlled on the serving path,
        // and the per-field caps in `from_bytes` still admit combinations
        // (pixels × hidden) far beyond any real model.
        let heads: u64 = match self.likelihood {
            Likelihood::Bernoulli => 1,
            Likelihood::BetaBinomial => 2,
        };
        let h = self.hidden as u64;
        let mut params: u64 = 0;
        // `heads_out` = number of h×out head matrices: every Gaussian
        // conditional has TWO (mu and logvar); the pixel head has one.
        let mut add = |input: u64, out: u64, heads_out: u64| {
            params = params
                .saturating_add(input.saturating_mul(h))
                .saturating_add(heads_out.saturating_mul(h.saturating_mul(out)));
        };
        for (l, &d) in self.dims.iter().enumerate() {
            let input = if l == 0 { self.pixels as u64 } else { self.dims[l - 1] as u64 };
            add(input, d as u64, 2); // recognition conditional
            if l + 1 < self.dims.len() {
                add(self.dims[l + 1] as u64, d as u64, 2); // generative conditional
            }
        }
        add(self.dims[0] as u64, (self.pixels as u64).saturating_mul(heads), 1);
        const MAX_PARAMS: u64 = 1 << 26; // 256 MiB of f32 weights
        if params > MAX_PARAMS {
            bail!(
                "container model needs {params} weight parameters (limit {MAX_PARAMS}); \
                 refusing to build"
            );
        }
        let meta = HierMeta {
            name: self.model.clone(),
            pixels: self.pixels as usize,
            dims: self.dims.iter().map(|&d| d as usize).collect(),
            hidden: self.hidden as usize,
            likelihood: self.likelihood,
        };
        let backend = HierVae::random(meta, self.weight_seed);
        if backend.backend_id() != self.backend_id {
            bail!(
                "rebuilt backend '{}' does not match container backend '{}'",
                backend.backend_id(),
                self.backend_id
            );
        }
        Ok(backend)
    }

    /// Lock-step decode (single thread, cross-chunk batched net calls —
    /// the coordinator's serving loop for this format).
    pub fn decode_lockstep<B: HierBackend + ?Sized>(
        &self,
        codec: &HierCodec<'_, B>,
    ) -> Result<Vec<Vec<u8>>> {
        self.validate_for(codec)?;
        codec.decode_chunks_lockstep(&self.chunks)
    }

    /// Thread-parallel decode across chunks.
    pub fn decode_with_workers<B: HierBackend + Sync + ?Sized>(
        &self,
        codec: &HierCodec<'_, B>,
        workers: usize,
    ) -> Result<Vec<Vec<u8>>> {
        self.validate_for(codec)?;
        codec.decode_dataset_chunked_with_workers(&self.chunks, workers)
    }

    /// [`Self::decode_with_workers`] on the default pool.
    pub fn decode_with<B: HierBackend + Sync + ?Sized>(
        &self,
        codec: &HierCodec<'_, B>,
    ) -> Result<Vec<Vec<u8>>> {
        self.validate_for(codec)?;
        codec.decode_dataset_chunked(&self.chunks)
    }

    fn validate_for<B: HierBackend + ?Sized>(&self, codec: &HierCodec<'_, B>) -> Result<()> {
        let meta = codec.backend().meta();
        if self.pixels as usize != meta.pixels {
            bail!(
                "container has {}-pixel images, model wants {}",
                self.pixels,
                meta.pixels
            );
        }
        let dims: Vec<u32> = meta.dims.iter().map(|&d| d as u32).collect();
        if self.dims != dims {
            bail!(
                "container layer dims {:?} do not match the model's {:?}",
                self.dims,
                dims
            );
        }
        if self.cfg != codec.cfg {
            bail!("decode codec config does not match the container header");
        }
        if self.schedule != codec.schedule {
            bail!(
                "container was coded with the {} schedule, codec uses {}",
                self.schedule.name(),
                codec.schedule.name()
            );
        }
        Ok(())
    }

    pub fn num_images(&self) -> u32 {
        self.chunks.iter().map(|c| c.num_images).sum()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC_HIER);
        out.push(1u8); // version
        push_str(&mut out, &self.model);
        push_str(&mut out, &self.backend_id);
        out.push(self.schedule.tag());
        out.push(self.cfg.latent_bits as u8);
        out.push(self.cfg.posterior_prec as u8);
        out.push(self.cfg.pixel_prec as u8);
        out.extend_from_slice(&self.cfg.clean_seed.to_le_bytes());
        out.push(self.likelihood.tag());
        out.extend_from_slice(&self.hidden.to_le_bytes());
        out.extend_from_slice(&self.weight_seed.to_le_bytes());
        out.extend_from_slice(&self.pixels.to_le_bytes());
        assert!(
            !self.dims.is_empty() && self.dims.len() <= 255,
            "layer count out of range"
        );
        out.push(self.dims.len() as u8);
        for &d in &self.dims {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out.extend_from_slice(&(self.chunks.len() as u32).to_le_bytes());
        let payloads: Vec<Vec<u8>> = self.chunks.iter().map(|c| c.message.to_bytes()).collect();
        for (c, p) in self.chunks.iter().zip(&payloads) {
            out.extend_from_slice(&c.num_images.to_le_bytes());
            out.extend_from_slice(&(p.len() as u64).to_le_bytes());
        }
        for p in &payloads {
            out.extend_from_slice(p);
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        // `pos <= b.len()` is an invariant, so the bounds check cannot
        // wrap (see ParallelContainer::from_bytes).
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if n > b.len() - *pos {
                bail!("hierarchical container truncated at {} (+{n})", *pos);
            }
            let s = &b[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let magic = take(&mut pos, 4)?;
        if magic != MAGIC_HIER {
            bail!(
                "bad hierarchical-container magic {magic:02x?} (want {MAGIC_HIER:02x?} = \"BBC3\")"
            );
        }
        let version = take(&mut pos, 1)?[0];
        if version != 1 {
            bail!("unsupported BBC3 container version {version} (this build reads version 1)");
        }
        let model = read_str(b, &mut pos).context("model name")?;
        let backend_id = read_str(b, &mut pos).context("backend id")?;
        let schedule = Schedule::from_tag(take(&mut pos, 1)?[0])?;
        let latent_bits = take(&mut pos, 1)?[0] as u32;
        let posterior_prec = take(&mut pos, 1)?[0] as u32;
        let pixel_prec = take(&mut pos, 1)?[0] as u32;
        let clean_seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let likelihood = Likelihood::from_tag(take(&mut pos, 1)?[0])?;
        let hidden = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let weight_seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let pixels = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        // Geometry sanity: these fields size real allocations on the
        // decode side (`build_backend`), so an untrusted container must
        // not be able to demand absurd models. The caps are far above any
        // plausible configuration.
        if pixels == 0 || pixels > 1 << 24 {
            bail!("implausible pixel count {pixels}");
        }
        if hidden == 0 || hidden > 1 << 20 {
            bail!("implausible hidden width {hidden}");
        }
        let n_layers = take(&mut pos, 1)?[0] as usize;
        if n_layers == 0 {
            bail!("hierarchical container declares zero latent layers");
        }
        if n_layers > 16 {
            bail!("implausible layer count {n_layers}");
        }
        let mut dims = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let d = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
            if d == 0 {
                bail!("hierarchical container declares a zero-width latent layer");
            }
            if d > 1 << 16 {
                bail!("implausible latent width {d}");
            }
            dims.push(d);
        }
        let n_chunks = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        if n_chunks > 1 << 20 {
            bail!("implausible chunk count {n_chunks}");
        }
        let table = read_chunk_table("hierarchical", b, &mut pos, n_chunks)?;
        let total: u64 = table.iter().map(|&(n, _)| n as u64).sum();
        check_decode_budget(total, pixels as u64)?;
        let chunks = read_chunk_payloads("hierarchical", b, &mut pos, table)?;
        if pos != b.len() {
            bail!("hierarchical container has {} trailing bytes", b.len() - pos);
        }
        let cfg = BbAnsConfig {
            latent_bits,
            posterior_prec,
            pixel_prec,
            clean_seed,
        };
        cfg.validate()?;
        Ok(Self {
            model,
            backend_id,
            schedule,
            cfg,
            likelihood,
            hidden,
            weight_seed,
            pixels,
            dims,
            chunks,
        })
    }

    /// Total compressed size in bytes (header + payloads).
    pub fn byte_len(&self) -> usize {
        self.to_bytes().len()
    }

    /// Compression rate in bits per pixel-dimension over the whole
    /// container.
    pub fn bits_per_dim(&self) -> f64 {
        (self.byte_len() as f64 * 8.0) / (self.num_images() as f64 * self.pixels as f64)
    }

    /// Rate counting only the ANS payloads (the model geometry is header
    /// overhead that amortizes over the dataset).
    pub fn payload_bits_per_dim(&self) -> f64 {
        let bits: u64 = self.chunks.iter().map(|c| c.message.bit_len()).sum();
        bits as f64 / (self.num_images() as f64 * self.pixels as f64)
    }
}

/// Read an `n_chunks`-entry offset table (`num_images` u32, `payload_len`
/// u64 per chunk) at `*pos` and validate the declared lengths **as a
/// whole** against the payload region that follows: every prefix sum must
/// fit and the chunks must tile the region exactly. The table is
/// attacker-controlled; validating up front means a bad entry names
/// itself (chunk index, declared length, bytes available) instead of
/// surfacing as a generic truncation error mid-parse — and the payload
/// reader below can slice without any further bounds checks.
fn read_chunk_table(
    what: &str,
    b: &[u8],
    pos: &mut usize,
    n_chunks: usize,
) -> Result<Vec<(u32, u64)>> {
    let mut table = Vec::with_capacity(n_chunks);
    for ci in 0..n_chunks {
        if 12 > b.len() - *pos {
            bail!("{what} container truncated in the chunk table at entry {ci}");
        }
        let num_images = u32::from_le_bytes(b[*pos..*pos + 4].try_into().unwrap());
        let len = u64::from_le_bytes(b[*pos + 4..*pos + 12].try_into().unwrap());
        *pos += 12;
        table.push((num_images, len));
    }
    let avail = (b.len() - *pos) as u128;
    let mut declared: u128 = 0; // u128: sums of u64 lengths cannot wrap
    for (ci, &(_, len)) in table.iter().enumerate() {
        declared += len as u128;
        if declared > avail {
            bail!(
                "{what} chunk {ci} declares a {len}-byte payload, but chunks 0..={ci} \
                 would need {declared} of the {avail} payload bytes present"
            );
        }
    }
    if declared != avail {
        bail!("{what} chunk table declares {declared} payload bytes, container has {avail}");
    }
    Ok(table)
}

/// Slice the chunk payloads a validated [`read_chunk_table`] result
/// describes, parsing each as an [`AnsMessage`] and rejecting any chunk
/// whose declared length is not exactly its message's canonical size (a
/// padded or truncated-in-place payload must not parse as valid).
fn read_chunk_payloads(
    what: &str,
    b: &[u8],
    pos: &mut usize,
    table: Vec<(u32, u64)>,
) -> Result<Vec<ChunkEntry>> {
    let mut chunks = Vec::with_capacity(table.len());
    for (ci, (num_images, len)) in table.into_iter().enumerate() {
        let len = len as usize; // fits: the table tiles the buffer tail
        let payload = &b[*pos..*pos + len];
        *pos += len;
        let message =
            AnsMessage::from_bytes(payload).with_context(|| format!("{what} chunk {ci} payload"))?;
        let canonical = 24 + 4 * message.stream.len();
        if len != canonical {
            bail!(
                "{what} chunk {ci} declares {len} payload bytes, \
                 but its ANS message occupies {canonical}"
            );
        }
        chunks.push(ChunkEntry {
            num_images,
            message,
        });
    }
    Ok(chunks)
}

pub(crate) fn push_str(out: &mut Vec<u8>, s: &str) {
    assert!(s.len() <= u8::MAX as usize, "string too long for container");
    out.push(s.len() as u8);
    out.extend_from_slice(s.as_bytes());
}

pub(crate) fn read_str(b: &[u8], pos: &mut usize) -> Result<String> {
    if *pos >= b.len() {
        bail!("truncated string length");
    }
    let n = b[*pos] as usize;
    *pos += 1;
    if *pos + n > b.len() {
        bail!("truncated string body");
    }
    let s = std::str::from_utf8(&b[*pos..*pos + n])
        .context("string utf8")?
        .to_string();
    *pos += n;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Container {
        Container {
            model: "bin".into(),
            backend_id: "native".into(),
            cfg: BbAnsConfig::default(),
            num_images: 17,
            pixels: 784,
            message: AnsMessage {
                head: crate::ans::RANS_L + 12345,
                stream: vec![1, 2, 3, 0xdeadbeef],
                clean_words_used: 13,
            },
        }
    }

    #[test]
    fn roundtrip() {
        let c = sample();
        let bytes = c.to_bytes();
        let c2 = Container::from_bytes(&bytes).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn rejects_corruption() {
        let bytes = sample().to_bytes();
        assert!(Container::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Container::from_bytes(&bad).is_err());
        let mut badver = bytes.clone();
        badver[4] = 9;
        assert!(Container::from_bytes(&badver).is_err());
    }

    #[test]
    fn rate_accounting() {
        let c = sample();
        let payload_bits = c.message.bit_len() as f64;
        assert!((c.payload_bits_per_dim() - payload_bits / (17.0 * 784.0)).abs() < 1e-12);
        assert!(c.bits_per_dim() > c.payload_bits_per_dim());
    }

    fn sample_parallel() -> ParallelContainer {
        ParallelContainer {
            model: "m".into(),
            backend_id: "native".into(),
            cfg: BbAnsConfig {
                latent_bits: 12,
                posterior_prec: 24,
                pixel_prec: 16,
                clean_seed: 7,
            },
            pixels: 4,
            chunks: vec![ChunkEntry {
                num_images: 1,
                message: AnsMessage {
                    head: crate::ans::RANS_L + 3,
                    stream: vec![0xAABB_CCDD],
                    clean_words_used: 2,
                },
            }],
        }
    }

    /// Golden vector: the BBC2 wire format is pinned byte-for-byte. If
    /// this test breaks, the container version must be bumped — decoders
    /// in the wild hold bytes produced by this exact layout.
    #[test]
    fn parallel_container_golden_bytes() {
        #[rustfmt::skip]
        let want: Vec<u8> = vec![
            // magic "BBC2", version
            0x42, 0x42, 0x43, 0x32, 0x02,
            // model "m"
            0x01, 0x6D,
            // backend_id "native"
            0x06, 0x6E, 0x61, 0x74, 0x69, 0x76, 0x65,
            // latent_bits, posterior_prec, pixel_prec
            0x0C, 0x18, 0x10,
            // clean_seed = 7 (LE u64)
            0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // pixels = 4 (LE u32)
            0x04, 0x00, 0x00, 0x00,
            // num_chunks = 1 (LE u32)
            0x01, 0x00, 0x00, 0x00,
            // offset table: num_images = 1, payload_len = 28
            0x01, 0x00, 0x00, 0x00,
            0x1C, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // payload: head = 2^32 + 3 (LE u64)
            0x03, 0x00, 0x00, 0x00, 0x01, 0x00, 0x00, 0x00,
            // clean_words_used = 2 (LE u64)
            0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // stream len = 1 (LE u64)
            0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // stream word 0xAABBCCDD (LE u32)
            0xDD, 0xCC, 0xBB, 0xAA,
        ];
        let got = sample_parallel().to_bytes();
        assert_eq!(got, want, "BBC2 wire format drifted");
        // And the pinned bytes parse back to the same container.
        assert_eq!(ParallelContainer::from_bytes(&want).unwrap(), sample_parallel());
    }

    #[test]
    fn containers_reject_absurd_image_counts() {
        // num_images sizes decode work and output memory, so untrusted
        // headers are budget-checked at parse time (all three formats).
        let mut c1 = sample();
        c1.num_images = u32::MAX;
        assert!(Container::from_bytes(&c1.to_bytes()).is_err());
        let mut c2 = sample_parallel();
        c2.chunks[0].num_images = u32::MAX;
        assert!(ParallelContainer::from_bytes(&c2.to_bytes()).is_err());
    }

    #[test]
    fn parallel_container_rejects_corruption() {
        let bytes = sample_parallel().to_bytes();
        assert!(ParallelContainer::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(ParallelContainer::from_bytes(&bad_magic).is_err());
        let mut bad_version = bytes.clone();
        bad_version[4] = 9;
        assert!(ParallelContainer::from_bytes(&bad_version).is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(ParallelContainer::from_bytes(&trailing).is_err());
    }

    #[test]
    fn bbc1_rejects_trailing_payload_bytes() {
        // The ANS message parser tolerates oversized buffers; the
        // container must not — a BBC1 byte stream is exactly header +
        // canonical message.
        let mut bytes = sample().to_bytes();
        bytes.extend_from_slice(&[0, 0, 0, 0]);
        let err = Container::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }

    #[test]
    fn chunk_table_overrun_names_the_chunk() {
        // sample_parallel has one chunk with a 28-byte payload; its
        // payload_len u64 is the 8 bytes just before the payload.
        let mut bytes = sample_parallel().to_bytes();
        let at = bytes.len() - 36;
        bytes[at..at + 8].copy_from_slice(&1000u64.to_le_bytes());
        let err = ParallelContainer::from_bytes(&bytes).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("chunk 0") && msg.contains("1000"), "{msg}");

        let mut hier = sample_hier().to_bytes();
        let at = hier.len() - 36;
        hier[at..at + 8].copy_from_slice(&1000u64.to_le_bytes());
        let err = HierContainer::from_bytes(&hier).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("chunk 0") && msg.contains("1000"), "{msg}");
    }

    #[test]
    fn chunk_table_undercoverage_is_rejected() {
        // A table whose declared lengths do not tile the payload region
        // exactly (here: one byte short) must fail in the table pre-pass.
        let mut bytes = sample_parallel().to_bytes();
        let at = bytes.len() - 36;
        bytes[at..at + 8].copy_from_slice(&27u64.to_le_bytes());
        let err = ParallelContainer::from_bytes(&bytes).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("27") && msg.contains("28"), "{msg}");
    }

    #[test]
    fn noncanonical_chunk_payload_is_rejected() {
        // Keep the declared 28-byte payload but shrink the message's own
        // stream length to 0: the message parses, yet it no longer
        // occupies the declared bytes — padded payloads must not pass.
        let mut bytes = sample_parallel().to_bytes();
        let at = bytes.len() - 12; // stream-len u64 of the only payload
        bytes[at..at + 8].copy_from_slice(&0u64.to_le_bytes());
        let err = ParallelContainer::from_bytes(&bytes).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("28") && msg.contains("24"), "{msg}");
    }

    #[test]
    fn truncated_chunk_table_names_the_entry() {
        // Cut the container mid-table: the error should point at the
        // table entry, not at a generic offset.
        let bytes = sample_parallel().to_bytes();
        let cut = bytes.len() - 30; // inside the single table entry
        let err = ParallelContainer::from_bytes(&bytes[..cut]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("chunk table"), "{msg}");
    }

    fn sample_hier() -> HierContainer {
        HierContainer {
            model: "h".into(),
            backend_id: "hier-native-s7".into(),
            schedule: Schedule::BitSwap,
            cfg: BbAnsConfig {
                latent_bits: 12,
                posterior_prec: 24,
                pixel_prec: 16,
                clean_seed: 7,
            },
            likelihood: Likelihood::Bernoulli,
            hidden: 8,
            weight_seed: 7,
            pixels: 4,
            dims: vec![3, 2],
            chunks: vec![ChunkEntry {
                num_images: 1,
                message: AnsMessage {
                    head: crate::ans::RANS_L + 3,
                    stream: vec![0xAABB_CCDD],
                    clean_words_used: 2,
                },
            }],
        }
    }

    /// Golden vector: the BBC3 wire format is pinned byte-for-byte. If
    /// this test breaks, the container version must be bumped.
    #[test]
    fn hier_container_golden_bytes() {
        #[rustfmt::skip]
        let want: Vec<u8> = vec![
            // magic "BBC3", version
            0x42, 0x42, 0x43, 0x33, 0x01,
            // model "h"
            0x01, 0x68,
            // backend_id "hier-native-s7"
            0x0E, 0x68, 0x69, 0x65, 0x72, 0x2D, 0x6E, 0x61, 0x74, 0x69, 0x76,
            0x65, 0x2D, 0x73, 0x37,
            // schedule = bitswap
            0x01,
            // latent_bits, posterior_prec, pixel_prec
            0x0C, 0x18, 0x10,
            // clean_seed = 7 (LE u64)
            0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // likelihood = bernoulli
            0x00,
            // hidden = 8 (LE u32)
            0x08, 0x00, 0x00, 0x00,
            // weight_seed = 7 (LE u64)
            0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // pixels = 4 (LE u32)
            0x04, 0x00, 0x00, 0x00,
            // n_layers = 2, dims = [3, 2]
            0x02,
            0x03, 0x00, 0x00, 0x00,
            0x02, 0x00, 0x00, 0x00,
            // num_chunks = 1 (LE u32)
            0x01, 0x00, 0x00, 0x00,
            // offset table: num_images = 1, payload_len = 28
            0x01, 0x00, 0x00, 0x00,
            0x1C, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // payload: head = 2^32 + 3 (LE u64)
            0x03, 0x00, 0x00, 0x00, 0x01, 0x00, 0x00, 0x00,
            // clean_words_used = 2 (LE u64)
            0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // stream len = 1 (LE u64)
            0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // stream word 0xAABBCCDD (LE u32)
            0xDD, 0xCC, 0xBB, 0xAA,
        ];
        let got = sample_hier().to_bytes();
        assert_eq!(got, want, "BBC3 wire format drifted");
        assert_eq!(HierContainer::from_bytes(&want).unwrap(), sample_hier());
    }

    #[test]
    fn hier_container_rejects_corruption() {
        let bytes = sample_hier().to_bytes();
        for cut in [1usize, 10, 30, 45] {
            assert!(
                HierContainer::from_bytes(&bytes[..bytes.len() - cut]).is_err(),
                "cut {cut}"
            );
        }
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(HierContainer::from_bytes(&bad_magic).is_err());
        let mut bad_version = bytes.clone();
        bad_version[4] = 9;
        assert!(HierContainer::from_bytes(&bad_version).is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(HierContainer::from_bytes(&trailing).is_err());
        // Unknown schedule tag fails cleanly: the schedule byte sits right
        // after magic(4) + version(1) + model "h" (2) + backend str (15).
        let mut bad_sched = bytes.clone();
        bad_sched[22] = 9;
        assert!(HierContainer::from_bytes(&bad_sched).is_err());
    }

    /// An untrusted header must not be able to demand an absurd model:
    /// the serving path rebuilds backends from BBC3 headers, so geometry
    /// is capped at parse time and the total weight count at build time.
    #[test]
    fn hier_container_rejects_absurd_geometry() {
        let cases: [fn(&mut HierContainer); 5] = [
            |c| c.hidden = u32::MAX,
            |c| c.pixels = u32::MAX,
            |c| c.dims = vec![u32::MAX, 2],
            |c| c.dims = vec![3; 40],
            |c| c.chunks[0].num_images = u32::MAX,
        ];
        for mutate in cases {
            let mut c = sample_hier();
            mutate(&mut c);
            assert!(HierContainer::from_bytes(&c.to_bytes()).is_err(), "{c:?}");
        }
        // Within the per-field caps but over the total-parameter budget:
        // parse succeeds, build_backend refuses.
        let mut big = sample_hier();
        big.pixels = 1 << 24;
        big.hidden = 1 << 20;
        let parsed = HierContainer::from_bytes(&big.to_bytes()).unwrap();
        assert!(parsed.build_backend().is_err());
    }

    /// Error-path reporting (satellite): magic/version mismatches must name
    /// the bytes actually found, for all three container formats.
    #[test]
    fn container_errors_report_found_bytes() {
        let cases: Vec<(Vec<u8>, &str)> = vec![
            (sample().to_bytes(), "bad container magic"),
            (sample_parallel().to_bytes(), "bad parallel-container magic"),
            (sample_hier().to_bytes(), "bad hierarchical-container magic"),
        ];
        for (bytes, want) in cases {
            let mut bad = bytes.clone();
            bad[0] = 0x58; // 'X'
            let err = match want {
                "bad container magic" => Container::from_bytes(&bad).unwrap_err(),
                "bad parallel-container magic" => {
                    ParallelContainer::from_bytes(&bad).unwrap_err()
                }
                _ => HierContainer::from_bytes(&bad).unwrap_err(),
            };
            let msg = format!("{err:#}");
            assert!(msg.contains(want), "{msg}");
            assert!(msg.contains("58"), "found byte missing from: {msg}");

            let mut badver = bytes.clone();
            badver[4] = 99;
            let err = match want {
                "bad container magic" => Container::from_bytes(&badver).unwrap_err(),
                "bad parallel-container magic" => {
                    ParallelContainer::from_bytes(&badver).unwrap_err()
                }
                _ => HierContainer::from_bytes(&badver).unwrap_err(),
            };
            let msg = format!("{err:#}");
            assert!(msg.contains("version 99"), "found version missing from: {msg}");
        }
    }

    /// Acceptance criterion: BBC3 containers round-trip (encode → decode →
    /// byte-equal images) for L ∈ {2, 3} under both schedules, through the
    /// serialized bytes and a header-rebuilt backend.
    #[test]
    fn hier_container_end_to_end_roundtrip() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xB175);
        for dims in [&[6usize, 4][..], &[6, 4, 3]] {
            let meta = HierMeta {
                name: format!("hier{}", dims.len()),
                pixels: 25,
                dims: dims.to_vec(),
                hidden: 10,
                likelihood: Likelihood::Bernoulli,
            };
            let backend = HierVae::random(meta, 42);
            let images: Vec<Vec<u8>> = (0..11)
                .map(|_| (0..25).map(|_| (rng.f64() < 0.3) as u8).collect())
                .collect();
            for schedule in [Schedule::Naive, Schedule::BitSwap] {
                let codec = HierCodec::new(&backend, BbAnsConfig::default(), schedule).unwrap();
                let hc = HierContainer::encode_with_workers(&codec, &images, 3, 2).unwrap();
                let bytes = hc.to_bytes();
                let parsed = HierContainer::from_bytes(&bytes).unwrap();
                assert_eq!(parsed, hc);
                let rebuilt = parsed.build_backend().unwrap();
                assert_eq!(rebuilt.backend_id(), backend.backend_id());
                let codec2 = HierCodec::new(&rebuilt, parsed.cfg, parsed.schedule).unwrap();
                assert_eq!(parsed.decode_lockstep(&codec2).unwrap(), images);
                assert_eq!(parsed.decode_with_workers(&codec2, 2).unwrap(), images);
            }
        }
    }

    #[test]
    fn hier_container_rejects_mismatched_codec() {
        let meta = HierMeta {
            name: "hier2".into(),
            pixels: 16,
            dims: vec![4, 3],
            hidden: 8,
            likelihood: Likelihood::Bernoulli,
        };
        let backend = HierVae::random(meta, 5);
        let codec =
            HierCodec::new(&backend, BbAnsConfig::default(), Schedule::BitSwap).unwrap();
        let images = vec![vec![0u8; 16]; 3];
        let hc = HierContainer::encode_with_workers(&codec, &images, 1, 1).unwrap();

        // Wrong schedule.
        let naive = HierCodec::new(&backend, BbAnsConfig::default(), Schedule::Naive).unwrap();
        assert!(hc.decode_lockstep(&naive).is_err());
        // Wrong config.
        let cfg = BbAnsConfig {
            latent_bits: 10,
            ..Default::default()
        };
        let other = HierCodec::new(&backend, cfg, Schedule::BitSwap).unwrap();
        assert!(hc.decode_lockstep(&other).is_err());
        // weight_seed 0 refuses to rebuild.
        let mut artifact = hc.clone();
        artifact.weight_seed = 0;
        assert!(artifact.build_backend().is_err());
    }

    /// ISSUE 9 golden test: attaching the rate ledger changes ZERO emitted
    /// bytes — the BBC1 message and the BBC2 chunk payloads from ledgered
    /// encodes are byte-identical to plain encodes — and every recorded
    /// entry satisfies the ELBO decomposition identity
    /// `net = data + Σ_l (pop_l + push_l)`.
    #[test]
    fn ledgered_encode_is_byte_identical_and_elbo_consistent() {
        use crate::model::vae::NativeVae;
        use crate::model::ModelMeta;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x1ED6E4);
        let images: Vec<Vec<u8>> = (0..9)
            .map(|_| (0..25).map(|_| (rng.f64() < 0.3) as u8).collect())
            .collect();
        let meta = ModelMeta {
            name: "led".into(),
            pixels: 25,
            latent_dim: 5,
            hidden: 10,
            likelihood: Likelihood::Bernoulli,
            test_elbo_bpd: f64::NAN,
        };
        let backend = NativeVae::random(meta, 3);
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();

        // BBC1: one chained stream.
        let (plain, _) = codec.encode_dataset(&images).unwrap();
        let (ledgered, _, ledger) = codec.encode_dataset_ledgered(&images).unwrap();
        assert_eq!(
            plain.to_message(),
            ledgered.to_message(),
            "ledger must not move BBC1 bytes"
        );
        assert_eq!(ledger.entries.len(), images.len());
        for e in &ledger.entries {
            assert!(e.decomposition_residual() < 1e-6, "{e:?}");
            assert!(e.data_bits > 0.0 && e.latent_pop_bits[0] < 0.0);
            assert!(e.latent_push_bits[0] > 0.0);
        }
        let s = ledger.summary(25);
        assert!(s.initial_bits > 0.0, "a fresh chain must borrow clean bits");
        assert!(s.max_residual < 1e-6);

        // BBC2: chunk-parallel chains.
        let plain_chunks = codec
            .encode_dataset_chunked_with_workers(&images, 3, 2)
            .unwrap();
        let (led_chunks, chunk_ledger) = codec
            .encode_dataset_chunked_ledgered(&images, 3, 2)
            .unwrap();
        assert_eq!(plain_chunks, led_chunks, "ledger must not move BBC2 bytes");
        assert_eq!(chunk_ledger.entries.len(), images.len());
        assert!(chunk_ledger.summary(25).max_residual < 1e-6);
    }

    /// ISSUE 9 golden test, hierarchical: ledgered BBC3 chunk encodes are
    /// byte-identical under BOTH schedules, entries decompose per layer,
    /// and the ledger directly exposes the naive-vs-Bit-Swap initial-bits
    /// gap the subsystem exists to measure.
    #[test]
    fn hier_ledger_pins_bytes_and_exposes_initial_bits_gap() {
        use crate::util::rng::Rng;
        let meta = HierMeta {
            name: "hled".into(),
            pixels: 64,
            dims: vec![16, 12],
            hidden: 10,
            likelihood: Likelihood::Bernoulli,
        };
        let backend = HierVae::random(meta, 11);
        let mut rng = Rng::new(0x1ED6E5);
        let images: Vec<Vec<u8>> = (0..6)
            .map(|_| (0..64).map(|_| (rng.f64() < 0.3) as u8).collect())
            .collect();
        let mut initials = Vec::new();
        for schedule in [Schedule::Naive, Schedule::BitSwap] {
            let codec = HierCodec::new(&backend, BbAnsConfig::default(), schedule).unwrap();
            let plain = codec
                .encode_dataset_chunked_with_workers(&images, 2, 2)
                .unwrap();
            let (ledgered, ledger) = codec
                .encode_dataset_chunked_ledgered(&images, 2, 2)
                .unwrap();
            assert_eq!(plain, ledgered, "{schedule:?}: ledger must not move BBC3 bytes");
            assert_eq!(ledger.entries.len(), images.len());
            for e in &ledger.entries {
                assert_eq!(e.latent_pop_bits.len(), 2, "{schedule:?}");
                assert!(e.decomposition_residual() < 1e-6, "{schedule:?} {e:?}");
            }
            initials.push(ledger.summary(64).initial_bits);
        }
        assert!(
            initials[1] < initials[0],
            "bitswap initial bits {} must undercut naive {}",
            initials[1],
            initials[0]
        );
    }

    #[test]
    fn chunk_seeds_are_distinct_and_stable() {
        // Chains must draw independent clean bits; seeds are pure
        // functions of (container seed, chunk index).
        let mut seen = std::collections::BTreeSet::new();
        for chunk in 0..64 {
            let s = chunk_seed(0xBBA4_55EE, chunk);
            assert_eq!(s, chunk_seed(0xBBA4_55EE, chunk), "must be deterministic");
            assert!(seen.insert(s), "chunk {chunk} repeats a seed");
        }
    }
}
