//! The bits-back rate ledger: passive per-image bit accounting.
//!
//! BB-ANS's claim (paper §3) is that the chained rate tracks the model's
//! −ELBO; the naive-vs-Bit-Swap comparison (Kingma et al., arXiv
//! 1905.06845) is entirely about **initial bits** — the clean words a
//! fresh chain must draw before bits-back has anything to pay back. The
//! ledger makes both directly observable instead of inferred: for every
//! image it records
//!
//! * `initial_bits` — 32 × the clean words newly drawn from the seed
//!   supply while coding this image (the chain-startup cost; ≈ Σ_l H(q_l)
//!   for the naive schedule vs ≈ H(q_0) for Bit-Swap);
//! * `latent_pop_bits[l]` — effective bits *consumed* popping layer `l`'s
//!   latent from its posterior (negative; `≈ −H(q_l)` terms);
//! * `latent_push_bits[l]` — bits *added* pushing layer `l` under its
//!   prior / top-down conditional (`≈ cross-entropy` terms);
//! * `data_bits` — bits added coding the pixels under the likelihood
//!   (`≈ −log p(x|z)`);
//! * `net_bits` — total effective message growth.
//!
//! The ELBO identity the golden tests pin:
//! `net = data + Σ_l (pop_l + push_l)` (within f64 rounding), i.e. the
//! measured rate *is* the discretized −ELBO estimate, decomposed.
//!
//! The ledger is a **pure observer**: it reads the same
//! `frac_bit_len − 32·clean_words_used` effective-length measure the
//! codecs already compute and never touches the coder, so a ledgered
//! encode emits byte-identical containers (pinned by golden tests in
//! `bbans::container`).

use crate::util::json::Json;

/// Per-image bit accounting (all values in bits; see module docs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LedgerEntry {
    /// 32 × clean words newly drawn while coding this image.
    pub initial_bits: f64,
    /// Effective bits consumed popping each layer's posterior (≤ 0),
    /// bottom (layer 0) first.
    pub latent_pop_bits: Vec<f64>,
    /// Bits added pushing each layer under its prior/conditional (≥ 0),
    /// bottom (layer 0) first.
    pub latent_push_bits: Vec<f64>,
    /// Bits added coding the pixels under the likelihood.
    pub data_bits: f64,
    /// Total effective message growth (−ELBO estimate for this image).
    pub net_bits: f64,
}

impl LedgerEntry {
    /// Fresh entry with `layers` zeroed per-layer slots.
    pub fn new(layers: usize) -> Self {
        Self {
            latent_pop_bits: vec![0.0; layers],
            latent_push_bits: vec![0.0; layers],
            ..Self::default()
        }
    }

    /// Net latent cost of layer `l`: pop (negative) + push.
    pub fn latent_net_bits(&self, l: usize) -> f64 {
        self.latent_pop_bits[l] + self.latent_push_bits[l]
    }

    /// |net − (data + Σ latent)| — the ELBO-decomposition residual.
    pub fn decomposition_residual(&self) -> f64 {
        let latent: f64 = (0..self.latent_pop_bits.len())
            .map(|l| self.latent_net_bits(l))
            .sum();
        (self.net_bits - (self.data_bits + latent)).abs()
    }
}

/// Accounting sink threaded through `CodecScratch`: `None` (the default)
/// costs one pointer-sized check per image and records nothing.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    pub entries: Vec<LedgerEntry>,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, e: LedgerEntry) {
        self.entries.push(e);
    }

    /// Append another ledger's entries (chunked encodes merge per-chunk
    /// ledgers in chunk order).
    pub fn merge(&mut self, other: Ledger) {
        self.entries.extend(other.entries);
    }

    /// Aggregate totals across all entries. `pixels` is the per-image
    /// dimension count the bits/dim figures normalize by.
    pub fn summary(&self, pixels: usize) -> LedgerSummary {
        let layers = self
            .entries
            .iter()
            .map(|e| e.latent_pop_bits.len())
            .max()
            .unwrap_or(0);
        let mut s = LedgerSummary {
            images: self.entries.len(),
            pixels,
            layers,
            latent_pop_bits: vec![0.0; layers],
            latent_push_bits: vec![0.0; layers],
            ..LedgerSummary::default()
        };
        for e in &self.entries {
            s.initial_bits += e.initial_bits;
            s.data_bits += e.data_bits;
            s.net_bits += e.net_bits;
            s.max_residual = s.max_residual.max(e.decomposition_residual());
            for l in 0..e.latent_pop_bits.len() {
                s.latent_pop_bits[l] += e.latent_pop_bits[l];
                s.latent_push_bits[l] += e.latent_push_bits[l];
            }
        }
        s
    }
}

/// Dataset-level ledger totals, with bits/dim views (the figures
/// `bbans compress -v`, `benches/hierarchy.rs`, and BENCH JSON report).
#[derive(Debug, Clone, Default)]
pub struct LedgerSummary {
    pub images: usize,
    pub pixels: usize,
    pub layers: usize,
    pub initial_bits: f64,
    pub data_bits: f64,
    pub net_bits: f64,
    /// Per-layer totals, bottom (layer 0) first.
    pub latent_pop_bits: Vec<f64>,
    pub latent_push_bits: Vec<f64>,
    /// Worst per-image |net − (data + Σ latent)| across the dataset —
    /// the ELBO-decomposition consistency bound.
    pub max_residual: f64,
}

impl LedgerSummary {
    fn dims(&self) -> f64 {
        (self.images * self.pixels).max(1) as f64
    }

    /// Measured −ELBO estimate in bits/dim (what the chained rate
    /// converges to; excludes initial bits by construction).
    pub fn net_bpd(&self) -> f64 {
        self.net_bits / self.dims()
    }

    /// Chain-startup cost amortized over the dataset, bits/dim.
    pub fn initial_bpd(&self) -> f64 {
        self.initial_bits / self.dims()
    }

    /// `−log p(x|z)` term, bits/dim.
    pub fn data_bpd(&self) -> f64 {
        self.data_bits / self.dims()
    }

    /// Layer `l`'s net latent cost (KL-term analogue), bits/dim.
    pub fn latent_net_bpd(&self, l: usize) -> f64 {
        (self.latent_pop_bits[l] + self.latent_push_bits[l]) / self.dims()
    }

    pub fn to_json(&self) -> Json {
        let per_layer: Vec<Json> = (0..self.layers)
            .map(|l| {
                Json::obj(vec![
                    ("layer", Json::Num(l as f64)),
                    ("pop_bits", Json::Num(self.latent_pop_bits[l])),
                    ("push_bits", Json::Num(self.latent_push_bits[l])),
                    ("net_bpd", Json::Num(self.latent_net_bpd(l))),
                ])
            })
            .collect();
        Json::obj(vec![
            ("images", Json::Num(self.images as f64)),
            ("pixels", Json::Num(self.pixels as f64)),
            ("layers", Json::Num(self.layers as f64)),
            ("net_bpd", Json::Num(self.net_bpd())),
            ("data_bpd", Json::Num(self.data_bpd())),
            ("initial_bits", Json::Num(self.initial_bits)),
            ("initial_bpd", Json::Num(self.initial_bpd())),
            ("max_residual_bits", Json::Num(self.max_residual)),
            ("latents", Json::Arr(per_layer)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(layers: usize, seed: f64) -> LedgerEntry {
        let mut e = LedgerEntry::new(layers);
        for l in 0..layers {
            e.latent_pop_bits[l] = -(10.0 + seed + l as f64);
            e.latent_push_bits[l] = 14.0 + seed + l as f64;
        }
        e.data_bits = 100.0 + seed;
        e.net_bits = e.data_bits
            + (0..layers).map(|l| e.latent_net_bits(l)).sum::<f64>();
        e.initial_bits = 64.0;
        e
    }

    #[test]
    fn summary_totals_and_bpd() {
        let mut led = Ledger::new();
        led.push(entry(2, 0.0));
        led.push(entry(2, 1.0));
        let s = led.summary(50);
        assert_eq!(s.images, 2);
        assert_eq!(s.layers, 2);
        assert!((s.data_bits - 201.0).abs() < 1e-9);
        assert!((s.initial_bits - 128.0).abs() < 1e-9);
        // Identity held exactly by construction → residual ~ 0.
        assert!(s.max_residual < 1e-9);
        // bits/dim normalizes by images × pixels.
        assert!((s.net_bpd() - s.net_bits / 100.0).abs() < 1e-12);
        // Per-layer KL analogue: pop + push per layer.
        assert!((s.latent_net_bpd(0) - (4.0 + 4.0) / 100.0).abs() < 1e-9);
    }

    #[test]
    fn residual_detects_broken_decomposition() {
        let mut e = entry(1, 0.0);
        e.net_bits += 3.0;
        assert!((e.decomposition_residual() - 3.0).abs() < 1e-9);
        let mut led = Ledger::new();
        led.push(e);
        assert!(led.summary(10).max_residual > 2.9);
    }

    #[test]
    fn merge_concatenates_in_order() {
        let mut a = Ledger::new();
        a.push(entry(1, 0.0));
        let mut b = Ledger::new();
        b.push(entry(1, 5.0));
        a.merge(b);
        assert_eq!(a.entries.len(), 2);
        assert!((a.entries[1].data_bits - 105.0).abs() < 1e-9);
    }

    #[test]
    fn summary_json_parses_back() {
        let mut led = Ledger::new();
        led.push(entry(3, 0.0));
        let j = led.summary(784).to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("images").unwrap().as_u64(), Some(1));
        assert_eq!(parsed.get("layers").unwrap().as_u64(), Some(3));
        assert_eq!(
            parsed.get("latents").unwrap().as_arr().unwrap().len(),
            3
        );
    }
}
