//! Observability layer (ISSUE 9): request tracing, the bits-back rate
//! ledger, and Prometheus-text exposition helpers.
//!
//! Three pillars, all from scratch (vendored-everything policy — zero
//! external deps):
//!
//! * [`trace`] — a lock-light span recorder: call sites append to
//!   per-thread buffers that drain into one bounded global ring, so the
//!   serving hot path never takes the ring lock per span. When tracing
//!   is disabled the entire cost is a single relaxed atomic load.
//! * [`ledger`] — the bits-back rate ledger: a passive per-image /
//!   per-layer bit-accounting sink threaded through
//!   [`crate::bbans::CodecScratch`]. It only *observes* the effective
//!   message length the codecs already compute — it never touches the
//!   coder, so ledgered encodes are byte-identical to plain ones
//!   (pinned by golden tests in `bbans::container`).
//! * [`prom`] — Prometheus text-format (version 0.0.4) line writers
//!   used by `coordinator::metrics` to render the existing counters and
//!   log₂ histograms as `name{labels} value` exposition.
//!
//! Layering: `obs` depends on nothing above `std` — the coordinator and
//! the codecs depend on it, never the other way around.

pub mod ledger;
pub mod prom;
pub mod trace;

pub use ledger::{Ledger, LedgerEntry, LedgerSummary};
pub use prom::PromWriter;
pub use trace::{tracer, SpanRecord, Tracer};
