//! Request tracing: a lock-light span recorder.
//!
//! Call sites record [`SpanRecord`]s tagged with a request's trace id.
//! Recording appends to a **per-thread buffer** (no lock); buffers drain
//! into one **bounded global ring** when they reach
//! [`FLUSH_SPANS`] entries or when a call site flushes explicitly (the
//! server flushes after the reply span, the worker after each round).
//! The ring overwrites its oldest spans when full — tracing must never
//! grow without bound or stall the serving path — and counts what it
//! overwrote, so a scrape can tell "quiet" from "wrapped".
//!
//! **Disabled-path contract (ISSUE 9):** when tracing is off, the whole
//! cost of a `record` call is one relaxed atomic load. Everything else —
//! timestamp math, the thread-local push, the ring lock — is behind
//! that check.
//!
//! Time is recorded as microseconds since the tracer's epoch (its
//! construction instant), so spans from different threads share one
//! clock and a trace's spans can be laid out on a common axis.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Spans buffered per thread before one ring-lock drain.
pub const FLUSH_SPANS: usize = 32;

/// Default capacity (in spans) of the global ring — enough for a few
/// hundred recent requests at ~6 spans each, small enough to snapshot
/// cheaply over the wire.
pub const DEFAULT_RING_SPANS: usize = 4096;

/// One recorded span: a named interval inside one request's lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    /// The request's trace id (0 is reserved: never recorded).
    pub trace: u64,
    /// Span name — a static label like `"admission"`, `"nn"`, `"reply"`.
    pub name: &'static str,
    /// Start, µs since the tracer epoch.
    pub start_us: u64,
    /// Duration, µs (0 for instantaneous marks).
    pub dur_us: u64,
    /// Payload size hint: images for coding spans, bytes for the reply.
    pub items: u64,
    /// Global drain order — stable sort key when wall-clocks tie.
    pub seq: u64,
}

impl SpanRecord {
    fn to_json(self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.to_string())),
            ("start_us", Json::Num(self.start_us as f64)),
            ("dur_us", Json::Num(self.dur_us as f64)),
            ("items", Json::Num(self.items as f64)),
            ("seq", Json::Num(self.seq as f64)),
        ])
    }
}

/// Bounded overwrite-oldest span storage (the "global ring").
struct Ring {
    buf: Vec<SpanRecord>,
    /// Index of the oldest element once the ring has wrapped.
    head: usize,
    /// Spans overwritten because the ring was full.
    dropped: u64,
}

impl Ring {
    fn push(&mut self, cap: usize, s: SpanRecord) {
        if self.buf.len() < cap {
            self.buf.push(s);
        } else {
            self.buf[self.head] = s;
            self.head = (self.head + 1) % cap;
            self.dropped += 1;
        }
    }

    /// Oldest → newest.
    fn in_order(&self) -> Vec<SpanRecord> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }
}

/// The span recorder. One global instance serves the process (see
/// [`tracer`]); tests construct private instances with a small ring.
pub struct Tracer {
    enabled: AtomicBool,
    epoch: Instant,
    next_id: AtomicU64,
    seq: AtomicU64,
    recorded: AtomicU64,
    cap: usize,
    ring: Mutex<Ring>,
}

thread_local! {
    /// Per-thread span buffer for the **global** tracer (private tracer
    /// instances push straight to their ring — only the global one is on
    /// a hot path worth buffering).
    static LOCAL: RefCell<Vec<SpanRecord>> = const { RefCell::new(Vec::new()) };
}

static GLOBAL: OnceLock<Tracer> = OnceLock::new();

/// The process-wide tracer (created disabled on first touch; the server
/// enables it at startup).
pub fn tracer() -> &'static Tracer {
    GLOBAL.get_or_init(|| Tracer::new(DEFAULT_RING_SPANS))
}

impl Tracer {
    pub fn new(ring_spans: usize) -> Self {
        Self {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            next_id: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
            cap: ring_spans.max(1),
            ring: Mutex::new(Ring {
                buf: Vec::new(),
                head: 0,
                dropped: 0,
            }),
        }
    }

    /// The single relaxed load every disabled-path `record` costs.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Fresh nonzero trace id for a request that arrived without one.
    pub fn next_trace_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// µs since the tracer epoch (spans share this clock).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record one span for `trace`. No-op when disabled or `trace == 0`
    /// (requests that opted out). `start` instants predating the epoch
    /// saturate to 0 — admission timestamps can precede a late enable.
    #[inline]
    pub fn record(&self, trace: u64, name: &'static str, start: Instant, dur: Duration, items: u64) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        if trace == 0 {
            return;
        }
        let rec = SpanRecord {
            trace,
            name,
            start_us: start.saturating_duration_since(self.epoch).as_micros() as u64,
            dur_us: dur.as_micros() as u64,
            items,
            seq: self.seq.fetch_add(1, Ordering::Relaxed),
        };
        self.recorded.fetch_add(1, Ordering::Relaxed);
        if self.is_global() {
            let full = LOCAL.with(|b| {
                let mut b = b.borrow_mut();
                b.push(rec);
                b.len() >= FLUSH_SPANS
            });
            if full {
                self.flush();
            }
        } else {
            self.ring.lock().expect("trace ring poisoned").push(self.cap, rec);
        }
    }

    /// Drain this thread's buffer into the ring (one lock for the whole
    /// batch). Terminal call sites — the reply span, the end of a worker
    /// round — flush so a trace is scrape-visible as soon as it ends.
    pub fn flush(&self) {
        if !self.is_global() {
            return; // private tracers never buffer
        }
        let batch = LOCAL.with(|b| std::mem::take(&mut *b.borrow_mut()));
        if batch.is_empty() {
            return;
        }
        let mut ring = self.ring.lock().expect("trace ring poisoned");
        for s in batch {
            ring.push(self.cap, s);
        }
    }

    /// Total spans ever recorded (including later-overwritten ones).
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Spans overwritten by ring wraparound.
    pub fn dropped(&self) -> u64 {
        self.ring.lock().expect("trace ring poisoned").dropped
    }

    /// Ring capacity in spans.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Ring contents, oldest → newest (flushes this thread first).
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.flush();
        self.ring.lock().expect("trace ring poisoned").in_order()
    }

    /// Snapshot the most recent `max_traces` traces as JSON:
    /// `{"capacity", "recorded", "dropped", "traces": [{"trace",
    /// "spans": [...]}, ...]}` with traces ordered most-recent-first and
    /// each trace's spans in drain (`seq`) order.
    pub fn snapshot_json(&self, max_traces: usize) -> Json {
        let spans = self.spans();
        // Group by trace id, preserving first-seen (oldest-first) order.
        let mut order: Vec<u64> = Vec::new();
        let mut groups: std::collections::HashMap<u64, Vec<SpanRecord>> =
            std::collections::HashMap::new();
        for s in spans {
            groups
                .entry(s.trace)
                .or_insert_with(|| {
                    order.push(s.trace);
                    Vec::new()
                })
                .push(s);
        }
        // Most recent trace = largest max-seq; emit newest first.
        order.sort_by_key(|t| {
            std::cmp::Reverse(groups[t].iter().map(|s| s.seq).max().unwrap_or(0))
        });
        let traces: Vec<Json> = order
            .into_iter()
            .take(max_traces)
            .map(|t| {
                let mut g = groups.remove(&t).expect("grouped above");
                g.sort_by_key(|s| s.seq);
                Json::obj(vec![
                    ("trace", Json::Num(t as f64)),
                    ("spans", Json::Arr(g.into_iter().map(SpanRecord::to_json).collect())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("capacity", Json::Num(self.cap as f64)),
            ("recorded", Json::Num(self.recorded() as f64)),
            ("dropped", Json::Num(self.dropped() as f64)),
            ("traces", Json::Arr(traces)),
        ])
    }

    fn is_global(&self) -> bool {
        GLOBAL.get().is_some_and(|g| std::ptr::eq(g, self))
    }
}

/// Serializes tests that toggle the GLOBAL tracer's enable bit, across
/// modules — without it, one test's `set_enabled(false)` teardown races
/// another's recording window.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(t: &Tracer, trace: u64, name: &'static str) {
        t.record(trace, name, Instant::now(), Duration::from_micros(5), 1);
    }

    #[test]
    fn disabled_records_nothing() {
        let t = Tracer::new(16);
        span(&t, 1, "a");
        assert_eq!(t.recorded(), 0);
        assert!(t.spans().is_empty());
    }

    #[test]
    fn trace_zero_never_recorded() {
        let t = Tracer::new(16);
        t.set_enabled(true);
        span(&t, 0, "a");
        assert_eq!(t.recorded(), 0);
    }

    #[test]
    fn ring_overflow_wraps_and_counts_dropped() {
        let t = Tracer::new(8);
        t.set_enabled(true);
        for i in 1..=20u64 {
            span(&t, i, "s");
        }
        let spans = t.spans();
        assert_eq!(spans.len(), 8, "ring is bounded");
        assert_eq!(t.dropped(), 12, "overwritten spans are counted");
        assert_eq!(t.recorded(), 20);
        // Oldest→newest after wraparound: traces 13..=20 survive, in order.
        let traces: Vec<u64> = spans.iter().map(|s| s.trace).collect();
        assert_eq!(traces, (13..=20).collect::<Vec<u64>>());
    }

    #[test]
    fn snapshot_groups_by_trace_newest_first() {
        let t = Tracer::new(64);
        t.set_enabled(true);
        span(&t, 7, "admission");
        span(&t, 9, "admission");
        span(&t, 7, "reply");
        let j = t.snapshot_json(10);
        let traces = j.get("traces").unwrap().as_arr().unwrap();
        assert_eq!(traces.len(), 2);
        // Trace 7's last span is newest, so trace 7 leads.
        assert_eq!(traces[0].get("trace").unwrap().as_u64(), Some(7));
        let spans7 = traces[0].get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans7.len(), 2);
        assert_eq!(spans7[0].get("name").unwrap().as_str(), Some("admission"));
        assert_eq!(spans7[1].get("name").unwrap().as_str(), Some("reply"));
        // max_traces truncates to the most recent traces only.
        let j1 = t.snapshot_json(1);
        let only = j1.get("traces").unwrap().as_arr().unwrap();
        assert_eq!(only.len(), 1);
        assert_eq!(only[0].get("trace").unwrap().as_u64(), Some(7));
    }

    #[test]
    fn trace_ids_are_nonzero_and_distinct() {
        let t = Tracer::new(4);
        let a = t.next_trace_id();
        let b = t.next_trace_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }

    /// The global tracer buffers per thread and drains on flush — spans
    /// recorded under the threshold are invisible until flushed. Uses a
    /// unique trace id so concurrent tests sharing the global are inert.
    #[test]
    fn global_tracer_buffers_then_flushes() {
        let _guard = test_guard();
        let t = tracer();
        let was = t.enabled();
        t.set_enabled(true);
        let id = t.next_trace_id() + 0xC0FFEE_0000;
        for _ in 0..3 {
            span(t, id, "buffered");
        }
        let j = t.snapshot_json(usize::MAX); // spans() flushes this thread
        let traces = j.get("traces").unwrap().as_arr().unwrap();
        let mine: Vec<&Json> = traces
            .iter()
            .filter(|tr| tr.get("trace").and_then(Json::as_u64) == Some(id))
            .collect();
        assert_eq!(mine.len(), 1);
        assert_eq!(mine[0].get("spans").unwrap().as_arr().unwrap().len(), 3);
        t.set_enabled(was);
    }
}
