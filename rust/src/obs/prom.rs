//! Prometheus text-format (exposition format 0.0.4) line writers.
//!
//! Zero-dependency rendering of the shapes `coordinator::metrics` holds:
//! monotone counters, gauges, and the log₂-bucketed latency histograms
//! (emitted as cumulative `_bucket{le="..."}` series plus `_sum` /
//! `_count`, the standard Prometheus histogram encoding). Every sample
//! line is `name{labels} value` — the exact shape CI's exposition lint
//! checks — preceded by `# HELP` / `# TYPE` comment lines.

/// Incremental builder for one exposition payload.
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
}

/// Escape a label value per the exposition format: backslash, double
/// quote, and newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn render_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

impl PromWriter {
    pub fn new() -> Self {
        Self::default()
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n"));
        self.out.push_str(&format!("# TYPE {name} {kind}\n"));
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        // Integral values print without a fractional part (9 not 9.0) —
        // both are valid exposition, this is just the conventional form.
        if value.fract() == 0.0 && value.abs() < 1e15 {
            self.out
                .push_str(&format!("{name}{} {}\n", render_labels(labels), value as i64));
        } else {
            self.out
                .push_str(&format!("{name}{} {value}\n", render_labels(labels)));
        }
    }

    /// One monotone counter.
    pub fn counter(&mut self, name: &str, help: &str, value: u64) {
        self.header(name, help, "counter");
        self.sample(name, &[], value as f64);
    }

    /// One gauge.
    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, help, "gauge");
        self.sample(name, &[], value);
    }

    /// A labeled constant-1 gauge (the `build_info` idiom).
    pub fn info(&mut self, name: &str, help: &str, labels: &[(&str, &str)]) {
        self.header(name, help, "gauge");
        self.sample(name, labels, 1.0);
    }

    /// One log₂-bucketed histogram: `counts[i]` observations fell in
    /// `[2^i, 2^(i+1))` (last bucket unbounded above), `sum` is the total
    /// of observed values, `n` the observation count. Rendered as the
    /// standard cumulative `_bucket{le}` series — bucket `i`'s upper
    /// bound is `2^(i+1)` — with the final bucket folded into `+Inf`.
    pub fn log2_histogram(&mut self, name: &str, help: &str, counts: &[u64], sum: u64, n: u64) {
        self.header(name, help, "histogram");
        let mut acc = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if i + 1 == counts.len() {
                break; // the last bucket has no finite upper bound
            }
            acc += c;
            let le = (1u64 << (i + 1)).to_string();
            self.sample(&format!("{name}_bucket"), &[("le", &le)], acc as f64);
        }
        self.sample(&format!("{name}_bucket"), &[("le", "+Inf")], n as f64);
        self.sample(&format!("{name}_sum"), &[], sum as f64);
        self.sample(&format!("{name}_count"), &[], n as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every non-comment line must be `name{labels} value` — the same
    /// shape CI's regex lint enforces against a live scrape.
    fn assert_exposition_shape(text: &str) {
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let (name_part, value) = line.rsplit_once(' ').expect("line has a value");
            let name = name_part.split('{').next().unwrap();
            assert!(
                !name.is_empty()
                    && name
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name in line: {line}"
            );
            assert!(
                value.parse::<f64>().is_ok() || value == "+Inf" || value == "NaN",
                "bad value in line: {line}"
            );
            if let Some(rest) = name_part.strip_prefix(name) {
                if !rest.is_empty() {
                    assert!(rest.starts_with('{') && rest.ends_with('}'), "{line}");
                }
            }
        }
    }

    #[test]
    fn counter_and_gauge_lines() {
        let mut w = PromWriter::new();
        w.counter("bbans_requests_total", "Requests admitted.", 42);
        w.gauge("bbans_queue_depth", "Jobs queued.", 3.0);
        w.info(
            "bbans_build_info",
            "Build identity.",
            &[("version", "0.1.0"), ("kernel", "avx2")],
        );
        let text = w.finish();
        assert!(text.contains("# TYPE bbans_requests_total counter\n"));
        assert!(text.contains("bbans_requests_total 42\n"));
        assert!(text.contains("bbans_queue_depth 3\n"));
        assert!(text.contains("bbans_build_info{version=\"0.1.0\",kernel=\"avx2\"} 1\n"));
        assert_exposition_shape(&text);
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_capped_by_inf() {
        let mut counts = [0u64; 32];
        counts[0] = 2; // [1, 2) µs
        counts[3] = 5; // [8, 16) µs
        counts[31] = 1; // unbounded top bucket → only in +Inf
        let mut w = PromWriter::new();
        w.log2_histogram("lat_us", "Latency.", &counts, 123, 8);
        let text = w.finish();
        assert!(text.contains("lat_us_bucket{le=\"2\"} 2\n"));
        assert!(text.contains("lat_us_bucket{le=\"8\"} 2\n"));
        assert!(text.contains("lat_us_bucket{le=\"16\"} 7\n"));
        assert!(text.contains("lat_us_bucket{le=\"+Inf\"} 8\n"));
        assert!(text.contains("lat_us_sum 123\n"));
        assert!(text.contains("lat_us_count 8\n"));
        // Cumulative counts never decrease along the le series.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{le=\"") && !l.contains("+Inf")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "{line}");
            last = v;
        }
        assert_exposition_shape(&text);
    }

    #[test]
    fn label_values_are_escaped() {
        let mut w = PromWriter::new();
        w.info("x_info", "Escaping.", &[("v", "a\"b\\c\nd")]);
        let text = w.finish();
        assert!(text.contains("x_info{v=\"a\\\"b\\\\c\\nd\"} 1\n"));
    }
}
