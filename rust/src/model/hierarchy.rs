//! Hierarchical (multi-layer) VAE backends — the model half of the
//! Bit-Swap subsystem (Kingma et al. 2019; HiLLoC, Townsend et al. 2020).
//!
//! The model class is a **Markov top-down hierarchy** over latent layers
//! `z_0 … z_{L-1}` (`z_0` closest to the data, `z_{L-1}` the top):
//!
//! ```text
//! generative:   p(z_{L-1}) · p(z_{L-2} | z_{L-1}) ··· p(z_0 | z_1) · p(x | z_0)
//! recognition:  q(z_0 | x) · q(z_1 | z_0) ··· q(z_{L-1} | z_{L-2})
//! ```
//!
//! Every conditional between latent layers is a diagonal Gaussian whose
//! `(mu, sigma)` come from a small MLP on the *discretized* layer below
//! (recognition) or above (generative); the top prior is the standard
//! normal, which the max-entropy bucketing turns into an exactly uniform
//! discrete prior. The Markov structure is what makes the interleaved
//! Bit-Swap coding schedule valid (see [`crate::bbans::hierarchy`]): at
//! every step of the chain, the next conditional depends only on the one
//! vector just coded.
//!
//! [`HierVae`] is the pure-Rust implementation, built entirely on the
//! packed-GEMM kernels from the tensor layer, so the determinism contract
//! carries over: every `(mu, sigma)` row is independent of batch grouping
//! bit-for-bit, which is what lets the coding loops batch the data-side
//! recognition calls and the coordinator batch across streams without
//! changing a single coded bit.

use anyhow::{bail, Result};

use super::tensor::{dense_packed, Epilogue, Matrix};
use super::vae::{AB_EPS, LOGVAR_MAX, LOGVAR_MIN};
use super::{Likelihood, PixelParams, PosteriorBatch};
use crate::util::rng::Rng;

/// Static description of one hierarchical model.
#[derive(Debug, Clone)]
pub struct HierMeta {
    pub name: String,
    pub pixels: usize,
    /// Latent widths bottom-up: `dims[0]` is `z_0` (next to the data),
    /// `dims[L-1]` the top layer.
    pub dims: Vec<usize>,
    /// Hidden width shared by every conditional's MLP.
    pub hidden: usize,
    pub likelihood: Likelihood,
}

impl HierMeta {
    /// Number of latent layers `L`.
    pub fn layers(&self) -> usize {
        self.dims.len()
    }

    /// Input width of recognition layer `l` (`q(z_l | z_{l-1})`, with
    /// `z_{-1} = x`).
    pub fn infer_in_dim(&self, layer: usize) -> usize {
        if layer == 0 {
            self.pixels
        } else {
            self.dims[layer - 1]
        }
    }
}

/// Where a hierarchical VAE's conditionals execute — the multi-layer
/// sibling of [`super::Backend`]. All calls are batched (`[B, ·]` matrices
/// in, batches out) and must be **row-independent and batch-invariant**:
/// row `r` of any output depends only on row `r` of the input, bit-for-bit,
/// so the BB-ANS/Bit-Swap loops and the coordinator's lock-step serving
/// loops may group rows freely.
pub trait HierBackend {
    fn meta(&self) -> &HierMeta;

    /// Stable identifier recorded in `BBC3` containers; decode must use a
    /// backend with the same id.
    fn backend_id(&self) -> String;

    /// Diagnostic compute-kernel variant, mirroring
    /// [`super::Backend::kernel_id`]: never part of the container
    /// identity because every variant is bit-identical.
    fn kernel_id(&self) -> String {
        crate::simd::kernel_name().to_string()
    }

    /// Seed that deterministically reproduces this backend's weights, for
    /// self-describing containers (`0` = weights come from trained
    /// artifacts and must be loaded by model name).
    fn weight_seed(&self) -> u64 {
        0
    }

    /// Recognition conditional `q(z_layer | z_{layer-1})` (`z_{-1} = x`):
    /// `[B, infer_in_dim(layer)]` → `(mu, sigma)` of width `dims[layer]`.
    fn infer_batch(&self, layer: usize, xs: &Matrix) -> Result<PosteriorBatch>;

    /// Generative conditional `p(z_layer | z_{layer+1})` for
    /// `layer < L-1`: `[B, dims[layer+1]]` → `(mu, sigma)` of width
    /// `dims[layer]`. (The top layer has no conditional — its prior is the
    /// exactly-uniform discretized standard normal.)
    fn gen_batch(&self, layer: usize, ys: &Matrix) -> Result<PosteriorBatch>;

    /// Data likelihood `p(x | z_0)`: `[B, dims[0]]` → per-pixel parameters
    /// per row.
    fn likelihood_batch(&self, ys: &Matrix) -> Result<Vec<PixelParams>>;
}

/// One diagonal-Gaussian conditional: `input → hidden (ReLU) → (mu, lv)`,
/// with `sigma = exp(lv/2)` exactly as the single-layer backend computes
/// it. Weights are stored packed only — the packed GEMM *is* the reference
/// semantics at this layer (pinned against the scalar kernel by the tensor
/// tests).
struct GaussNet {
    w1: super::tensor::PackedMatrix,
    b1: Vec<f32>,
    w_mu: super::tensor::PackedMatrix,
    b_mu: Vec<f32>,
    w_lv: super::tensor::PackedMatrix,
    b_lv: Vec<f32>,
}

impl GaussNet {
    fn random(rng: &mut Rng, input: usize, hidden: usize, out: usize) -> Self {
        let mut mat = |r: usize, c: usize, scale: f64| {
            Matrix::new(
                r,
                c,
                (0..r * c).map(|_| (rng.normal() * scale) as f32).collect(),
            )
            .packed()
        };
        Self {
            w1: mat(input, hidden, 0.08),
            b1: vec![0.0; hidden],
            w_mu: mat(hidden, out, 0.1),
            b_mu: vec![0.0; out],
            w_lv: mat(hidden, out, 0.05),
            b_lv: vec![-1.0; out],
        }
    }

    fn forward(&self, xs: &Matrix) -> PosteriorBatch {
        let h = dense_packed(xs, &self.w1, &self.b1, Epilogue::Relu);
        let mu = dense_packed(&h, &self.w_mu, &self.b_mu, Epilogue::Linear);
        let mut sigma = dense_packed(&h, &self.w_lv, &self.b_lv, Epilogue::Linear);
        for v in &mut sigma.data {
            *v = (0.5 * v.clamp(LOGVAR_MIN, LOGVAR_MAX)).exp();
        }
        PosteriorBatch { mu, sigma }
    }
}

/// The pixel head `p(x | z_0)`: `dims[0] → hidden (ReLU) → pixels·heads`
/// with the output nonlinearity fused, mirroring the single-layer
/// generative net.
struct OutNet {
    w1: super::tensor::PackedMatrix,
    b1: Vec<f32>,
    w_out: super::tensor::PackedMatrix,
    b_out: Vec<f32>,
}

impl OutNet {
    fn random(rng: &mut Rng, input: usize, hidden: usize, out: usize) -> Self {
        let mut mat = |r: usize, c: usize, scale: f64| {
            Matrix::new(
                r,
                c,
                (0..r * c).map(|_| (rng.normal() * scale) as f32).collect(),
            )
            .packed()
        };
        Self {
            w1: mat(input, hidden, 0.1),
            b1: vec![0.0; hidden],
            w_out: mat(hidden, out, 0.05),
            b_out: vec![0.0; out],
        }
    }

    fn forward(&self, ys: &Matrix, likelihood: Likelihood, pixels: usize) -> Vec<PixelParams> {
        let ep = match likelihood {
            Likelihood::Bernoulli => Epilogue::Sigmoid,
            Likelihood::BetaBinomial => Epilogue::Softplus,
        };
        let h = dense_packed(ys, &self.w1, &self.b1, Epilogue::Relu);
        let out = dense_packed(&h, &self.w_out, &self.b_out, ep);
        match likelihood {
            Likelihood::Bernoulli => (0..ys.rows)
                .map(|r| PixelParams::Bernoulli(out.row(r).to_vec()))
                .collect(),
            Likelihood::BetaBinomial => (0..ys.rows)
                .map(|r| {
                    let row = out.row(r);
                    PixelParams::BetaBinomialAb {
                        alpha: row[..pixels].iter().map(|v| v + AB_EPS).collect(),
                        beta: row[pixels..].iter().map(|v| v + AB_EPS).collect(),
                    }
                })
                .collect(),
        }
    }
}

/// Pure-Rust hierarchical VAE on the packed-GEMM kernels. `Sync` by
/// construction (plain data), so the chunk-parallel coding paths apply.
pub struct HierVae {
    meta: HierMeta,
    /// `inf[l]` computes `q(z_l | z_{l-1})` (`z_{-1} = x`); length `L`.
    inf: Vec<GaussNet>,
    /// `gen[l]` computes `p(z_l | z_{l+1})`; length `L-1`.
    gen: Vec<GaussNet>,
    out: OutNet,
    weight_seed: u64,
}

impl HierVae {
    /// A deterministic, seeded model: the same `(meta, seed)` always yields
    /// the same weights, on the encoder and the decoder side alike — this
    /// is what makes `BBC3` containers self-describing until trained
    /// hierarchical artifacts exist (the header records `(dims, hidden,
    /// likelihood, weight_seed)`).
    pub fn random(meta: HierMeta, seed: u64) -> Self {
        assert!(!meta.dims.is_empty(), "hierarchy needs at least one layer");
        assert!(meta.dims.iter().all(|&d| d >= 1), "zero-width latent layer");
        assert_ne!(seed, 0, "weight seed 0 is reserved for artifact-backed models");
        let mut rng = Rng::new(seed);
        let heads = match meta.likelihood {
            Likelihood::Bernoulli => 1,
            Likelihood::BetaBinomial => 2,
        };
        let l = meta.layers();
        let inf = (0..l)
            .map(|layer| {
                GaussNet::random(&mut rng, meta.infer_in_dim(layer), meta.hidden, meta.dims[layer])
            })
            .collect();
        let gen = (0..l.saturating_sub(1))
            .map(|layer| {
                GaussNet::random(&mut rng, meta.dims[layer + 1], meta.hidden, meta.dims[layer])
            })
            .collect();
        let out = OutNet::random(&mut rng, meta.dims[0], meta.hidden, meta.pixels * heads);
        Self {
            meta,
            inf,
            gen,
            out,
            weight_seed: seed,
        }
    }
}

impl HierBackend for HierVae {
    fn meta(&self) -> &HierMeta {
        &self.meta
    }

    fn backend_id(&self) -> String {
        format!("hier-native-s{}", self.weight_seed)
    }

    fn weight_seed(&self) -> u64 {
        self.weight_seed
    }

    fn infer_batch(&self, layer: usize, xs: &Matrix) -> Result<PosteriorBatch> {
        let Some(net) = self.inf.get(layer) else {
            bail!("recognition layer {layer} out of range (L = {})", self.meta.layers());
        };
        let want = self.meta.infer_in_dim(layer);
        if xs.cols != want {
            bail!("recognition layer {layer} input width {} != {want}", xs.cols);
        }
        Ok(net.forward(xs))
    }

    fn gen_batch(&self, layer: usize, ys: &Matrix) -> Result<PosteriorBatch> {
        let Some(net) = self.gen.get(layer) else {
            bail!(
                "generative conditional {layer} out of range (L = {})",
                self.meta.layers()
            );
        };
        let want = self.meta.dims[layer + 1];
        if ys.cols != want {
            bail!("generative conditional {layer} input width {} != {want}", ys.cols);
        }
        Ok(net.forward(ys))
    }

    fn likelihood_batch(&self, ys: &Matrix) -> Result<Vec<PixelParams>> {
        if ys.cols != self.meta.dims[0] {
            bail!("likelihood input width {} != {}", ys.cols, self.meta.dims[0]);
        }
        Ok(self
            .out
            .forward(ys, self.meta.likelihood, self.meta.pixels))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(likelihood: Likelihood, dims: &[usize]) -> HierMeta {
        HierMeta {
            name: "hier-test".into(),
            pixels: 20,
            dims: dims.to_vec(),
            hidden: 9,
            likelihood,
        }
    }

    #[test]
    fn shapes_and_positivity() {
        let v = HierVae::random(meta(Likelihood::Bernoulli, &[6, 4, 3]), 3);
        let x = Matrix::new(2, 20, vec![0.5; 40]);
        let p0 = v.infer_batch(0, &x).unwrap();
        assert_eq!((p0.mu.rows, p0.mu.cols), (2, 6));
        assert!(p0.sigma.data.iter().all(|&s| s > 0.0));

        let z0 = Matrix::new(2, 6, vec![0.1; 12]);
        let p1 = v.infer_batch(1, &z0).unwrap();
        assert_eq!(p1.mu.cols, 4);

        let z1 = Matrix::new(2, 4, vec![-0.2; 8]);
        let g0 = v.gen_batch(0, &z1).unwrap();
        assert_eq!(g0.mu.cols, 6);

        match &v.likelihood_batch(&z0).unwrap()[0] {
            PixelParams::Bernoulli(p) => {
                assert_eq!(p.len(), 20);
                assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
            }
            other => panic!("wrong params {other:?}"),
        }
    }

    #[test]
    fn beta_binomial_head_positive() {
        let v = HierVae::random(meta(Likelihood::BetaBinomial, &[5, 3]), 4);
        let z0 = Matrix::new(1, 5, vec![0.3; 5]);
        match &v.likelihood_batch(&z0).unwrap()[0] {
            PixelParams::BetaBinomialAb { alpha, beta } => {
                assert_eq!(alpha.len(), 20);
                assert_eq!(beta.len(), 20);
                assert!(alpha.iter().all(|&a| a > 0.0));
                assert!(beta.iter().all(|&b| b > 0.0));
            }
            other => panic!("wrong params {other:?}"),
        }
    }

    #[test]
    fn deterministic_and_batch_invariant() {
        // Same seed → same weights; row r of a batch equals the same row
        // computed alone, bitwise (the contract every coding loop needs).
        let a = HierVae::random(meta(Likelihood::Bernoulli, &[6, 4]), 11);
        let b = HierVae::random(meta(Likelihood::Bernoulli, &[6, 4]), 11);
        let mut rng = Rng::new(5);
        let rows: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..20).map(|_| (rng.f64() < 0.4) as u32 as f32).collect())
            .collect();
        let flat: Vec<f32> = rows.iter().flatten().copied().collect();
        let batch = a.infer_batch(0, &Matrix::new(5, 20, flat)).unwrap();
        for (r, row) in rows.iter().enumerate() {
            let one = a.infer_batch(0, &Matrix::new(1, 20, row.clone())).unwrap();
            assert_eq!(one.mu.row(0), batch.mu.row(r), "mu row {r}");
            assert_eq!(one.sigma.row(0), batch.sigma.row(r), "sigma row {r}");
            let other = b.infer_batch(0, &Matrix::new(1, 20, row.clone())).unwrap();
            assert_eq!(other.mu.row(0), one.mu.row(0), "seeded rebuild row {r}");
        }
    }

    #[test]
    fn rejects_bad_layer_and_width() {
        let v = HierVae::random(meta(Likelihood::Bernoulli, &[6, 4]), 7);
        let x = Matrix::new(1, 20, vec![0.0; 20]);
        assert!(v.infer_batch(2, &x).is_err());
        assert!(v.infer_batch(1, &x).is_err()); // wants width 6
        assert!(v.gen_batch(1, &x).is_err()); // only conditional 0 exists
        let z1 = Matrix::new(1, 4, vec![0.0; 4]);
        assert!(v.gen_batch(0, &z1).is_ok());
        assert!(v.likelihood_batch(&z1).is_err()); // wants width 6
    }
}
