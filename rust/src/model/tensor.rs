//! Dense f32 tensor ops for the native (pure-Rust) model backend.
//!
//! Two matmul paths share one numeric contract:
//!
//! * [`dense`] — the scalar reference kernel (kept for cross-checks and
//!   as the validation baseline);
//! * [`dense_packed`] — the production kernel: a cache-blocked,
//!   register-tiled GEMM over a [`PackedMatrix`] (transposed weights in
//!   panels of [`NR`] output columns, packed once at model load via
//!   [`Matrix::packed`]), with optional fused bias+activation epilogues
//!   ([`Epilogue`]).
//!
//! **Determinism contract.** Every output element is accumulated in ONE
//! fixed order — `b[n]`, then `x[k]·w[k][n]` for `k` ascending — exactly
//! the order of the reference kernel, independent of batch size, tile
//! shape, or how rows are distributed across threads. BB-ANS needs the
//! decoder to reproduce the encoder's f32 distribution parameters
//! bit-for-bit, so the packed path can batch arbitrarily without changing
//! a single coded bit (pinned by `packed_matches_reference_bitwise` below
//! and the batch-identity property tests). The reference kernel skips
//! exact-zero inputs and the packed kernel does too; for finite weights an
//! elided `+= 0.0 * w` changes no value (at most the sign of a zero,
//! which no downstream computation distinguishes).
//!
//! **SIMD microkernels** (ISSUE 5). [`dense_packed_into`] dispatches at
//! runtime ([`crate::simd::active`]) between the scalar-with-
//! autovectorization body and explicit AVX2 (`x86_64`) / NEON (`aarch64`)
//! microkernels. The SIMD bodies vectorize **across the [`NR`] output
//! lanes of a panel — never across `k`**: one vector register holds a
//! row-tile's `NR` accumulators, each updated with a lane-wise
//! `acc + x·w` (separate mul and add; FMA would fuse the two roundings
//! the contract requires). Each lane therefore performs the exact scalar
//! accumulation sequence, so every variant is bit-identical to the
//! reference — pinned by `simd_kernels_match_scalar_bitwise` below and
//! the container-level invariance tests in `tests/properties.rs`. The
//! bias load and ReLU epilogue are vectorized too (ReLU via compare+mask,
//! preserving `-0.0` and NaN semantics); the sigmoid/softplus epilogues
//! apply the scalar libm-exact functions lane by lane at store time — a
//! vector `exp` approximation would break bit-identity — still fused in
//! the sense that the output matrix is written exactly once.

/// Output columns per packed panel (register-tile width; the microkernel
/// keeps `NR` accumulators live per row).
pub const NR: usize = 8;
/// Rows per register tile: one panel pass accumulates `MR` rows so the
/// L1-resident panel is reused before it is evicted.
pub const MR: usize = 4;
/// K-dimension cache block: the microkernel streams panels in `KC`-row
/// slabs (`KC * NR * 4` bytes ≈ 16 KiB, comfortably L1-resident).
pub const KC: usize = 512;
/// Row-dimension cache block: `MC` input rows (`MC * K` floats) are
/// re-streamed against every panel, so they should stay L2-resident.
pub const MC: usize = 64;

/// Row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Pack this weight matrix (`[K, N]`) for [`dense_packed`]: columns
    /// are grouped into panels of [`NR`], each panel stored k-major with
    /// the `NR` column weights of one `k` contiguous. Done once at model
    /// load; the tail panel is zero-padded (padded lanes accumulate into
    /// discarded registers).
    pub fn packed(&self) -> PackedMatrix {
        let (k, n) = (self.rows, self.cols);
        let n_panels = n.div_ceil(NR).max(1);
        let mut panels = vec![0.0f32; n_panels * k * NR];
        for j in 0..n_panels {
            let width = NR.min(n - (j * NR).min(n));
            let base = j * k * NR;
            for kk in 0..k {
                for nn in 0..width {
                    panels[base + kk * NR + nn] = self.data[kk * n + j * NR + nn];
                }
            }
        }
        PackedMatrix {
            rows: k,
            cols: n,
            panels,
        }
    }
}

/// Transposed-panel weight layout produced by [`Matrix::packed`]; the
/// input format of [`dense_packed`].
#[derive(Debug, Clone, PartialEq)]
pub struct PackedMatrix {
    /// K — inner (contraction) dimension.
    pub rows: usize,
    /// N — output columns (before padding).
    pub cols: usize,
    /// `ceil(N/NR)` panels, each `K * NR` floats, k-major.
    panels: Vec<f32>,
}

impl PackedMatrix {
    #[inline]
    fn n_panels(&self) -> usize {
        self.cols.div_ceil(NR).max(1)
    }

    #[inline]
    fn panel(&self, j: usize) -> &[f32] {
        &self.panels[j * self.rows * NR..(j + 1) * self.rows * NR]
    }
}

/// Fused epilogue applied to each output element while it is still in an
/// accumulator register — saves a second full pass over the output matrix
/// and its write-back/reload. Bit-identical to running the corresponding
/// `*_inplace` pass afterwards (same scalar function, same input).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Epilogue {
    /// Store the biased accumulator unchanged.
    Linear,
    /// `max(v, 0)` with the same `-0.0` semantics as [`relu_inplace`].
    Relu,
    /// Numerically stable [`sigmoid_f32`].
    Sigmoid,
    /// Numerically stable [`softplus_f32`].
    Softplus,
}

impl Epilogue {
    #[inline(always)]
    fn apply(self, v: f32) -> f32 {
        match self {
            Epilogue::Linear => v,
            Epilogue::Relu => {
                if v < 0.0 {
                    0.0
                } else {
                    v
                }
            }
            Epilogue::Sigmoid => sigmoid_f32(v),
            Epilogue::Softplus => softplus_f32(v),
        }
    }
}

/// `out = x @ w + b`, with `x: [B, K]`, `w: [K, N]`, `b: [N]`.
///
/// The inner loop is written k-outer so each pass streams a row of `w`
/// sequentially (cache-friendly; autovectorizes well).
pub fn dense(x: &Matrix, w: &Matrix, b: &[f32]) -> Matrix {
    assert_eq!(x.cols, w.rows, "dense: inner dims {} vs {}", x.cols, w.rows);
    assert_eq!(w.cols, b.len(), "dense: bias len");
    let mut out = Matrix::zeros(x.rows, w.cols);
    for r in 0..x.rows {
        let xrow = x.row(r);
        let orow = out.row_mut(r);
        orow.copy_from_slice(b);
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue; // input images are sparse; skip zero activations
            }
            let wrow = w.row(k);
            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                *o += xv * wv;
            }
        }
    }
    out
}

/// `out = epilogue(x @ w + b)` over packed weights — the production GEMM.
///
/// Loop structure (outer→inner): `MC` row blocks of `x` (L2 reuse) →
/// weight panels of [`NR`] columns → [`MR`]-row register tiles → `KC`
/// cache blocks of the contraction → rows of the tile → `k` ascending.
/// The `NR` accumulators per row live in registers across the whole `k`
/// loop, so each element's floating-point accumulation order is exactly
/// the reference [`dense`] order regardless of every blocking parameter —
/// see the module docs for why BB-ANS requires that.
pub fn dense_packed(x: &Matrix, w: &PackedMatrix, b: &[f32], epilogue: Epilogue) -> Matrix {
    let mut out = Matrix::zeros(x.rows, w.cols);
    dense_packed_into(x, w, b, epilogue, &mut out);
    out
}

/// [`dense_packed`] writing into a caller-owned output matrix (the
/// batched backend reuses one per layer across calls). Dispatches to the
/// scalar or SIMD microkernel selected by [`crate::simd::active`]; every
/// variant is bit-identical (module docs).
pub fn dense_packed_into(
    x: &Matrix,
    w: &PackedMatrix,
    b: &[f32],
    epilogue: Epilogue,
    out: &mut Matrix,
) {
    let (bsz, k, n) = (x.rows, w.rows, w.cols);
    assert_eq!(x.cols, k, "dense_packed: inner dims {} vs {k}", x.cols);
    assert_eq!(b.len(), n, "dense_packed: bias len");
    assert_eq!((out.rows, out.cols), (bsz, n), "dense_packed: out shape");
    if n == 0 {
        return;
    }
    dense_packed_into_kernel(crate::simd::active(), x, w, b, epilogue, out);
}

/// [`dense_packed_into`] pinned to one kernel variant (tests and benches;
/// shape checks are the caller's).
pub(crate) fn dense_packed_into_kernel(
    kernel: crate::simd::Kernel,
    x: &Matrix,
    w: &PackedMatrix,
    b: &[f32],
    epilogue: Epilogue,
    out: &mut Matrix,
) {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Kernel::Avx2` is only ever active()/forced when the CPU
        // reports AVX2 (see `simd::detect` / `simd::force`).
        crate::simd::Kernel::Avx2 => unsafe { dense_packed_into_avx2(x, w, b, epilogue, out) },
        #[cfg(target_arch = "aarch64")]
        crate::simd::Kernel::Neon => dense_packed_into_neon(x, w, b, epilogue, out),
        _ => dense_packed_into_scalar(x, w, b, epilogue, out),
    }
}

/// The scalar-with-autovectorization body (the pre-SIMD production kernel,
/// kept verbatim as the portable reference of the packed loop structure).
fn dense_packed_into_scalar(
    x: &Matrix,
    w: &PackedMatrix,
    b: &[f32],
    epilogue: Epilogue,
    out: &mut Matrix,
) {
    let (bsz, k, n) = (x.rows, w.rows, w.cols);
    for rc in (0..bsz).step_by(MC) {
        let rc_end = (rc + MC).min(bsz);
        for j in 0..w.n_panels() {
            let panel = w.panel(j);
            let col0 = j * NR;
            let width = NR.min(n - col0);
            // Bias tile, zero-padded so every accumulator lane has a
            // well-defined (discarded) value in the tail panel.
            let mut btile = [0.0f32; NR];
            btile[..width].copy_from_slice(&b[col0..col0 + width]);
            for r0 in (rc..rc_end).step_by(MR) {
                let mr = MR.min(rc_end - r0);
                let mut acc = [btile; MR];
                for kb in (0..k).step_by(KC) {
                    let kb_end = (kb + KC).min(k);
                    let pslab = &panel[kb * NR..kb_end * NR];
                    for (i, acc_i) in acc.iter_mut().enumerate().take(mr) {
                        let xrow = &x.row(r0 + i)[kb..kb_end];
                        for (&xv, pk) in xrow.iter().zip(pslab.chunks_exact(NR)) {
                            if xv == 0.0 {
                                continue; // value-preserving sparse skip
                            }
                            for (a, &wv) in acc_i.iter_mut().zip(pk.iter()) {
                                *a += xv * wv;
                            }
                        }
                    }
                }
                for (i, acc_i) in acc.iter().enumerate().take(mr) {
                    let orow = &mut out.row_mut(r0 + i)[col0..col0 + width];
                    for (o, &a) in orow.iter_mut().zip(acc_i.iter()) {
                        *o = epilogue.apply(a);
                    }
                }
            }
        }
    }
}

/// AVX2 microkernel: identical loop structure to the scalar body, with a
/// row-tile's [`NR`] = 8 accumulators held in one `ymm` register. Each
/// k-step is `acc = acc + broadcast(x[k]) * panel[k]` as a separate
/// `vmulps` + `vaddps` (NOT `vfmadd`), so every lane's value sequence is
/// exactly the scalar one — bit-identical by IEEE-754 lane semantics. The
/// `x[k] == 0` sparse skip is kept (same value-preservation argument and
/// the same perf win on MNIST-like inputs).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dense_packed_into_avx2(
    x: &Matrix,
    w: &PackedMatrix,
    b: &[f32],
    epilogue: Epilogue,
    out: &mut Matrix,
) {
    use core::arch::x86_64::*;
    const _: () = assert!(NR == 8, "the AVX2 microkernel is written for 8 f32 lanes");
    let (bsz, k, n) = (x.rows, w.rows, w.cols);
    for rc in (0..bsz).step_by(MC) {
        let rc_end = (rc + MC).min(bsz);
        for j in 0..w.n_panels() {
            let panel = w.panel(j);
            let col0 = j * NR;
            let width = NR.min(n - col0);
            let mut btile = [0.0f32; NR];
            btile[..width].copy_from_slice(&b[col0..col0 + width]);
            let bvec = _mm256_loadu_ps(btile.as_ptr());
            for r0 in (rc..rc_end).step_by(MR) {
                let mr = MR.min(rc_end - r0);
                let mut acc = [bvec; MR];
                for kb in (0..k).step_by(KC) {
                    let kb_end = (kb + KC).min(k);
                    let pslab = &panel[kb * NR..kb_end * NR];
                    for (i, acc_i) in acc.iter_mut().enumerate().take(mr) {
                        let xrow = &x.row(r0 + i)[kb..kb_end];
                        let mut a = *acc_i;
                        for (kk, &xv) in xrow.iter().enumerate() {
                            if xv == 0.0 {
                                continue; // value-preserving sparse skip
                            }
                            let wv = _mm256_loadu_ps(pslab.as_ptr().add(kk * NR));
                            a = _mm256_add_ps(a, _mm256_mul_ps(_mm256_set1_ps(xv), wv));
                        }
                        *acc_i = a;
                    }
                }
                for (i, &acc_i) in acc.iter().enumerate().take(mr) {
                    // ReLU stays fully vectorized: where `a < 0.0` select
                    // +0.0, else keep the bits — matches `Epilogue::apply`
                    // for -0.0 (kept) and NaN (kept) exactly.
                    let v = if matches!(epilogue, Epilogue::Relu) {
                        let neg = _mm256_cmp_ps::<_CMP_LT_OQ>(acc_i, _mm256_setzero_ps());
                        _mm256_andnot_ps(neg, acc_i)
                    } else {
                        acc_i
                    };
                    let mut tmp = [0.0f32; NR];
                    _mm256_storeu_ps(tmp.as_mut_ptr(), v);
                    let orow = &mut out.row_mut(r0 + i)[col0..col0 + width];
                    match epilogue {
                        // Already applied (or nothing to apply).
                        Epilogue::Linear | Epilogue::Relu => {
                            orow.copy_from_slice(&tmp[..width]);
                        }
                        // Transcendentals must match libm bit-for-bit, so
                        // they run scalar per lane, fused at store time.
                        Epilogue::Sigmoid => {
                            for (o, &t) in orow.iter_mut().zip(tmp.iter()) {
                                *o = sigmoid_f32(t);
                            }
                        }
                        Epilogue::Softplus => {
                            for (o, &t) in orow.iter_mut().zip(tmp.iter()) {
                                *o = softplus_f32(t);
                            }
                        }
                    }
                }
            }
        }
    }
}

/// NEON microkernel (`aarch64`): the AVX2 body with the 8 output lanes
/// split across two `float32x4_t` registers (NEON is 128-bit). Same
/// lane-direction rule, same separate mul+add (`vmulq`/`vaddq`, never
/// `vfmaq`), same sparse skip, same scalar transcendental epilogues —
/// bit-identical to the scalar kernel by the identical per-lane op
/// sequence. NEON is a baseline `aarch64` feature, so the intrinsics are
/// unconditionally safe to issue there.
#[cfg(target_arch = "aarch64")]
fn dense_packed_into_neon(
    x: &Matrix,
    w: &PackedMatrix,
    b: &[f32],
    epilogue: Epilogue,
    out: &mut Matrix,
) {
    use core::arch::aarch64::*;
    const _: () = assert!(NR == 8, "the NEON microkernel is written for 2x4 f32 lanes");
    let (bsz, k, n) = (x.rows, w.rows, w.cols);
    for rc in (0..bsz).step_by(MC) {
        let rc_end = (rc + MC).min(bsz);
        for j in 0..w.n_panels() {
            let panel = w.panel(j);
            let col0 = j * NR;
            let width = NR.min(n - col0);
            let mut btile = [0.0f32; NR];
            btile[..width].copy_from_slice(&b[col0..col0 + width]);
            // SAFETY: NEON is baseline on aarch64; all pointers below stay
            // in bounds of their slices (panel rows are NR-strided, the
            // btile/tmp arrays are NR long).
            unsafe {
                let blo = vld1q_f32(btile.as_ptr());
                let bhi = vld1q_f32(btile.as_ptr().add(4));
                for r0 in (rc..rc_end).step_by(MR) {
                    let mr = MR.min(rc_end - r0);
                    let mut acc = [[blo, bhi]; MR];
                    for kb in (0..k).step_by(KC) {
                        let kb_end = (kb + KC).min(k);
                        let pslab = &panel[kb * NR..kb_end * NR];
                        for (i, acc_i) in acc.iter_mut().enumerate().take(mr) {
                            let xrow = &x.row(r0 + i)[kb..kb_end];
                            let (mut alo, mut ahi) = (acc_i[0], acc_i[1]);
                            for (kk, &xv) in xrow.iter().enumerate() {
                                if xv == 0.0 {
                                    continue; // value-preserving sparse skip
                                }
                                let xb = vdupq_n_f32(xv);
                                let wlo = vld1q_f32(pslab.as_ptr().add(kk * NR));
                                let whi = vld1q_f32(pslab.as_ptr().add(kk * NR + 4));
                                alo = vaddq_f32(alo, vmulq_f32(xb, wlo));
                                ahi = vaddq_f32(ahi, vmulq_f32(xb, whi));
                            }
                            *acc_i = [alo, ahi];
                        }
                    }
                    for (i, &[alo, ahi]) in acc.iter().enumerate().take(mr) {
                        let (vlo, vhi) = if matches!(epilogue, Epilogue::Relu) {
                            // where a < 0.0 clear to +0.0, else keep bits.
                            let z = vdupq_n_f32(0.0);
                            let nlo = vcltq_f32(alo, z);
                            let nhi = vcltq_f32(ahi, z);
                            (
                                vreinterpretq_f32_u32(vbicq_u32(
                                    vreinterpretq_u32_f32(alo),
                                    nlo,
                                )),
                                vreinterpretq_f32_u32(vbicq_u32(
                                    vreinterpretq_u32_f32(ahi),
                                    nhi,
                                )),
                            )
                        } else {
                            (alo, ahi)
                        };
                        let mut tmp = [0.0f32; NR];
                        vst1q_f32(tmp.as_mut_ptr(), vlo);
                        vst1q_f32(tmp.as_mut_ptr().add(4), vhi);
                        let orow = &mut out.row_mut(r0 + i)[col0..col0 + width];
                        match epilogue {
                            Epilogue::Linear | Epilogue::Relu => {
                                orow.copy_from_slice(&tmp[..width]);
                            }
                            Epilogue::Sigmoid => {
                                for (o, &t) in orow.iter_mut().zip(tmp.iter()) {
                                    *o = sigmoid_f32(t);
                                }
                            }
                            Epilogue::Softplus => {
                                for (o, &t) in orow.iter_mut().zip(tmp.iter()) {
                                    *o = softplus_f32(t);
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

pub fn relu_inplace(m: &mut Matrix) {
    for v in &mut m.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Numerically stable f32 sigmoid: never exponentiates a positive
/// argument, so it cannot overflow anywhere in the f32 domain.
#[inline]
pub fn sigmoid_f32(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable f32 softplus, mirroring the branch structure of the
/// f64 reference `util::math::softplus`: `x` for large positive `x`
/// (where `ln(1+eˣ) − x` is far below f32 resolution), `eˣ` for large
/// negative `x`, `ln_1p(eˣ)` in between. No overflow at any input.
#[inline]
pub fn softplus_f32(x: f32) -> f32 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

pub fn sigmoid_inplace(m: &mut Matrix) {
    // Computed directly in f32 (no f64 round-trip): same stable
    // formulation as the f64 reference, ~half the lane width cost on
    // vectorized loops. Accuracy vs f64 is pinned by a tolerance test.
    for v in &mut m.data {
        *v = sigmoid_f32(*v);
    }
}

pub fn softplus_inplace(m: &mut Matrix) {
    for v in &mut m.data {
        *v = softplus_f32(*v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_known_values() {
        // x = [[1,2],[3,4]], w = [[1,0],[0,1]], b = [10, 20]
        let x = Matrix::new(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = Matrix::new(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let out = dense(&x, &w, &[10.0, 20.0]);
        assert_eq!(out.data, vec![11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn dense_rectangular() {
        let x = Matrix::new(1, 3, vec![1.0, -1.0, 2.0]);
        let w = Matrix::new(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let out = dense(&x, &w, &[0.5, -0.5]);
        // [1*1 -1*3 + 2*5 + 0.5, 1*2 -1*4 + 2*6 - 0.5] = [8.5, 9.5]
        assert_eq!(out.data, vec![8.5, 9.5]);
    }

    #[test]
    fn activations() {
        let mut m = Matrix::new(1, 3, vec![-1.0, 0.0, 2.0]);
        relu_inplace(&mut m);
        assert_eq!(m.data, vec![0.0, 0.0, 2.0]);
        let mut s = Matrix::new(1, 1, vec![0.0]);
        sigmoid_inplace(&mut s);
        assert_eq!(s.data, vec![0.5]);
        let mut p = Matrix::new(1, 1, vec![0.0]);
        softplus_inplace(&mut p);
        assert!((p.data[0] - std::f64::consts::LN_2 as f32).abs() < 1e-6);
    }

    #[test]
    fn f32_activations_track_f64_reference() {
        // The f32 formulations must stay within float-rounding distance of
        // the f64 reference in util::math, so swapping them in changes no
        // decode decision (encoder and decoder share the same code path;
        // this pins the *accuracy* of that shared path).
        let mut xs: Vec<f32> = (-2700..=2700).map(|i| i as f32 * 0.037).collect();
        xs.extend_from_slice(&[
            -1.0e4, -88.7, -30.001, -30.0, -29.999, -1e-4, 0.0, 1e-4, 29.999, 30.0, 30.001, 88.7,
            1.0e4,
        ]);
        for &x in &xs {
            let s = sigmoid_f32(x) as f64;
            let s_ref = crate::util::math::sigmoid(x as f64);
            assert!(
                (s - s_ref).abs() <= 1e-6,
                "sigmoid({x}): f32 {s} vs f64 {s_ref}"
            );
            assert!(s.is_finite() && (0.0..=1.0).contains(&s));

            let p = softplus_f32(x) as f64;
            let p_ref = crate::util::math::softplus(x as f64);
            // Relative tolerance, with an absolute floor for the deep
            // subnormal tail (x ≲ −87 underflows gracefully in f32).
            assert!(
                (p - p_ref).abs() <= 1e-6 * p_ref.abs() + 1e-40,
                "softplus({x}): f32 {p} vs f64 {p_ref}"
            );
            assert!(p.is_finite() && p >= 0.0);
        }
    }

    fn rand_matrix(rng: &mut crate::util::rng::Rng, rows: usize, cols: usize) -> Matrix {
        Matrix::new(
            rows,
            cols,
            (0..rows * cols)
                .map(|_| {
                    // Sparse-ish, like scaled MNIST, to exercise the skip.
                    if rng.f64() < 0.3 {
                        0.0
                    } else {
                        (rng.normal() * 0.7) as f32
                    }
                })
                .collect(),
        )
    }

    /// The packed kernel must agree with the reference kernel BITWISE for
    /// every shape, including tile tails (rows % MR, cols % NR, k % KC) —
    /// this is the determinism contract the whole batched BB-ANS pipeline
    /// rests on (module docs). Because the accumulation order also equals
    /// the seed `dense()` order, every pre-existing golden vector remains
    /// valid.
    #[test]
    fn packed_matches_reference_bitwise() {
        let mut rng = crate::util::rng::Rng::new(0x9e3);
        let shapes = [
            (1usize, 1usize, 1usize),
            (1, 784, 100),
            (3, 7, 1),
            (4, 8, 8),
            (5, 9, 17),
            (64, 100, 40),
            (65, 40, 103),
            (130, 513, 23),
        ];
        for &(m, k, n) in &shapes {
            let x = rand_matrix(&mut rng, m, k);
            let w = rand_matrix(&mut rng, k, n);
            let b: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.2) as f32).collect();
            let reference = dense(&x, &w, &b);
            let wp = w.packed();
            let got = dense_packed(&x, &wp, &b, Epilogue::Linear);
            assert_eq!((got.rows, got.cols), (m, n));
            for (i, (a, r)) in got.data.iter().zip(reference.data.iter()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    r.to_bits(),
                    "shape {m}x{k}x{n} elem {i}: packed {a} vs reference {r}"
                );
            }
        }
    }

    /// Every runtime-dispatchable SIMD kernel must agree with the scalar
    /// packed kernel BITWISE, for every epilogue and for shapes covering
    /// all tile tails (rows % MR, cols % NR, k % KC) — the ISSUE 5 face
    /// of the determinism contract. Kernels are invoked directly (not via
    /// the global dispatch), so this test is race-free under the parallel
    /// test harness.
    #[test]
    fn simd_kernels_match_scalar_bitwise() {
        let mut rng = crate::util::rng::Rng::new(0x51D0);
        let shapes = [
            (1usize, 1usize, 1usize),
            (1, 784, 100),
            (3, 7, 1),
            (4, 8, 8),
            (5, 9, 17),
            (2, 513, 23), // k > KC: multiple cache slabs
            (65, 40, 103),
        ];
        let epilogues = [
            Epilogue::Linear,
            Epilogue::Relu,
            Epilogue::Sigmoid,
            Epilogue::Softplus,
        ];
        for &(m, k, n) in &shapes {
            let x = rand_matrix(&mut rng, m, k);
            let w = rand_matrix(&mut rng, k, n);
            let wp = w.packed();
            let b: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.2) as f32).collect();
            for ep in epilogues {
                let mut want = Matrix::zeros(m, n);
                dense_packed_into_kernel(crate::simd::Kernel::Scalar, &x, &wp, &b, ep, &mut want);
                for kernel in crate::simd::available() {
                    let mut got = Matrix::zeros(m, n);
                    dense_packed_into_kernel(kernel, &x, &wp, &b, ep, &mut got);
                    for (i, (a, r)) in got.data.iter().zip(want.data.iter()).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            r.to_bits(),
                            "{kernel:?} {ep:?} shape {m}x{k}x{n} elem {i}: {a} vs {r}"
                        );
                    }
                }
            }
        }
        // Special values through ReLU: with all-zero inputs the sparse
        // skip leaves acc = bias exactly, so the vectorized compare+mask
        // must keep -0.0 (scalar `v < 0.0` is false for it) and zero the
        // negative subnormal.
        let x = Matrix::new(1, 2, vec![0.0, 0.0]);
        let w = Matrix::new(2, 9, vec![0.0; 18]);
        let wp = w.packed();
        let mut bias = vec![0.0f32; 9];
        bias[0] = -0.0;
        bias[1] = f32::MIN_POSITIVE;
        bias[2] = -f32::MIN_POSITIVE;
        for kernel in crate::simd::available() {
            let mut got = Matrix::zeros(1, 9);
            dense_packed_into_kernel(kernel, &x, &wp, &bias, Epilogue::Relu, &mut got);
            assert_eq!(got.data[0].to_bits(), (-0.0f32).to_bits(), "{kernel:?} -0.0");
            assert_eq!(got.data[1], f32::MIN_POSITIVE, "{kernel:?}");
            assert_eq!(got.data[2], 0.0, "{kernel:?} negative subnormal");
        }
    }

    /// Fused epilogues equal the separate activation pass bit-for-bit.
    #[test]
    fn fused_epilogues_match_separate_passes() {
        let mut rng = crate::util::rng::Rng::new(0xe91);
        let x = rand_matrix(&mut rng, 9, 31);
        let w = rand_matrix(&mut rng, 31, 21);
        let b: Vec<f32> = (0..21).map(|_| (rng.normal()) as f32).collect();
        let wp = w.packed();
        let passes: [(Epilogue, fn(&mut Matrix)); 3] = [
            (Epilogue::Relu, relu_inplace),
            (Epilogue::Sigmoid, sigmoid_inplace),
            (Epilogue::Softplus, softplus_inplace),
        ];
        for (ep, pass) in passes {
            let fused = dense_packed(&x, &wp, &b, ep);
            let mut separate = dense_packed(&x, &wp, &b, Epilogue::Linear);
            pass(&mut separate);
            let same = fused
                .data
                .iter()
                .zip(separate.data.iter())
                .all(|(a, r)| a.to_bits() == r.to_bits());
            assert!(same, "epilogue {ep:?} diverged from the separate pass");
        }
    }

    /// Batching must not change any row: the packed result for B rows
    /// equals B separate 1-row calls (each element's accumulation touches
    /// only its own row).
    #[test]
    fn packed_rows_independent_of_batch_grouping() {
        let mut rng = crate::util::rng::Rng::new(0x77);
        let x = rand_matrix(&mut rng, 11, 50);
        let w = rand_matrix(&mut rng, 50, 19);
        let b: Vec<f32> = (0..19).map(|_| (rng.normal()) as f32).collect();
        let wp = w.packed();
        let batched = dense_packed(&x, &wp, &b, Epilogue::Sigmoid);
        for r in 0..x.rows {
            let one = Matrix::new(1, 50, x.row(r).to_vec());
            let single = dense_packed(&one, &wp, &b, Epilogue::Sigmoid);
            assert_eq!(single.row(0), batched.row(r), "row {r}");
        }
    }

    #[test]
    fn sparse_skip_matches_dense_path() {
        // Zero-skipping must not change results.
        let x = Matrix::new(1, 4, vec![0.0, 1.5, 0.0, -2.0]);
        let w = Matrix::new(4, 3, (0..12).map(|i| i as f32 * 0.1).collect());
        let out = dense(&x, &w, &[1.0, 1.0, 1.0]);
        let mut want = vec![1.0f32; 3];
        for k in 0..4 {
            for n in 0..3 {
                want[n] += x.data[k] * w.data[k * 3 + n];
            }
        }
        for (a, b) in out.data.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
