//! Minimal dense f32 tensor ops for the native (pure-Rust) model backend.
//!
//! This is deliberately small: the VAE needs matmul + bias + a few
//! activations. The native backend exists to (a) cross-check the PJRT
//! path, (b) run tests without artifacts, and (c) serve as the fallback
//! when no accelerator runtime is available. The PJRT path is the
//! production one.

/// Row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// `out = x @ w + b`, with `x: [B, K]`, `w: [K, N]`, `b: [N]`.
///
/// The inner loop is written k-outer so each pass streams a row of `w`
/// sequentially (cache-friendly; autovectorizes well).
pub fn dense(x: &Matrix, w: &Matrix, b: &[f32]) -> Matrix {
    assert_eq!(x.cols, w.rows, "dense: inner dims {} vs {}", x.cols, w.rows);
    assert_eq!(w.cols, b.len(), "dense: bias len");
    let mut out = Matrix::zeros(x.rows, w.cols);
    for r in 0..x.rows {
        let xrow = x.row(r);
        let orow = out.row_mut(r);
        orow.copy_from_slice(b);
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue; // input images are sparse; skip zero activations
            }
            let wrow = w.row(k);
            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                *o += xv * wv;
            }
        }
    }
    out
}

pub fn relu_inplace(m: &mut Matrix) {
    for v in &mut m.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Numerically stable f32 sigmoid: never exponentiates a positive
/// argument, so it cannot overflow anywhere in the f32 domain.
#[inline]
pub fn sigmoid_f32(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable f32 softplus, mirroring the branch structure of the
/// f64 reference `util::math::softplus`: `x` for large positive `x`
/// (where `ln(1+eˣ) − x` is far below f32 resolution), `eˣ` for large
/// negative `x`, `ln_1p(eˣ)` in between. No overflow at any input.
#[inline]
pub fn softplus_f32(x: f32) -> f32 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

pub fn sigmoid_inplace(m: &mut Matrix) {
    // Computed directly in f32 (no f64 round-trip): same stable
    // formulation as the f64 reference, ~half the lane width cost on
    // vectorized loops. Accuracy vs f64 is pinned by a tolerance test.
    for v in &mut m.data {
        *v = sigmoid_f32(*v);
    }
}

pub fn softplus_inplace(m: &mut Matrix) {
    for v in &mut m.data {
        *v = softplus_f32(*v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_known_values() {
        // x = [[1,2],[3,4]], w = [[1,0],[0,1]], b = [10, 20]
        let x = Matrix::new(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = Matrix::new(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let out = dense(&x, &w, &[10.0, 20.0]);
        assert_eq!(out.data, vec![11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn dense_rectangular() {
        let x = Matrix::new(1, 3, vec![1.0, -1.0, 2.0]);
        let w = Matrix::new(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let out = dense(&x, &w, &[0.5, -0.5]);
        // [1*1 -1*3 + 2*5 + 0.5, 1*2 -1*4 + 2*6 - 0.5] = [8.5, 9.5]
        assert_eq!(out.data, vec![8.5, 9.5]);
    }

    #[test]
    fn activations() {
        let mut m = Matrix::new(1, 3, vec![-1.0, 0.0, 2.0]);
        relu_inplace(&mut m);
        assert_eq!(m.data, vec![0.0, 0.0, 2.0]);
        let mut s = Matrix::new(1, 1, vec![0.0]);
        sigmoid_inplace(&mut s);
        assert_eq!(s.data, vec![0.5]);
        let mut p = Matrix::new(1, 1, vec![0.0]);
        softplus_inplace(&mut p);
        assert!((p.data[0] - std::f64::consts::LN_2 as f32).abs() < 1e-6);
    }

    #[test]
    fn f32_activations_track_f64_reference() {
        // The f32 formulations must stay within float-rounding distance of
        // the f64 reference in util::math, so swapping them in changes no
        // decode decision (encoder and decoder share the same code path;
        // this pins the *accuracy* of that shared path).
        let mut xs: Vec<f32> = (-2700..=2700).map(|i| i as f32 * 0.037).collect();
        xs.extend_from_slice(&[
            -1.0e4, -88.7, -30.001, -30.0, -29.999, -1e-4, 0.0, 1e-4, 29.999, 30.0, 30.001, 88.7,
            1.0e4,
        ]);
        for &x in &xs {
            let s = sigmoid_f32(x) as f64;
            let s_ref = crate::util::math::sigmoid(x as f64);
            assert!(
                (s - s_ref).abs() <= 1e-6,
                "sigmoid({x}): f32 {s} vs f64 {s_ref}"
            );
            assert!(s.is_finite() && (0.0..=1.0).contains(&s));

            let p = softplus_f32(x) as f64;
            let p_ref = crate::util::math::softplus(x as f64);
            // Relative tolerance, with an absolute floor for the deep
            // subnormal tail (x ≲ −87 underflows gracefully in f32).
            assert!(
                (p - p_ref).abs() <= 1e-6 * p_ref.abs() + 1e-40,
                "softplus({x}): f32 {p} vs f64 {p_ref}"
            );
            assert!(p.is_finite() && p >= 0.0);
        }
    }

    #[test]
    fn sparse_skip_matches_dense_path() {
        // Zero-skipping must not change results.
        let x = Matrix::new(1, 4, vec![0.0, 1.5, 0.0, -2.0]);
        let w = Matrix::new(4, 3, (0..12).map(|i| i as f32 * 0.1).collect());
        let out = dense(&x, &w, &[1.0, 1.0, 1.0]);
        let mut want = vec![1.0f32; 3];
        for k in 0..4 {
            for n in 0..3 {
                want[n] += x.data[k] * w.data[k * 3 + n];
            }
        }
        for (a, b) in out.data.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
