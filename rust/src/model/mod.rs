//! Model layer: metadata, weight loading, and the [`Backend`] abstraction
//! over *where* the VAE's networks run.
//!
//! Two interchangeable backends produce distribution parameters for the
//! BB-ANS codec:
//!
//! * [`vae::NativeVae`] — pure-Rust forward pass from the `.bbwt` weights
//!   (tests, cross-checks, artifact-free operation);
//! * [`vae::PjrtVae`] — executes the AOT-lowered HLO artifacts through
//!   [`crate::runtime::Engine`] (the production path; Pallas kernels
//!   inlined in the graphs).
//!
//! A compressed stream records which backend produced it: floating-point
//! results differ across backends at the ULP level, and BB-ANS requires
//! the decoder to reproduce the encoder's quantized distributions exactly.

pub mod hierarchy;
pub mod tensor;
pub mod vae;
pub mod weights;

use anyhow::{bail, Result};

use self::tensor::Matrix;

/// Which per-pixel likelihood family the generative net parameterizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Likelihood {
    /// Binarized MNIST: one probability per pixel.
    Bernoulli,
    /// Full MNIST: two positive shape parameters per pixel.
    BetaBinomial,
}

impl Likelihood {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "bernoulli" => Ok(Self::Bernoulli),
            "beta_binomial" => Ok(Self::BetaBinomial),
            other => anyhow::bail!("unknown likelihood '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Bernoulli => "bernoulli",
            Self::BetaBinomial => "beta_binomial",
        }
    }

    /// Wire tag used by container headers (`BBC3` records the likelihood
    /// family so self-describing hierarchical models rebuild exactly).
    pub fn tag(&self) -> u8 {
        match self {
            Self::Bernoulli => 0,
            Self::BetaBinomial => 1,
        }
    }

    /// Inverse of [`Likelihood::tag`].
    pub fn from_tag(tag: u8) -> Result<Self> {
        match tag {
            0 => Ok(Self::Bernoulli),
            1 => Ok(Self::BetaBinomial),
            other => bail!("unknown likelihood tag {other}"),
        }
    }
}

/// Static description of one trained model.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub pixels: usize,
    pub latent_dim: usize,
    pub hidden: usize,
    pub likelihood: Likelihood,
    /// Test-set negative ELBO in bits/dim as measured at training time
    /// (the compression-rate target; paper Table 2).
    pub test_elbo_bpd: f64,
}

/// Per-image likelihood parameters handed to the pixel codecs.
#[derive(Debug, Clone)]
pub enum PixelParams {
    /// `pixels` Bernoulli probabilities.
    Bernoulli(Vec<f32>),
    /// Analytic beta-binomial parameters (native backend).
    BetaBinomialAb { alpha: Vec<f32>, beta: Vec<f32> },
    /// Precomputed PMF table, row-major `[pixels, 256]` (PJRT backend —
    /// the table is produced inside the decoder graph by the L1 kernel).
    BetaBinomialTable(Vec<f32>),
}

/// Posterior parameters for a batch of images: row `r` of `mu`/`sigma`
/// belongs to input row `r`. Produced by [`Backend::encode_batch`]; the
/// matrices keep the whole chunk contiguous so the BB-ANS dataset loops
/// hand rows to the coder without per-image allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct PosteriorBatch {
    /// `[B, latent_dim]` posterior means.
    pub mu: Matrix,
    /// `[B, latent_dim]` posterior standard deviations.
    pub sigma: Matrix,
}

impl PosteriorBatch {
    /// Number of images in the batch.
    pub fn len(&self) -> usize {
        self.mu.rows
    }

    pub fn is_empty(&self) -> bool {
        self.mu.rows == 0
    }

    /// `(mu, sigma)` of image `r`.
    #[inline]
    pub fn row(&self, r: usize) -> (&[f32], &[f32]) {
        (self.mu.row(r), self.sigma.row(r))
    }

    /// Split into the per-image representation of [`Backend::posterior`].
    pub fn into_rows(self) -> Vec<(Vec<f32>, Vec<f32>)> {
        (0..self.len())
            .map(|r| (self.mu.row(r).to_vec(), self.sigma.row(r).to_vec()))
            .collect()
    }
}

/// Where the VAE networks execute. Batched calls take several images /
/// latents at once so callers (the coordinator) can amortize dispatch.
///
/// Deliberately **not** `Send`/`Sync`: the `xla` crate's PJRT handles are
/// reference-counted thread-local objects. The coordinator therefore owns
/// each backend inside a dedicated model-worker thread and talks to it via
/// channels (see `coordinator::batcher`), which is the batching
/// architecture we want anyway.
pub trait Backend {
    fn meta(&self) -> &ModelMeta;

    /// Stable identifier recorded in compressed containers; decode must
    /// use a backend with the same id (it encodes everything that affects
    /// bit-exactness of the distribution parameters, e.g. the PJRT batch
    /// variant).
    fn backend_id(&self) -> String;

    /// Diagnostic identifier of the compute-kernel variant this backend
    /// dispatches to (`avx2`/`neon`/`scalar`/...), surfaced next to
    /// [`Backend::backend_id`] in logs, serve banners and bench
    /// annotations. Deliberately **not** part of the container identity:
    /// every kernel variant is bit-identical by the tensor-layer
    /// determinism contract, so streams move freely between machines with
    /// different vector units.
    fn kernel_id(&self) -> String {
        crate::simd::kernel_name().to_string()
    }

    /// Recognition net: scaled images (len `pixels` each, values in [0,1])
    /// → (mu, sigma) per image.
    fn posterior(&self, xs: &[&[f32]]) -> Result<Vec<(Vec<f32>, Vec<f32>)>>;

    /// Generative net: latents (len `latent_dim` each) → per-pixel
    /// likelihood parameters per latent.
    fn likelihood(&self, ys: &[&[f32]]) -> Result<Vec<PixelParams>>;

    /// Recognition net over a whole `[B, pixels]` batch in one dispatch.
    ///
    /// The default routes through [`Backend::posterior`]; backends with a
    /// native batched path (the packed-GEMM `NativeVae`) override it.
    /// Implementations must be row-independent and batch-size-invariant:
    /// row `r` of the result depends only on row `r` of `xs`, bit-for-bit
    /// — the BB-ANS pipeline batches freely on that guarantee.
    fn encode_batch(&self, xs: &Matrix) -> Result<PosteriorBatch> {
        let refs: Vec<&[f32]> = (0..xs.rows).map(|r| xs.row(r)).collect();
        let posts = self.posterior(&refs)?;
        let l = self.meta().latent_dim;
        let mut mu = Vec::with_capacity(xs.rows * l);
        let mut sigma = Vec::with_capacity(xs.rows * l);
        for (m, s) in posts {
            if m.len() != l || s.len() != l {
                bail!("posterior returned {}/{} values, want {l}", m.len(), s.len());
            }
            mu.extend_from_slice(&m);
            sigma.extend_from_slice(&s);
        }
        Ok(PosteriorBatch {
            mu: Matrix::new(xs.rows, l, mu),
            sigma: Matrix::new(xs.rows, l, sigma),
        })
    }

    /// Generative net over a whole `[B, latent_dim]` batch in one
    /// dispatch; same row-independence contract as
    /// [`Backend::encode_batch`].
    fn decode_batch(&self, ys: &Matrix) -> Result<Vec<PixelParams>> {
        let refs: Vec<&[f32]> = (0..ys.rows).map(|r| ys.row(r)).collect();
        self.likelihood(&refs)
    }
}

/// Near-even contiguous split of `rows` into at most `parts` non-empty
/// row ranges — the shared [`crate::util::chunk_ranges`] partition, so
/// batch sharding and chunked coding agree on one split semantics.
pub(crate) fn row_shards(rows: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    crate::util::chunk_ranges(rows, parts)
}

pub(crate) fn shard_matrix(m: &Matrix, r: &std::ops::Range<usize>) -> Matrix {
    Matrix::new(
        r.len(),
        m.cols,
        m.data[r.start * m.cols..r.end * m.cols].to_vec(),
    )
}

/// [`Backend::encode_batch`] with the batch's rows fanned out over
/// `workers` scoped threads (ISSUE 5's serving-side fan-out primitive).
///
/// Bitwise identical to the single call for any worker count: the
/// batched-call contract says row `r` depends only on input row `r`, so
/// splitting rows into contiguous shards and stitching the outputs back
/// in shard order changes nothing (pinned by
/// `sharded_batches_match_unsharded_bitwise`). Requires a `Sync` backend;
/// PJRT backends stay on the single-threaded worker instead.
pub fn encode_batch_sharded<B: Backend + Sync + ?Sized>(
    backend: &B,
    xs: &Matrix,
    workers: usize,
) -> Result<PosteriorBatch> {
    let shards = row_shards(xs.rows, workers);
    if shards.len() <= 1 {
        return backend.encode_batch(xs);
    }
    let parts: Vec<PosteriorBatch> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .iter()
            .map(|r| {
                let sub = shard_matrix(xs, r);
                scope.spawn(move || backend.encode_batch(&sub))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("encode shard panicked"))
            .collect::<Result<_>>()
    })?;
    let l = backend.meta().latent_dim;
    let mut mu = Vec::with_capacity(xs.rows * l);
    let mut sigma = Vec::with_capacity(xs.rows * l);
    for p in parts {
        mu.extend_from_slice(&p.mu.data);
        sigma.extend_from_slice(&p.sigma.data);
    }
    Ok(PosteriorBatch {
        mu: Matrix::new(xs.rows, l, mu),
        sigma: Matrix::new(xs.rows, l, sigma),
    })
}

/// [`Backend::decode_batch`] with rows fanned out over `workers` scoped
/// threads — same contract and bit-identity argument as
/// [`encode_batch_sharded`].
pub fn decode_batch_sharded<B: Backend + Sync + ?Sized>(
    backend: &B,
    ys: &Matrix,
    workers: usize,
) -> Result<Vec<PixelParams>> {
    let shards = row_shards(ys.rows, workers);
    if shards.len() <= 1 {
        return backend.decode_batch(ys);
    }
    let parts: Vec<Vec<PixelParams>> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .iter()
            .map(|r| {
                let sub = shard_matrix(ys, r);
                scope.spawn(move || backend.decode_batch(&sub))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("decode shard panicked"))
            .collect::<Result<_>>()
    })?;
    Ok(parts.into_iter().flatten().collect())
}

#[cfg(test)]
mod shard_tests {
    use super::*;
    use crate::util::rng::Rng;

    fn backend(likelihood: Likelihood) -> vae::NativeVae {
        vae::NativeVae::random(
            ModelMeta {
                name: "shard".into(),
                pixels: 24,
                latent_dim: 5,
                hidden: 9,
                likelihood,
                test_elbo_bpd: f64::NAN,
            },
            0xFA40,
        )
    }

    /// Row-sharded dispatch must equal the single batched call bitwise
    /// for every worker count — the contract the coordinator's sync
    /// fan-out rests on.
    #[test]
    fn sharded_batches_match_unsharded_bitwise() {
        let mut rng = Rng::new(0x54A2);
        for likelihood in [Likelihood::Bernoulli, Likelihood::BetaBinomial] {
            let v = backend(likelihood);
            for rows in [1usize, 2, 5, 16] {
                let xs = Matrix::new(
                    rows,
                    24,
                    (0..rows * 24).map(|_| (rng.f64() < 0.4) as u32 as f32).collect(),
                );
                let ys = Matrix::new(
                    rows,
                    5,
                    (0..rows * 5).map(|_| rng.normal() as f32).collect(),
                );
                let want_post = v.encode_batch(&xs).unwrap();
                let want_par = v.decode_batch(&ys).unwrap();
                for workers in [1usize, 2, 3, 7, 32] {
                    let post = encode_batch_sharded(&v, &xs, workers).unwrap();
                    assert_eq!(post, want_post, "{likelihood:?} rows={rows} w={workers}");
                    let par = decode_batch_sharded(&v, &ys, workers).unwrap();
                    assert_eq!(par.len(), want_par.len());
                    for (a, b) in par.iter().zip(want_par.iter()) {
                        match (a, b) {
                            (PixelParams::Bernoulli(x), PixelParams::Bernoulli(y)) => {
                                assert_eq!(x, y)
                            }
                            (
                                PixelParams::BetaBinomialAb { alpha: a1, beta: b1 },
                                PixelParams::BetaBinomialAb { alpha: a2, beta: b2 },
                            ) => {
                                assert_eq!(a1, a2);
                                assert_eq!(b1, b2);
                            }
                            other => panic!("param kinds diverged: {other:?}"),
                        }
                    }
                }
            }
        }
    }
}
