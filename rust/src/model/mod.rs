//! Model layer: metadata, weight loading, and the [`Backend`] abstraction
//! over *where* the VAE's networks run.
//!
//! Two interchangeable backends produce distribution parameters for the
//! BB-ANS codec:
//!
//! * [`vae::NativeVae`] — pure-Rust forward pass from the `.bbwt` weights
//!   (tests, cross-checks, artifact-free operation);
//! * [`vae::PjrtVae`] — executes the AOT-lowered HLO artifacts through
//!   [`crate::runtime::Engine`] (the production path; Pallas kernels
//!   inlined in the graphs).
//!
//! A compressed stream records which backend produced it: floating-point
//! results differ across backends at the ULP level, and BB-ANS requires
//! the decoder to reproduce the encoder's quantized distributions exactly.

pub mod hierarchy;
pub mod tensor;
pub mod vae;
pub mod weights;

use anyhow::{bail, Result};

use self::tensor::Matrix;

/// Which per-pixel likelihood family the generative net parameterizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Likelihood {
    /// Binarized MNIST: one probability per pixel.
    Bernoulli,
    /// Full MNIST: two positive shape parameters per pixel.
    BetaBinomial,
}

impl Likelihood {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "bernoulli" => Ok(Self::Bernoulli),
            "beta_binomial" => Ok(Self::BetaBinomial),
            other => anyhow::bail!("unknown likelihood '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Bernoulli => "bernoulli",
            Self::BetaBinomial => "beta_binomial",
        }
    }

    /// Wire tag used by container headers (`BBC3` records the likelihood
    /// family so self-describing hierarchical models rebuild exactly).
    pub fn tag(&self) -> u8 {
        match self {
            Self::Bernoulli => 0,
            Self::BetaBinomial => 1,
        }
    }

    /// Inverse of [`Likelihood::tag`].
    pub fn from_tag(tag: u8) -> Result<Self> {
        match tag {
            0 => Ok(Self::Bernoulli),
            1 => Ok(Self::BetaBinomial),
            other => bail!("unknown likelihood tag {other}"),
        }
    }
}

/// Static description of one trained model.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub pixels: usize,
    pub latent_dim: usize,
    pub hidden: usize,
    pub likelihood: Likelihood,
    /// Test-set negative ELBO in bits/dim as measured at training time
    /// (the compression-rate target; paper Table 2).
    pub test_elbo_bpd: f64,
}

/// Per-image likelihood parameters handed to the pixel codecs.
#[derive(Debug, Clone)]
pub enum PixelParams {
    /// `pixels` Bernoulli probabilities.
    Bernoulli(Vec<f32>),
    /// Analytic beta-binomial parameters (native backend).
    BetaBinomialAb { alpha: Vec<f32>, beta: Vec<f32> },
    /// Precomputed PMF table, row-major `[pixels, 256]` (PJRT backend —
    /// the table is produced inside the decoder graph by the L1 kernel).
    BetaBinomialTable(Vec<f32>),
}

/// Posterior parameters for a batch of images: row `r` of `mu`/`sigma`
/// belongs to input row `r`. Produced by [`Backend::encode_batch`]; the
/// matrices keep the whole chunk contiguous so the BB-ANS dataset loops
/// hand rows to the coder without per-image allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct PosteriorBatch {
    /// `[B, latent_dim]` posterior means.
    pub mu: Matrix,
    /// `[B, latent_dim]` posterior standard deviations.
    pub sigma: Matrix,
}

impl PosteriorBatch {
    /// Number of images in the batch.
    pub fn len(&self) -> usize {
        self.mu.rows
    }

    pub fn is_empty(&self) -> bool {
        self.mu.rows == 0
    }

    /// `(mu, sigma)` of image `r`.
    #[inline]
    pub fn row(&self, r: usize) -> (&[f32], &[f32]) {
        (self.mu.row(r), self.sigma.row(r))
    }

    /// Split into the per-image representation of [`Backend::posterior`].
    pub fn into_rows(self) -> Vec<(Vec<f32>, Vec<f32>)> {
        (0..self.len())
            .map(|r| (self.mu.row(r).to_vec(), self.sigma.row(r).to_vec()))
            .collect()
    }
}

/// Where the VAE networks execute. Batched calls take several images /
/// latents at once so callers (the coordinator) can amortize dispatch.
///
/// Deliberately **not** `Send`/`Sync`: the `xla` crate's PJRT handles are
/// reference-counted thread-local objects. The coordinator therefore owns
/// each backend inside a dedicated model-worker thread and talks to it via
/// channels (see `coordinator::batcher`), which is the batching
/// architecture we want anyway.
pub trait Backend {
    fn meta(&self) -> &ModelMeta;

    /// Stable identifier recorded in compressed containers; decode must
    /// use a backend with the same id (it encodes everything that affects
    /// bit-exactness of the distribution parameters, e.g. the PJRT batch
    /// variant).
    fn backend_id(&self) -> String;

    /// Recognition net: scaled images (len `pixels` each, values in [0,1])
    /// → (mu, sigma) per image.
    fn posterior(&self, xs: &[&[f32]]) -> Result<Vec<(Vec<f32>, Vec<f32>)>>;

    /// Generative net: latents (len `latent_dim` each) → per-pixel
    /// likelihood parameters per latent.
    fn likelihood(&self, ys: &[&[f32]]) -> Result<Vec<PixelParams>>;

    /// Recognition net over a whole `[B, pixels]` batch in one dispatch.
    ///
    /// The default routes through [`Backend::posterior`]; backends with a
    /// native batched path (the packed-GEMM `NativeVae`) override it.
    /// Implementations must be row-independent and batch-size-invariant:
    /// row `r` of the result depends only on row `r` of `xs`, bit-for-bit
    /// — the BB-ANS pipeline batches freely on that guarantee.
    fn encode_batch(&self, xs: &Matrix) -> Result<PosteriorBatch> {
        let refs: Vec<&[f32]> = (0..xs.rows).map(|r| xs.row(r)).collect();
        let posts = self.posterior(&refs)?;
        let l = self.meta().latent_dim;
        let mut mu = Vec::with_capacity(xs.rows * l);
        let mut sigma = Vec::with_capacity(xs.rows * l);
        for (m, s) in posts {
            if m.len() != l || s.len() != l {
                bail!("posterior returned {}/{} values, want {l}", m.len(), s.len());
            }
            mu.extend_from_slice(&m);
            sigma.extend_from_slice(&s);
        }
        Ok(PosteriorBatch {
            mu: Matrix::new(xs.rows, l, mu),
            sigma: Matrix::new(xs.rows, l, sigma),
        })
    }

    /// Generative net over a whole `[B, latent_dim]` batch in one
    /// dispatch; same row-independence contract as
    /// [`Backend::encode_batch`].
    fn decode_batch(&self, ys: &Matrix) -> Result<Vec<PixelParams>> {
        let refs: Vec<&[f32]> = (0..ys.rows).map(|r| ys.row(r)).collect();
        self.likelihood(&refs)
    }
}
