//! Model layer: metadata, weight loading, and the [`Backend`] abstraction
//! over *where* the VAE's networks run.
//!
//! Two interchangeable backends produce distribution parameters for the
//! BB-ANS codec:
//!
//! * [`vae::NativeVae`] — pure-Rust forward pass from the `.bbwt` weights
//!   (tests, cross-checks, artifact-free operation);
//! * [`vae::PjrtVae`] — executes the AOT-lowered HLO artifacts through
//!   [`crate::runtime::Engine`] (the production path; Pallas kernels
//!   inlined in the graphs).
//!
//! A compressed stream records which backend produced it: floating-point
//! results differ across backends at the ULP level, and BB-ANS requires
//! the decoder to reproduce the encoder's quantized distributions exactly.

pub mod tensor;
pub mod vae;
pub mod weights;

use anyhow::Result;

/// Which per-pixel likelihood family the generative net parameterizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Likelihood {
    /// Binarized MNIST: one probability per pixel.
    Bernoulli,
    /// Full MNIST: two positive shape parameters per pixel.
    BetaBinomial,
}

impl Likelihood {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "bernoulli" => Ok(Self::Bernoulli),
            "beta_binomial" => Ok(Self::BetaBinomial),
            other => anyhow::bail!("unknown likelihood '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Bernoulli => "bernoulli",
            Self::BetaBinomial => "beta_binomial",
        }
    }
}

/// Static description of one trained model.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub pixels: usize,
    pub latent_dim: usize,
    pub hidden: usize,
    pub likelihood: Likelihood,
    /// Test-set negative ELBO in bits/dim as measured at training time
    /// (the compression-rate target; paper Table 2).
    pub test_elbo_bpd: f64,
}

/// Per-image likelihood parameters handed to the pixel codecs.
#[derive(Debug, Clone)]
pub enum PixelParams {
    /// `pixels` Bernoulli probabilities.
    Bernoulli(Vec<f32>),
    /// Analytic beta-binomial parameters (native backend).
    BetaBinomialAb { alpha: Vec<f32>, beta: Vec<f32> },
    /// Precomputed PMF table, row-major `[pixels, 256]` (PJRT backend —
    /// the table is produced inside the decoder graph by the L1 kernel).
    BetaBinomialTable(Vec<f32>),
}

/// Where the VAE networks execute. Batched calls take several images /
/// latents at once so callers (the coordinator) can amortize dispatch.
///
/// Deliberately **not** `Send`/`Sync`: the `xla` crate's PJRT handles are
/// reference-counted thread-local objects. The coordinator therefore owns
/// each backend inside a dedicated model-worker thread and talks to it via
/// channels (see `coordinator::batcher`), which is the batching
/// architecture we want anyway.
pub trait Backend {
    fn meta(&self) -> &ModelMeta;

    /// Stable identifier recorded in compressed containers; decode must
    /// use a backend with the same id (it encodes everything that affects
    /// bit-exactness of the distribution parameters, e.g. the PJRT batch
    /// variant).
    fn backend_id(&self) -> String;

    /// Recognition net: scaled images (len `pixels` each, values in [0,1])
    /// → (mu, sigma) per image.
    fn posterior(&self, xs: &[&[f32]]) -> Result<Vec<(Vec<f32>, Vec<f32>)>>;

    /// Generative net: latents (len `latent_dim` each) → per-pixel
    /// likelihood parameters per latent.
    fn likelihood(&self, ys: &[&[f32]]) -> Result<Vec<PixelParams>>;
}
