//! The two [`Backend`] implementations: native Rust forward pass and the
//! PJRT artifact executor.

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use super::tensor::{
    dense, dense_packed, relu_inplace, sigmoid_inplace, softplus_inplace, Epilogue, Matrix,
    PackedMatrix,
};
use super::weights::Weights;
use super::{Backend, Likelihood, ModelMeta, PixelParams, PosteriorBatch};
use crate::runtime::{Engine, Tensor};

/// Matches `python/compile/model.py::LOGVAR_MIN/MAX`. Shared with the
/// hierarchical backend so every Gaussian head in the system uses one
/// sigma transform.
pub(crate) const LOGVAR_MIN: f32 = -10.0;
pub(crate) const LOGVAR_MAX: f32 = 10.0;
/// Matches `python/compile/model.py::AB_EPS`.
pub(crate) const AB_EPS: f32 = 1e-3;

/// Load a [`NativeVae`] for `model` from the artifact bundle (shared by
/// the CLI, examples, benches and tests).
pub fn load_native(artifact_dir: impl AsRef<std::path::Path>, model: &str) -> Result<NativeVae> {
    let dir = artifact_dir.as_ref();
    let config = crate::runtime::load_config(dir)?;
    let m = config
        .get("models")
        .and_then(|ms| ms.get(model))
        .ok_or_else(|| anyhow!("model '{model}' not in config"))?;
    let meta = ModelMeta {
        name: model.to_string(),
        pixels: config
            .req("pixels")
            .map_err(|e| anyhow!("{e}"))?
            .as_usize()
            .unwrap(),
        latent_dim: m
            .req("latent_dim")
            .map_err(|e| anyhow!("{e}"))?
            .as_usize()
            .unwrap(),
        hidden: m
            .req("hidden")
            .map_err(|e| anyhow!("{e}"))?
            .as_usize()
            .unwrap(),
        likelihood: Likelihood::parse(
            m.req("likelihood")
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .unwrap(),
        )?,
        test_elbo_bpd: m
            .get("test_elbo_bpd")
            .and_then(|v| v.as_f64())
            .unwrap_or(f64::NAN),
    };
    let weights = dir.join(
        m.req("weights")
            .map_err(|e| anyhow!("{e}"))?
            .as_str()
            .unwrap(),
    );
    NativeVae::load(weights, meta)
}

// ---------------------------------------------------------------- native

/// Weight panels for the packed GEMM, built once at model load
/// (`Matrix::packed`) — the per-call work is pure microkernel.
struct PackedWeights {
    enc_w1: PackedMatrix,
    enc_w_mu: PackedMatrix,
    enc_w_lv: PackedMatrix,
    dec_w1: PackedMatrix,
    dec_w_out: PackedMatrix,
}

/// Pure-Rust VAE forward pass from `.bbwt` weights.
pub struct NativeVae {
    meta: ModelMeta,
    enc_w1: Matrix,
    enc_b1: Vec<f32>,
    enc_w_mu: Matrix,
    enc_b_mu: Vec<f32>,
    enc_w_lv: Matrix,
    enc_b_lv: Vec<f32>,
    dec_w1: Matrix,
    dec_b1: Vec<f32>,
    dec_w_out: Matrix,
    dec_b_out: Vec<f32>,
    packed: PackedWeights,
    /// Route the forward pass through the scalar reference kernel instead
    /// of the packed GEMM (validation/bench baseline; bit-identical).
    reference_gemm: bool,
}

impl NativeVae {
    fn finish(
        meta: ModelMeta,
        enc_w1: Matrix,
        enc_b1: Vec<f32>,
        enc_w_mu: Matrix,
        enc_b_mu: Vec<f32>,
        enc_w_lv: Matrix,
        enc_b_lv: Vec<f32>,
        dec_w1: Matrix,
        dec_b1: Vec<f32>,
        dec_w_out: Matrix,
        dec_b_out: Vec<f32>,
    ) -> Result<Self> {
        // Shape sanity.
        let (p, l, h) = (meta.pixels, meta.latent_dim, meta.hidden);
        let heads = match meta.likelihood {
            Likelihood::Bernoulli => 1,
            Likelihood::BetaBinomial => 2,
        };
        if enc_w1.rows != p || enc_w1.cols != h {
            bail!("enc_w1 shape {:?}", (enc_w1.rows, enc_w1.cols));
        }
        if enc_w_mu.cols != l || enc_w_lv.cols != l {
            bail!("latent head shapes");
        }
        if dec_w1.rows != l || dec_w_out.cols != p * heads {
            bail!("decoder shapes");
        }
        let packed = PackedWeights {
            enc_w1: enc_w1.packed(),
            enc_w_mu: enc_w_mu.packed(),
            enc_w_lv: enc_w_lv.packed(),
            dec_w1: dec_w1.packed(),
            dec_w_out: dec_w_out.packed(),
        };
        Ok(Self {
            meta,
            enc_w1,
            enc_b1,
            enc_w_mu,
            enc_b_mu,
            enc_w_lv,
            enc_b_lv,
            dec_w1,
            dec_b1,
            dec_w_out,
            dec_b_out,
            packed,
            reference_gemm: false,
        })
    }

    pub fn from_weights(weights: &Weights, meta: ModelMeta) -> Result<Self> {
        Self::finish(
            meta,
            weights.matrix("enc_w1")?,
            weights.vector("enc_b1")?,
            weights.matrix("enc_w_mu")?,
            weights.vector("enc_b_mu")?,
            weights.matrix("enc_w_lv")?,
            weights.vector("enc_b_lv")?,
            weights.matrix("dec_w1")?,
            weights.vector("dec_b1")?,
            weights.matrix("dec_w_out")?,
            weights.vector("dec_b_out")?,
        )
    }

    /// Use the scalar reference kernel ([`dense`]) instead of the packed
    /// GEMM. Bit-identical by the tensor-layer determinism contract — the
    /// golden-container tests and the `model` bench use it as the seed
    /// baseline. The `backend_id` is unchanged because streams encoded by
    /// either path decode under the other.
    pub fn with_reference_gemm(mut self, on: bool) -> Self {
        self.reference_gemm = on;
        self
    }

    pub fn load(path: impl AsRef<std::path::Path>, meta: ModelMeta) -> Result<Self> {
        let w = Weights::load(path)?;
        Self::from_weights(&w, meta)
    }

    /// A deterministic, randomly-initialized model (tests / benches that
    /// must run without trained artifacts).
    pub fn random(meta: ModelMeta, seed: u64) -> Self {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let heads = match meta.likelihood {
            Likelihood::Bernoulli => 1,
            Likelihood::BetaBinomial => 2,
        };
        let (p, h, l) = (meta.pixels, meta.hidden, meta.latent_dim);
        let mut mat = |r: usize, c: usize, scale: f64| {
            Matrix::new(
                r,
                c,
                (0..r * c)
                    .map(|_| (rng.normal() * scale) as f32)
                    .collect(),
            )
        };
        Self::finish(
            meta,
            mat(p, h, 0.05),
            vec![0.0; h],
            mat(h, l, 0.1),
            vec![0.0; l],
            mat(h, l, 0.05),
            vec![-1.0; l],
            mat(l, h, 0.1),
            vec![0.0; h],
            mat(h, p * heads, 0.05),
            vec![0.0; p * heads],
        )
        .expect("random weights have consistent shapes")
    }

    fn batch_matrix(&self, xs: &[&[f32]], want_cols: usize) -> Result<Matrix> {
        let mut data = Vec::with_capacity(xs.len() * want_cols);
        for x in xs {
            if x.len() != want_cols {
                bail!("input length {} != {want_cols}", x.len());
            }
            data.extend_from_slice(x);
        }
        Ok(Matrix::new(xs.len(), want_cols, data))
    }
}

impl Backend for NativeVae {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn backend_id(&self) -> String {
        "native".to_string()
    }

    /// The GEMM variant this forward pass dispatches to. Diagnostic only:
    /// every variant (and the scalar reference) is bit-identical, so the
    /// container-identity `backend_id` stays "native" regardless.
    fn kernel_id(&self) -> String {
        if self.reference_gemm {
            "reference".to_string()
        } else {
            crate::simd::kernel_name().to_string()
        }
    }

    fn posterior(&self, xs: &[&[f32]]) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
        // Rerouted through the batched path (B = xs.len()); bit-identical
        // to any other batch grouping by the tensor-layer contract.
        let x = self.batch_matrix(xs, self.meta.pixels)?;
        Ok(self.encode_batch(&x)?.into_rows())
    }

    fn likelihood(&self, ys: &[&[f32]]) -> Result<Vec<PixelParams>> {
        let y = self.batch_matrix(ys, self.meta.latent_dim)?;
        self.decode_batch(&y)
    }

    /// Recognition net, one packed-GEMM dispatch for the whole batch with
    /// the ReLU fused into the hidden layer.
    fn encode_batch(&self, xs: &Matrix) -> Result<PosteriorBatch> {
        if xs.cols != self.meta.pixels {
            bail!("input width {} != {}", xs.cols, self.meta.pixels);
        }
        let (mu, mut sigma) = if self.reference_gemm {
            let mut h = dense(xs, &self.enc_w1, &self.enc_b1);
            relu_inplace(&mut h);
            (
                dense(&h, &self.enc_w_mu, &self.enc_b_mu),
                dense(&h, &self.enc_w_lv, &self.enc_b_lv),
            )
        } else {
            let h = dense_packed(xs, &self.packed.enc_w1, &self.enc_b1, Epilogue::Relu);
            (
                dense_packed(&h, &self.packed.enc_w_mu, &self.enc_b_mu, Epilogue::Linear),
                dense_packed(&h, &self.packed.enc_w_lv, &self.enc_b_lv, Epilogue::Linear),
            )
        };
        // Log-variance head → sigma, in f32 exactly as the seed backend.
        for v in &mut sigma.data {
            *v = (0.5 * v.clamp(LOGVAR_MIN, LOGVAR_MAX)).exp();
        }
        Ok(PosteriorBatch { mu, sigma })
    }

    /// Generative net, one packed-GEMM dispatch with the output
    /// nonlinearity (sigmoid/softplus) fused into the final layer.
    fn decode_batch(&self, ys: &Matrix) -> Result<Vec<PixelParams>> {
        if ys.cols != self.meta.latent_dim {
            bail!("latent width {} != {}", ys.cols, self.meta.latent_dim);
        }
        let out_ep = match self.meta.likelihood {
            Likelihood::Bernoulli => Epilogue::Sigmoid,
            Likelihood::BetaBinomial => Epilogue::Softplus,
        };
        let out = if self.reference_gemm {
            let mut h = dense(ys, &self.dec_w1, &self.dec_b1);
            relu_inplace(&mut h);
            let mut o = dense(&h, &self.dec_w_out, &self.dec_b_out);
            match out_ep {
                Epilogue::Sigmoid => sigmoid_inplace(&mut o),
                Epilogue::Softplus => softplus_inplace(&mut o),
                _ => unreachable!(),
            }
            o
        } else {
            let h = dense_packed(ys, &self.packed.dec_w1, &self.dec_b1, Epilogue::Relu);
            dense_packed(&h, &self.packed.dec_w_out, &self.dec_b_out, out_ep)
        };
        match self.meta.likelihood {
            Likelihood::Bernoulli => Ok((0..ys.rows)
                .map(|r| PixelParams::Bernoulli(out.row(r).to_vec()))
                .collect()),
            Likelihood::BetaBinomial => {
                let p = self.meta.pixels;
                Ok((0..ys.rows)
                    .map(|r| {
                        let row = out.row(r);
                        PixelParams::BetaBinomialAb {
                            alpha: row[..p].iter().map(|v| v + AB_EPS).collect(),
                            beta: row[p..].iter().map(|v| v + AB_EPS).collect(),
                        }
                    })
                    .collect())
            }
        }
    }
}

// ----------------------------------------------------------------- pjrt

/// VAE backend executing the AOT artifacts through PJRT.
///
/// **Determinism contract**: every call routes through ONE fixed batch-size
/// variant (`coding_batch`), chunked and zero-padded. Different batch
/// variants are different compiled executables whose f32 results can
/// differ at ULP level; BB-ANS requires the decoder to reproduce the
/// encoder's distribution parameters bit-exactly, and within a fixed
/// executable each output row depends only on its own input row, so
/// padding/co-batching is safe while variant-switching is not. The chosen
/// batch is part of [`Backend::backend_id`] and recorded in containers.
pub struct PjrtVae {
    meta: ModelMeta,
    engine: Arc<Engine>,
    /// (batch_size, encoder artifact, decoder artifact), ascending batch.
    variants: Vec<(usize, String, String)>,
    /// Index into `variants` used for ALL coding-path calls.
    coding_variant: usize,
    backend_id: String,
}

impl PjrtVae {
    /// Build from `model_config.json` (loads + compiles all variants).
    pub fn from_config(
        engine: Arc<Engine>,
        config: &crate::util::json::Json,
        name: &str,
    ) -> Result<Self> {
        let m = config
            .get("models")
            .and_then(|ms| ms.get(name))
            .ok_or_else(|| anyhow!("model '{name}' not in config"))?;
        let meta = ModelMeta {
            name: name.to_string(),
            pixels: config.req("pixels").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap(),
            latent_dim: m.req("latent_dim").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap(),
            hidden: m.req("hidden").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap(),
            likelihood: Likelihood::parse(
                m.req("likelihood").map_err(|e| anyhow!("{e}"))?.as_str().unwrap(),
            )?,
            test_elbo_bpd: m
                .get("test_elbo_bpd")
                .and_then(|v| v.as_f64())
                .unwrap_or(f64::NAN),
        };
        let enc = m.req("encoder_hlo").map_err(|e| anyhow!("{e}"))?;
        let dec = m.req("decoder_hlo").map_err(|e| anyhow!("{e}"))?;
        let mut variants = Vec::new();
        if let (crate::util::json::Json::Obj(eo), crate::util::json::Json::Obj(dobj)) = (enc, dec) {
            for (bs, ef) in eo {
                let b: usize = bs.parse().context("batch size key")?;
                let df = dobj
                    .get(bs)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("decoder variant for batch {b} missing"))?;
                let ef = ef.as_str().ok_or_else(|| anyhow!("encoder file"))?;
                variants.push((b, ef.to_string(), df.to_string()));
            }
        } else {
            bail!("encoder_hlo/decoder_hlo must be objects");
        }
        variants.sort_by_key(|v| v.0);
        if variants.is_empty() {
            bail!("no artifact variants for model '{name}'");
        }
        // Fixed coding variant: the largest batch (best amortization; the
        // coordinator batches cross-stream work up to this size).
        let coding_variant = variants.len() - 1;
        let backend_id = format!("pjrt-b{}", variants[coding_variant].0);
        // Only the coding variant needs compiling.
        engine.load(&variants[coding_variant].1)?;
        engine.load(&variants[coding_variant].2)?;
        Ok(Self {
            meta,
            engine,
            variants,
            coding_variant,
            backend_id,
        })
    }

    /// Switch to a specific batch-size variant (changes the backend id —
    /// streams encoded under a different variant cannot be decoded).
    pub fn with_coding_batch(mut self, batch: usize) -> Result<Self> {
        let idx = self
            .variants
            .iter()
            .position(|(b, _, _)| *b == batch)
            .ok_or_else(|| anyhow!("no artifact variant for batch {batch}"))?;
        self.coding_variant = idx;
        self.backend_id = format!("pjrt-b{batch}");
        self.engine.load(&self.variants[idx].1)?;
        self.engine.load(&self.variants[idx].2)?;
        Ok(self)
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        self.variants.iter().map(|v| v.0).collect()
    }

    pub fn coding_batch(&self) -> usize {
        self.variants[self.coding_variant].0
    }

    /// Run the fixed-variant artifact over `items`, chunking + padding.
    fn run_batched(
        &self,
        items: &[&[f32]],
        item_len: usize,
        pick: impl Fn(&(usize, String, String)) -> &String,
    ) -> Result<Vec<Vec<Tensor>>> {
        let mut outs = Vec::new();
        let mut i = 0;
        while i < items.len() {
            let remaining = items.len() - i;
            let var = &self.variants[self.coding_variant];
            let b = var.0;
            let take = remaining.min(b);
            let mut data = Vec::with_capacity(b * item_len);
            for item in &items[i..i + take] {
                if item.len() != item_len {
                    bail!("item length {} != {item_len}", item.len());
                }
                data.extend_from_slice(item);
            }
            data.resize(b * item_len, 0.0); // zero-pad
            let t = Tensor::new(vec![b, item_len], data);
            let result = self.engine.run(pick(var), &[t])?;
            outs.push((take, result));
            i += take;
        }
        // Flatten: per original item, slice the padded outputs.
        let mut per_item = Vec::with_capacity(items.len());
        for (take, tensors) in outs {
            for r in 0..take {
                per_item.push(
                    tensors
                        .iter()
                        .map(|t| {
                            let stride: usize = t.dims[1..].iter().product();
                            Tensor::new(
                                t.dims[1..].to_vec(),
                                t.data[r * stride..(r + 1) * stride].to_vec(),
                            )
                        })
                        .collect::<Vec<_>>(),
                );
            }
        }
        Ok(per_item)
    }
}

impl Backend for PjrtVae {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn backend_id(&self) -> String {
        self.backend_id.clone()
    }

    fn kernel_id(&self) -> String {
        "pjrt".to_string()
    }

    fn posterior(&self, xs: &[&[f32]]) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
        let per_item = self.run_batched(xs, self.meta.pixels, |v| &v.1)?;
        per_item
            .into_iter()
            .map(|ts| {
                if ts.len() != 2 {
                    bail!("encoder must output (mu, sigma), got {} tensors", ts.len());
                }
                Ok((ts[0].data.clone(), ts[1].data.clone()))
            })
            .collect()
    }

    fn likelihood(&self, ys: &[&[f32]]) -> Result<Vec<PixelParams>> {
        let per_item = self.run_batched(ys, self.meta.latent_dim, |v| &v.2)?;
        per_item
            .into_iter()
            .map(|ts| {
                let t = ts
                    .first()
                    .ok_or_else(|| anyhow!("decoder produced no output"))?;
                match self.meta.likelihood {
                    Likelihood::Bernoulli => Ok(PixelParams::Bernoulli(t.data.clone())),
                    Likelihood::BetaBinomial => {
                        Ok(PixelParams::BetaBinomialTable(t.data.clone()))
                    }
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(likelihood: Likelihood) -> ModelMeta {
        ModelMeta {
            name: "test".into(),
            pixels: 16,
            latent_dim: 4,
            hidden: 8,
            likelihood,
            test_elbo_bpd: f64::NAN,
        }
    }

    #[test]
    fn native_posterior_shapes_and_ranges() {
        let v = NativeVae::random(meta(Likelihood::Bernoulli), 1);
        let x = vec![0.5f32; 16];
        let out = v.posterior(&[&x, &x]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].0.len(), 4);
        assert_eq!(out[0].1.len(), 4);
        assert!(out[0].1.iter().all(|&s| s > 0.0));
        // Deterministic.
        let out2 = v.posterior(&[&x]).unwrap();
        assert_eq!(out[0], out2[0]);
    }

    #[test]
    fn native_likelihood_bernoulli_in_unit_interval() {
        let v = NativeVae::random(meta(Likelihood::Bernoulli), 2);
        let y = vec![0.3f32; 4];
        match &v.likelihood(&[&y]).unwrap()[0] {
            PixelParams::Bernoulli(p) => {
                assert_eq!(p.len(), 16);
                assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
            }
            other => panic!("wrong params {other:?}"),
        }
    }

    #[test]
    fn native_likelihood_beta_binomial_positive() {
        let v = NativeVae::random(meta(Likelihood::BetaBinomial), 3);
        let y = vec![-0.5f32; 4];
        match &v.likelihood(&[&y]).unwrap()[0] {
            PixelParams::BetaBinomialAb { alpha, beta } => {
                assert_eq!(alpha.len(), 16);
                assert_eq!(beta.len(), 16);
                assert!(alpha.iter().all(|&a| a > 0.0));
                assert!(beta.iter().all(|&b| b > 0.0));
            }
            other => panic!("wrong params {other:?}"),
        }
    }

    /// The packed forward must equal the scalar reference forward
    /// bit-for-bit, for both likelihood heads — the backend-level face of
    /// the tensor determinism contract.
    #[test]
    fn packed_forward_matches_reference_bitwise() {
        for (seed, lk) in [(21u64, Likelihood::Bernoulli), (22, Likelihood::BetaBinomial)] {
            let fast = NativeVae::random(meta(lk), seed);
            let slow = NativeVae::random(meta(lk), seed).with_reference_gemm(true);
            let mut rng = crate::util::rng::Rng::new(seed ^ 0xff);
            let xs: Vec<Vec<f32>> = (0..5)
                .map(|_| (0..16).map(|_| (rng.f64() * 0.9) as f32).collect())
                .collect();
            let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            assert_eq!(fast.posterior(&refs).unwrap(), slow.posterior(&refs).unwrap());
            let ys: Vec<Vec<f32>> = (0..5)
                .map(|_| (0..4).map(|_| rng.normal() as f32).collect())
                .collect();
            let yrefs: Vec<&[f32]> = ys.iter().map(|v| v.as_slice()).collect();
            let (a, b) = (fast.likelihood(&yrefs).unwrap(), slow.likelihood(&yrefs).unwrap());
            for (pa, pb) in a.iter().zip(b.iter()) {
                match (pa, pb) {
                    (PixelParams::Bernoulli(x), PixelParams::Bernoulli(y)) => assert_eq!(x, y),
                    (
                        PixelParams::BetaBinomialAb { alpha: a1, beta: b1 },
                        PixelParams::BetaBinomialAb { alpha: a2, beta: b2 },
                    ) => {
                        assert_eq!(a1, a2);
                        assert_eq!(b1, b2);
                    }
                    other => panic!("param kinds diverged: {other:?}"),
                }
            }
        }
    }

    /// `encode_batch` rows must not depend on batch grouping: B images in
    /// one call equal B one-image calls, bitwise.
    #[test]
    fn encode_batch_invariant_to_grouping() {
        let v = NativeVae::random(meta(Likelihood::Bernoulli), 23);
        let mut rng = crate::util::rng::Rng::new(99);
        let xs: Vec<Vec<f32>> = (0..7)
            .map(|_| (0..16).map(|_| (rng.f64() < 0.4) as u32 as f32).collect())
            .collect();
        let flat: Vec<f32> = xs.iter().flatten().copied().collect();
        let batch = v.encode_batch(&Matrix::new(7, 16, flat)).unwrap();
        for (r, x) in xs.iter().enumerate() {
            let one = v
                .encode_batch(&Matrix::new(1, 16, x.clone()))
                .unwrap();
            let (mu, sigma) = batch.row(r);
            assert_eq!(one.mu.row(0), mu, "mu row {r}");
            assert_eq!(one.sigma.row(0), sigma, "sigma row {r}");
        }
    }

    #[test]
    fn native_rejects_bad_input_len() {
        let v = NativeVae::random(meta(Likelihood::Bernoulli), 4);
        let x = vec![0.0f32; 15];
        assert!(v.posterior(&[&x]).is_err());
    }

    #[test]
    fn weights_roundtrip_through_bbwt() {
        use crate::model::weights::{write_bbwt, TensorData, Weights};
        use std::collections::BTreeMap;
        let v = NativeVae::random(meta(Likelihood::Bernoulli), 5);
        let mut m = BTreeMap::new();
        let mut put2 = |name: &str, mat: &Matrix| {
            m.insert(
                name.to_string(),
                TensorData {
                    dims: vec![mat.rows, mat.cols],
                    data: mat.data.clone(),
                },
            );
        };
        put2("enc_w1", &v.enc_w1);
        put2("enc_w_mu", &v.enc_w_mu);
        put2("enc_w_lv", &v.enc_w_lv);
        put2("dec_w1", &v.dec_w1);
        put2("dec_w_out", &v.dec_w_out);
        let mut put1 = |name: &str, vec: &Vec<f32>| {
            m.insert(
                name.to_string(),
                TensorData {
                    dims: vec![vec.len()],
                    data: vec.clone(),
                },
            );
        };
        put1("enc_b1", &v.enc_b1);
        put1("enc_b_mu", &v.enc_b_mu);
        put1("enc_b_lv", &v.enc_b_lv);
        put1("dec_b1", &v.dec_b1);
        put1("dec_b_out", &v.dec_b_out);
        let bytes = write_bbwt(&m);
        let w = Weights::parse(&bytes).unwrap();
        let v2 = NativeVae::from_weights(&w, meta(Likelihood::Bernoulli)).unwrap();
        let x = vec![0.7f32; 16];
        assert_eq!(v.posterior(&[&x]).unwrap(), v2.posterior(&[&x]).unwrap());
    }
}
