//! BBWT weight-file parser (written by `python/compile/aot.py::write_bbwt`).
//!
//! Layout (little-endian): magic `b"BBWT"`, u32 version, u32 tensor count,
//! then per tensor: u16 name_len, name bytes (utf-8), u8 ndim,
//! u32 dims..., f32 data.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone)]
pub struct TensorData {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct Weights {
    pub tensors: BTreeMap<String, TensorData>,
}

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("BBWT truncated at byte {} (need {n} more)", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
}

impl Weights {
    pub fn parse(bytes: &[u8]) -> Result<Self> {
        let mut c = Cursor { b: bytes, pos: 0 };
        let magic = c.take(4)?;
        if magic != b"BBWT" {
            bail!("bad BBWT magic {magic:?}");
        }
        let version = c.u32()?;
        if version != 1 {
            bail!("unsupported BBWT version {version}");
        }
        let count = c.u32()? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name_len = c.u16()? as usize;
            let name = std::str::from_utf8(c.take(name_len)?)
                .context("tensor name utf8")?
                .to_string();
            let ndim = c.u8()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(c.u32()? as usize);
            }
            let n: usize = dims.iter().product();
            let raw = c.take(4 * n)?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|ch| f32::from_le_bytes(ch.try_into().unwrap()))
                .collect();
            tensors.insert(name, TensorData { dims, data });
        }
        if c.pos != bytes.len() {
            bail!("BBWT trailing garbage: {} bytes", bytes.len() - c.pos);
        }
        Ok(Self { tensors })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading weights {}", path.as_ref().display()))?;
        Self::parse(&bytes)
    }

    pub fn get(&self, name: &str) -> Result<&TensorData> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor '{name}'"))
    }

    /// Fetch as a matrix ([K, N] 2-D tensor).
    pub fn matrix(&self, name: &str) -> Result<crate::model::tensor::Matrix> {
        let t = self.get(name)?;
        if t.dims.len() != 2 {
            bail!("tensor '{name}' is not 2-D: {:?}", t.dims);
        }
        Ok(crate::model::tensor::Matrix::new(
            t.dims[0],
            t.dims[1],
            t.data.clone(),
        ))
    }

    /// Fetch as a vector (1-D tensor).
    pub fn vector(&self, name: &str) -> Result<Vec<f32>> {
        let t = self.get(name)?;
        if t.dims.len() != 1 {
            bail!("tensor '{name}' is not 1-D: {:?}", t.dims);
        }
        Ok(t.data.clone())
    }
}

/// Serialize (used by tests to fabricate weight files).
pub fn write_bbwt(tensors: &BTreeMap<String, TensorData>) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(b"BBWT");
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.push(t.dims.len() as u8);
        for &d in &t.dims {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for &v in &t.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BTreeMap<String, TensorData> {
        let mut m = BTreeMap::new();
        m.insert(
            "w1".to_string(),
            TensorData {
                dims: vec![2, 3],
                data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            },
        );
        m.insert(
            "b1".to_string(),
            TensorData {
                dims: vec![3],
                data: vec![0.1, 0.2, 0.3],
            },
        );
        m
    }

    #[test]
    fn roundtrip() {
        let bytes = write_bbwt(&sample());
        let w = Weights::parse(&bytes).unwrap();
        assert_eq!(w.tensors.len(), 2);
        assert_eq!(w.get("w1").unwrap().dims, vec![2, 3]);
        assert_eq!(w.vector("b1").unwrap(), vec![0.1, 0.2, 0.3]);
        let m = w.matrix("w1").unwrap();
        assert_eq!(m.rows, 2);
        assert_eq!(m.cols, 3);
    }

    #[test]
    fn rejects_corruption() {
        let bytes = write_bbwt(&sample());
        assert!(Weights::parse(&bytes[..bytes.len() - 2]).is_err()); // truncated
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Weights::parse(&bad).is_err()); // bad magic
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(Weights::parse(&extra).is_err()); // trailing garbage
    }

    #[test]
    fn wrong_rank_access_errors() {
        let bytes = write_bbwt(&sample());
        let w = Weights::parse(&bytes).unwrap();
        assert!(w.matrix("b1").is_err());
        assert!(w.vector("w1").is_err());
        assert!(w.get("nope").is_err());
    }
}
