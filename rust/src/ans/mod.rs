//! Asymmetric numeral systems (rANS) — the stack-like entropy coder at the
//! heart of BB-ANS (Duda 2009; paper §2.1).
//!
//! The coder state is a `u64` head plus a stream of `u32` words. The head
//! keeps the invariant `x ∈ [2³², 2⁶⁴)` between operations (except for a
//! freshly-initialized empty coder, whose head starts at the lower bound).
//!
//! Encoding a symbol with quantized probability `freq / 2^prec` and
//! cumulative start `start`:
//!
//! ```text
//! while x >= ((freq as u64) << (64 - prec)) { emit low 32 bits; x >>= 32 }
//! x = (x / freq) << prec | (x % freq + start)
//! ```
//!
//! Decoding pops `cf = x & (2^prec - 1)`, the caller maps `cf` to a symbol
//! interval `(start, freq)`, and the state is restored with
//! `x = freq * (x >> prec) + cf - start`, refilling 32-bit words while
//! `x < 2³²`. Decode is the exact inverse of encode — the property BB-ANS
//! exploits to use the coder as an *invertible sampler* (paper §2.1).
//!
//! Because BB-ANS treats decode-on-an-empty-stack as "sampling with clean
//! bits", [`Ans::pop_cf`] transparently draws pseudo-random words from a
//! seeded [`Rng`] when the stream runs dry, and counts how many were used
//! ([`Ans::clean_bits_used`] reproduces the paper's "~400 bits to start the
//! chain" measurement).

pub mod arith;
pub mod coder;
pub mod interleaved;
pub mod prepared;

pub use coder::EntropyCoder;
pub use interleaved::Interval;
pub use prepared::{PreparedInterval, SymbolTable};

use crate::util::rng::Rng;

/// Lower bound of the normalized head: 2³².
pub const RANS_L: u64 = 1 << 32;

/// Maximum precision (bits) for quantized distributions.
pub const MAX_PREC: u32 = 32;

/// Stack-like rANS coder.
#[derive(Debug, Clone)]
pub struct Ans {
    /// Head state; invariant `head ∈ [RANS_L, 2^64)`.
    head: u64,
    /// Stream of renormalized words; the *top* of the stack is the end.
    stream: Vec<u32>,
    /// Source of "clean bits" when popping from an empty stream.
    clean: Rng,
    /// Number of 32-bit words drawn from `clean`.
    clean_words_used: u64,
}

impl Ans {
    /// A fresh, empty coder. `seed` drives the clean-bit supply used when
    /// more information is popped than was pushed (bits-back seeding).
    pub fn new(seed: u64) -> Self {
        Self {
            head: RANS_L,
            stream: Vec::new(),
            clean: Rng::new(seed),
            clean_words_used: 0,
        }
    }

    /// Reconstruct a coder from a serialized message (head ++ stream) and
    /// the clean-bit seed, replaying `clean_words_used` so that further
    /// pops continue the same clean-bit sequence.
    pub fn from_message(msg: &AnsMessage, seed: u64) -> Self {
        let mut clean = Rng::new(seed);
        for _ in 0..msg.clean_words_used {
            clean.next_u32();
        }
        Self {
            head: msg.head,
            stream: msg.stream.clone(),
            clean,
            clean_words_used: msg.clean_words_used,
        }
    }

    /// Push (encode) a symbol occupying the interval `[start, start+freq)`
    /// out of `2^prec`.
    #[inline]
    pub fn push(&mut self, start: u32, freq: u32, prec: u32) {
        debug_assert!(prec <= MAX_PREC);
        debug_assert!(freq > 0, "zero-frequency symbol");
        debug_assert!((start as u64 + freq as u64) <= (1u64 << prec));
        if freq as u64 == 1u64 << prec {
            // Full-mass symbol (single-symbol alphabets, e.g. a one-state
            // HMM's latent): zero bits of information, and the textbook
            // update below is the exact identity (start must be 0, so
            // `(x / 2^prec) << prec | x % 2^prec == x`) — but its
            // renormalization threshold `freq << (64 - prec)` would wrap
            // to 0 and renormalize forever. Take the exact no-op early;
            // the decode side (`update`) is naturally the identity.
            return;
        }
        // Renormalize: emit words until the push keeps head < 2^64.
        let limit = (freq as u64) << (64 - prec);
        while self.head >= limit {
            self.stream.push(self.head as u32);
            self.head >>= 32;
        }
        self.head =
            ((self.head / freq as u64) << prec) | (self.head % freq as u64 + start as u64);
    }

    /// Division-free variant of [`Ans::push`] for a precomputed symbol —
    /// byte-identical output (see [`prepared`]). This is the per-symbol
    /// hot path for the uniform prior (`freq == 1` prepares without any
    /// division) and for codecs that hold a [`SymbolTable`].
    #[inline]
    pub fn push_prepared(&mut self, sym: &PreparedInterval) {
        sym.push_raw(&mut self.head, &mut self.stream);
    }

    /// Pop step 1: peek the cumulative value in `[0, 2^prec)` identifying
    /// the next symbol's interval. Must be followed by [`Ans::update`].
    #[inline]
    pub fn pop_cf(&mut self, prec: u32) -> u32 {
        debug_assert!(prec <= MAX_PREC);
        (self.head & ((1u64 << prec) - 1)) as u32
    }

    /// Pop step 2: advance the state given the interval decoded from the
    /// cumulative value returned by [`Ans::pop_cf`].
    #[inline]
    pub fn update(&mut self, start: u32, freq: u32, prec: u32) {
        debug_assert!(freq > 0);
        let cf = self.head & ((1u64 << prec) - 1);
        debug_assert!(cf >= start as u64 && cf < start as u64 + freq as u64);
        self.head = freq as u64 * (self.head >> prec) + cf - start as u64;
        while self.head < RANS_L {
            let w = match self.stream.pop() {
                Some(w) => w,
                None => {
                    self.clean_words_used += 1;
                    self.clean.next_u32()
                }
            };
            self.head = (self.head << 32) | w as u64;
        }
    }

    /// Pop a symbol via a lookup closure mapping the cumulative value to
    /// `(symbol, start, freq)`.
    #[inline]
    pub fn pop_with<S>(&mut self, prec: u32, lookup: impl FnOnce(u32) -> (S, u32, u32)) -> S {
        let cf = self.pop_cf(prec);
        let (sym, start, freq) = lookup(cf);
        self.update(start, freq, prec);
        sym
    }

    /// Total message length in bits if serialized right now.
    pub fn bit_len(&self) -> u64 {
        // Head always serializes as 64 bits; stream words are 32 each.
        64 + 32 * self.stream.len() as u64
    }

    /// A finer-grained measure for rate accounting: fractional information
    /// content of the head plus stream bits. Useful for measuring per-symbol
    /// costs below the 32-bit renormalization granularity.
    pub fn frac_bit_len(&self) -> f64 {
        (self.head as f64).log2() + 32.0 * self.stream.len() as f64
    }

    /// Number of clean-bit *words* drawn so far from the seed supply.
    pub fn clean_words_used(&self) -> u64 {
        self.clean_words_used
    }

    /// Clean bits drawn (paper §3.2 reports ~400 bits for chain startup).
    pub fn clean_bits_used(&self) -> u64 {
        32 * self.clean_words_used
    }

    /// Serialize into a message (head ++ stream ++ clean-bit bookkeeping).
    pub fn into_message(self) -> AnsMessage {
        AnsMessage {
            head: self.head,
            stream: self.stream,
            clean_words_used: self.clean_words_used,
        }
    }

    /// Borrowing variant of [`Ans::into_message`].
    pub fn to_message(&self) -> AnsMessage {
        AnsMessage {
            head: self.head,
            stream: self.stream.clone(),
            clean_words_used: self.clean_words_used,
        }
    }

    /// Current number of stream words (excluding head).
    pub fn stream_len(&self) -> usize {
        self.stream.len()
    }

    /// Is the coder in its pristine state (nothing pushed, nothing popped)?
    pub fn is_empty(&self) -> bool {
        self.head == RANS_L && self.stream.is_empty() && self.clean_words_used == 0
    }
}

/// A serialized ANS message: the head, the word stream, and how many clean
/// words the producer consumed (needed to resume the clean-bit sequence and
/// to account rates exactly).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AnsMessage {
    pub head: u64,
    pub stream: Vec<u32>,
    pub clean_words_used: u64,
}

impl AnsMessage {
    /// Flat byte serialization: head (LE u64) ++ clean_words_used (LE u64)
    /// ++ stream len (LE u64) ++ words (LE u32 each).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(24 + 4 * self.stream.len());
        out.extend_from_slice(&self.head.to_le_bytes());
        out.extend_from_slice(&self.clean_words_used.to_le_bytes());
        out.extend_from_slice(&(self.stream.len() as u64).to_le_bytes());
        for w in &self.stream {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> anyhow::Result<Self> {
        use anyhow::bail;
        if b.len() < 24 {
            bail!("ANS message too short: {} bytes", b.len());
        }
        let head = u64::from_le_bytes(b[0..8].try_into().unwrap());
        let clean_words_used = u64::from_le_bytes(b[8..16].try_into().unwrap());
        let n = u64::from_le_bytes(b[16..24].try_into().unwrap()) as usize;
        // Guard the word count before computing byte offsets, so an
        // attacker-controlled length can neither overflow `24 + 4 * n`
        // nor push the word slice below past the buffer.
        if n > (b.len() - 24) / 4 {
            bail!(
                "ANS message truncated: have {}, need {} stream words",
                b.len(),
                n
            );
        }
        let stream = b[24..24 + 4 * n]
            .chunks_exact(4)
            .map(|w| u32::from_le_bytes(w.try_into().unwrap()))
            .collect();
        Ok(Self {
            head,
            stream,
            clean_words_used,
        })
    }

    pub fn bit_len(&self) -> u64 {
        64 + 32 * self.stream.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Symbols from a fixed skewed distribution with precision `prec`.
    fn skewed_dist(prec: u32) -> (Vec<u32>, Vec<u32>) {
        // freqs proportional to [1, 2, 4, 8, ...] padded to fill 2^prec.
        let k = 8usize;
        let total = 1u64 << prec;
        let raw: Vec<u64> = (0..k).map(|i| 1u64 << i).collect();
        let raw_sum: u64 = raw.iter().sum();
        let mut freqs: Vec<u32> = raw
            .iter()
            .map(|&r| ((r * total) / raw_sum).max(1) as u32)
            .collect();
        let diff = total as i64 - freqs.iter().map(|&f| f as i64).sum::<i64>();
        let last = freqs.len() - 1;
        freqs[last] = (freqs[last] as i64 + diff) as u32;
        let mut starts = vec![0u32; k];
        for i in 1..k {
            starts[i] = starts[i - 1] + freqs[i - 1];
        }
        (starts, freqs)
    }

    fn lookup_symbol(cf: u32, starts: &[u32], freqs: &[u32]) -> usize {
        // Linear scan is fine for tests.
        for i in 0..starts.len() {
            if cf >= starts[i] && cf < starts[i] + freqs[i] {
                return i;
            }
        }
        panic!("cf {cf} out of range");
    }

    #[test]
    fn push_pop_roundtrip_skewed() {
        let prec = 16;
        let (starts, freqs) = skewed_dist(prec);
        let mut rng = Rng::new(5);
        let syms: Vec<usize> = (0..10_000).map(|_| rng.below(8) as usize).collect();
        let mut ans = Ans::new(0);
        for &s in &syms {
            ans.push(starts[s], freqs[s], prec);
        }
        for &s in syms.iter().rev() {
            let got = ans.pop_with(prec, |cf| {
                let i = lookup_symbol(cf, &starts, &freqs);
                (i, starts[i], freqs[i])
            });
            assert_eq!(got, s);
        }
        assert!(ans.is_empty(), "coder must return to pristine state");
    }

    #[test]
    fn message_length_near_entropy() {
        // Push n symbols from the *matching* distribution; message length
        // should be close to n * H(p).
        let prec = 14;
        let (starts, freqs) = skewed_dist(prec);
        let total = (1u64 << prec) as f64;
        let probs: Vec<f64> = freqs.iter().map(|&f| f as f64 / total).collect();
        let entropy: f64 = probs.iter().map(|p| -p * p.log2()).sum();

        // Sample from the distribution itself.
        let mut rng = Rng::new(77);
        let n = 200_000usize;
        let syms: Vec<usize> = (0..n)
            .map(|_| {
                let cf = rng.below(1 << prec) as u32;
                lookup_symbol(cf, &starts, &freqs)
            })
            .collect();
        let mut ans = Ans::new(0);
        let before = ans.frac_bit_len();
        for &s in &syms {
            ans.push(starts[s], freqs[s], prec);
        }
        let bits = ans.frac_bit_len() - before;
        let rate = bits / n as f64;
        // Tolerance is dominated by sampling noise of the empirical symbol
        // mix (std ≈ 0.003 bits at n = 200k), not coder redundancy.
        assert!(
            (rate - entropy).abs() / entropy < 0.005,
            "rate={rate} entropy={entropy}"
        );
    }

    #[test]
    fn decode_is_sampler_when_stream_empty() {
        // Popping from an empty coder draws clean bits and yields symbols
        // distributed ~ the coding distribution (invertible sampling).
        let prec = 12;
        let (starts, freqs) = skewed_dist(prec);
        let mut ans = Ans::new(42);
        let n = 50_000;
        let mut counts = vec![0u64; freqs.len()];
        for _ in 0..n {
            let s = ans.pop_with(prec, |cf| {
                let i = lookup_symbol(cf, &starts, &freqs);
                (i, starts[i], freqs[i])
            });
            counts[s] += 1;
        }
        assert!(ans.clean_bits_used() > 0);
        let total = (1u64 << prec) as f64;
        for i in 0..freqs.len() {
            let want = freqs[i] as f64 / total;
            let got = counts[i] as f64 / n as f64;
            assert!(
                (got - want).abs() < 0.01 + want * 0.08,
                "symbol {i}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn sample_then_encode_returns_bits() {
        // The bits-back identity: decode (sample) k symbols from an empty
        // coder, re-encode them in reverse, and the coder must return to its
        // pristine head with zero stream — the "bits back" are recovered.
        let prec = 10;
        let (starts, freqs) = skewed_dist(prec);
        let mut ans = Ans::new(99);
        let mut syms = Vec::new();
        for _ in 0..1000 {
            let s = ans.pop_with(prec, |cf| {
                let i = lookup_symbol(cf, &starts, &freqs);
                (i, starts[i], freqs[i])
            });
            syms.push(s);
        }
        for &s in syms.iter().rev() {
            ans.push(starts[s], freqs[s], prec);
        }
        // All sampled information is returned: the head is back at its
        // pristine value and the stream holds *exactly* the clean words the
        // sampling consumed (in reverse consumption order) — i.e. the
        // "bits back" were recovered verbatim.
        assert_eq!(ans.head, RANS_L);
        let used = ans.clean_words_used() as usize;
        assert_eq!(ans.stream_len(), used);
        let mut fresh = Rng::new(99);
        let consumed: Vec<u32> = (0..used).map(|_| fresh.next_u32()).collect();
        let msg = ans.to_message();
        let mut returned = msg.stream.clone();
        returned.reverse();
        assert_eq!(returned, consumed);
    }

    #[test]
    fn message_serialization_roundtrip() {
        let prec = 16;
        let (starts, freqs) = skewed_dist(prec);
        let mut ans = Ans::new(7);
        let mut rng = Rng::new(8);
        let syms: Vec<usize> = (0..500).map(|_| rng.below(8) as usize).collect();
        for &s in &syms {
            ans.push(starts[s], freqs[s], prec);
        }
        let msg = ans.to_message();
        let bytes = msg.to_bytes();
        let msg2 = AnsMessage::from_bytes(&bytes).unwrap();
        assert_eq!(msg, msg2);
        let mut ans2 = Ans::from_message(&msg2, 7);
        for &s in syms.iter().rev() {
            let got = ans2.pop_with(prec, |cf| {
                let i = lookup_symbol(cf, &starts, &freqs);
                (i, starts[i], freqs[i])
            });
            assert_eq!(got, s);
        }
    }

    #[test]
    fn from_bytes_rejects_truncation() {
        let msg = AnsMessage {
            head: RANS_L,
            stream: vec![1, 2, 3],
            clean_words_used: 0,
        };
        let bytes = msg.to_bytes();
        assert!(AnsMessage::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(AnsMessage::from_bytes(&bytes[..10]).is_err());
    }

    #[test]
    fn full_mass_symbol_is_free_and_invertible() {
        // A freq = 2^prec symbol (single-symbol alphabet) carries zero
        // bits: pushes leave the coder untouched and pops return it with
        // the state unchanged.
        let mut ans = Ans::new(3);
        ans.push(5, 3, 8); // some real content first
        let before = ans.to_message();
        for prec in [1u32, 8, 16, 24] {
            for _ in 0..100 {
                ans.push(0, 1u32 << prec, prec);
            }
            assert_eq!(ans.to_message(), before, "prec {prec}");
            for _ in 0..100 {
                let got = ans.pop_with(prec, |cf| (cf, 0, 1u32 << prec));
                assert!((got as u64) < (1u64 << prec));
            }
            assert_eq!(ans.to_message(), before, "prec {prec} after pops");
        }
    }

    #[test]
    fn mixed_precisions_roundtrip() {
        // Interleave pushes at different precisions; pops must invert.
        let mut ans = Ans::new(0);
        let mut rng = Rng::new(4);
        let ops: Vec<(u32, u32)> = (0..5000)
            .map(|_| {
                let prec = 1 + rng.below(24) as u32;
                let sym = rng.below(1u64 << prec) as u32;
                (prec, sym)
            })
            .collect();
        // Uniform distribution at each precision: start=sym, freq=1.
        for &(prec, sym) in &ops {
            ans.push(sym, 1, prec);
        }
        for &(prec, sym) in ops.iter().rev() {
            let got = ans.pop_with(prec, |cf| (cf, cf, 1));
            assert_eq!(got, sym);
        }
        assert!(ans.is_empty());
    }
}
