//! Division-free symbol encoding (the ISSUE 2 hot-path layer).
//!
//! `Ans::push` pays a hardware `u64` divide + modulo per symbol, and the
//! division sits on the loop-carried dependency chain through the coder
//! head — every symbol's divide must retire before the next can start.
//! Production rANS implementations (ryg's `rans64.h`; Alverson, *Integer
//! division using reciprocals*, 1991) precompute, per symbol interval, a
//! fixed-point reciprocal of the frequency so the encode step becomes a
//! high-multiply plus shifts:
//!
//! ```text
//! q  = (x · rcp_freq) >> 64 >> rcp_shift          // exactly x / freq
//! x' = x + bias + q · cmpl_freq                   // == (q << prec) | (x % freq + start)
//! ```
//!
//! The one division left (building `rcp_freq`) runs once per *distribution
//! symbol* when a [`SymbolTable`] is built, or — for one-shot symbols —
//! off the dependency chain, where it pipelines with neighbouring work
//! instead of serializing the coder.
//!
//! **Bit-exactness.** For every `(start, freq, prec, head)` the prepared
//! step produces the same head and the same renormalization words as the
//! division step; `freq == 1` (the uniform-prior path) is special-cased
//! with `rcp = 2⁶⁴ − 1`, which needs no division at all to build. The
//! equivalence is enforced by exhaustive unit tests here and by the
//! cross-coder property tests in `tests/properties.rs`, so the prepared
//! path never changes a byte of any container.
//!
//! One subtlety vs ryg's rans64: that coder renormalizes its state below
//! 2⁶³, where a 64-bit round-up reciprocal is exact for every frequency.
//! Our head lives in `[2³², 2⁶⁴)` and after renormalization can reach
//! `freq · 2^(64−prec) − 1`, which for `freq > 2^(prec−1)` (symbols with
//! probability > ½) exceeds the Granlund–Montgomery exactness range for
//! some frequencies. [`PreparedInterval::new`] therefore checks the exact
//! bound `rem · 2^(64−prec) < rcp` at build time; the rare symbol that
//! fails it (only possible at p > ½) encodes through the division path,
//! flagged by a `rcp_freq == 0` sentinel — correctness never depends on
//! the reciprocal being exact.

use super::interleaved::Interval;
use super::MAX_PREC;

/// A symbol interval with its precomputed encode constants.
///
/// Immutable once built; `Copy` so tables can hand out values without
/// indirection in the hot loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PreparedInterval {
    /// Fixed-point reciprocal: `ceil(2^(shift+63) / freq)` for `freq ≥ 2`,
    /// `2⁶⁴ − 1` for `freq == 1`, or `0` as the sentinel for the rare
    /// p > ½ symbol whose reciprocal is not exact over the full head
    /// range (encodes via division; see the module docs).
    rcp_freq: u64,
    /// `start` for `freq ≥ 2`; `start + 2^prec − 1` for `freq == 1`.
    bias: u64,
    /// `2^prec − freq`.
    cmpl_freq: u64,
    /// Renormalization threshold: `freq << (64 − prec)`.
    limit: u64,
    /// `ceil(log2 freq) − 1` for `freq ≥ 2`; `0` for `freq == 1`.
    rcp_shift: u32,
    start: u32,
    freq: u32,
    prec: u32,
}

impl PreparedInterval {
    /// Prepare the interval `[start, start+freq)` out of `2^prec`.
    #[inline]
    pub fn new(start: u32, freq: u32, prec: u32) -> Self {
        debug_assert!(prec >= 1 && prec <= MAX_PREC);
        debug_assert!(freq > 0, "zero-frequency symbol");
        debug_assert!((start as u64 + freq as u64) <= (1u64 << prec));
        let m = 1u64 << prec;
        // A full-mass symbol (freq == 2^prec, single-symbol alphabets)
        // carries zero bits and `Ans::push` treats it as an exact no-op —
        // but the renormalization threshold `freq << (64 − prec)` wraps to
        // 0, which the hot loop cannot use. It is represented as the
        // explicit no-op sentinel `limit == 0` (push_raw returns
        // immediately), mirroring `Ans::push`'s early return bit-for-bit.
        // Batch producers therefore need no pre-filtering (ISSUE 5's
        // `new_batch` relies on this).
        if freq as u64 == m {
            debug_assert!(start == 0, "full-mass symbol must start at 0");
            return Self {
                rcp_freq: 0,
                bias: 0,
                cmpl_freq: 0,
                limit: 0,
                rcp_shift: 0,
                start,
                freq,
                prec,
            };
        }
        let limit = (freq as u64) << (64 - prec);
        if freq == 1 {
            // x / 1 == x: encode as mulhi(x, 2⁶⁴−1) = x − 1, compensated
            // through the bias so x' = (x << prec) | start. No division.
            Self {
                rcp_freq: u64::MAX,
                bias: start as u64 + m - 1,
                cmpl_freq: m - 1,
                limit,
                rcp_shift: 0,
                start,
                freq,
                prec,
            }
        } else {
            // shift = ceil(log2 freq); rcp = ceil(2^(shift+63) / freq),
            // computed as a 2-limb long division so no u128 division (a
            // libcall) is needed (as in ryg rans64.h).
            let shift = 32 - (freq - 1).leading_zeros();
            let f = freq as u64;
            let hi = 1u64 << (shift + 31);
            let t1 = hi / f;
            let t0 = ((hi % f) << 32 | (f - 1)) / f;
            let rcp = (t1 << 32) + t0;
            // Exactness guard (Granlund–Montgomery): with rem =
            // rcp·freq − 2^(shift+63), the reciprocal reproduces x / freq
            // for every q = x/freq < 2^(64−prec) — i.e. every x this
            // symbol can see after renormalization — iff
            // rem · 2^(64−prec) < rcp. Symbols with freq ≤ 2^(prec−1)
            // always pass; a failing p > ½ symbol keeps the division
            // path (sentinel rcp_freq = 0) so output never changes.
            let rem = ((rcp as u128 * f as u128) - (1u128 << (shift + 63))) as u64;
            if rem <= (rcp - 1) >> (64 - prec) {
                Self {
                    rcp_freq: rcp,
                    bias: start as u64,
                    cmpl_freq: m - f,
                    limit,
                    rcp_shift: shift - 1,
                    start,
                    freq,
                    prec,
                }
            } else {
                Self {
                    rcp_freq: 0,
                    bias: 0,
                    cmpl_freq: 0,
                    limit,
                    rcp_shift: 0,
                    start,
                    freq,
                    prec,
                }
            }
        }
    }

    /// Batch-prepare a whole interval sequence into a reusable buffer
    /// (cleared first) — the ISSUE 5 build path for symbol tables and
    /// gathered pixel batches.
    ///
    /// The per-symbol math is [`PreparedInterval::new`] exactly (bitwise-
    /// identical output, pinned by tests below); the win is structural:
    /// the loop is unrolled four-wide over **independent** symbols so the
    /// 2-limb reciprocal divisions — the only remaining divides, and the
    /// long-latency op of the build — overlap in the pipeline instead of
    /// serializing behind one `Vec::push` at a time, and `freq == 1` /
    /// full-mass symbols take their division-free constructors. (u64
    /// division has no SIMD form on x86/aarch64; across-symbol ILP is the
    /// vector unit this loop gets.)
    pub fn new_batch(intervals: &[Interval], prec: u32, out: &mut Vec<Self>) {
        out.clear();
        out.reserve(intervals.len());
        let mut chunks = intervals.chunks_exact(4);
        for q in chunks.by_ref() {
            // Four independent builds; no data dependence between them.
            let a = Self::new(q[0].start, q[0].freq, prec);
            let b = Self::new(q[1].start, q[1].freq, prec);
            let c = Self::new(q[2].start, q[2].freq, prec);
            let d = Self::new(q[3].start, q[3].freq, prec);
            out.extend_from_slice(&[a, b, c, d]);
        }
        out.extend(
            chunks
                .remainder()
                .iter()
                .map(|iv| Self::new(iv.start, iv.freq, prec)),
        );
    }

    /// The plain quantized interval (fallback for coders without a
    /// prepared fast path).
    #[inline]
    pub fn interval(&self) -> Interval {
        Interval {
            start: self.start,
            freq: self.freq,
        }
    }

    /// Coding precision this symbol was prepared at.
    #[inline]
    pub fn prec(&self) -> u32 {
        self.prec
    }

    /// Does this symbol encode through the reciprocal (vs the rare
    /// division fallback)?
    #[inline]
    fn uses_reciprocal(&self) -> bool {
        self.rcp_freq != 0
    }

    /// The encoder's quotient term (reciprocal symbols only). For
    /// `freq ≥ 2` this is exactly `x / freq` for every `x` below this
    /// symbol's renormalization threshold; for `freq == 1` it is `x − 1`
    /// (for `x ≥ 1`), which the bias compensates.
    #[inline(always)]
    fn quotient(&self, x: u64) -> u64 {
        (((x as u128 * self.rcp_freq as u128) >> 64) as u64) >> self.rcp_shift
    }

    /// Is this the zero-information full-mass sentinel (`freq == 2^prec`),
    /// whose encode step is an exact no-op?
    #[inline]
    pub fn is_full_mass(&self) -> bool {
        self.limit == 0
    }

    /// One encode step: renormalize `head` against this symbol's
    /// precomputed threshold (emitting 32-bit words to `stream`), then
    /// apply the state transition — division-free except for the rare
    /// sentinel symbol (see the module docs). Byte-identical to
    /// `Ans::push`.
    #[inline(always)]
    pub(crate) fn push_raw(&self, head: &mut u64, stream: &mut Vec<u32>) {
        if self.limit == 0 {
            return; // full-mass no-op, exactly as Ans::push
        }
        let mut x = *head;
        while x >= self.limit {
            stream.push(x as u32);
            x >>= 32;
        }
        *head = if self.uses_reciprocal() {
            x + self.bias + self.quotient(x) * self.cmpl_freq
        } else {
            ((x / self.freq as u64) << self.prec) | (x % self.freq as u64 + self.start as u64)
        };
    }
}

/// All symbols of one quantized distribution, prepared once.
///
/// Build cost is one reciprocal per *distribution symbol*; every encoded
/// occurrence after that is division-free. Pays for itself as soon as a
/// distribution codes more symbols than its alphabet size.
#[derive(Debug, Clone)]
pub struct SymbolTable {
    prec: u32,
    syms: Vec<PreparedInterval>,
}

impl SymbolTable {
    /// Prepare a full interval table (intervals must tile `[0, 2^prec)`
    /// in symbol order, as produced by the quantizer). Routes through the
    /// batched [`PreparedInterval::new_batch`] build.
    pub fn from_intervals(intervals: &[Interval], prec: u32) -> Self {
        let mut syms = Vec::new();
        PreparedInterval::new_batch(intervals, prec, &mut syms);
        Self { prec, syms }
    }

    /// Prepare from cumulative bounds (`cdf.len() == num_symbols + 1`,
    /// `cdf[0] == 0`, strictly increasing) — the layout
    /// `codecs::quantize::QuantizedCdf` stores.
    pub fn from_cdf(cdf: &[u32], prec: u32) -> Self {
        debug_assert!(cdf.len() >= 2);
        Self {
            prec,
            syms: cdf
                .windows(2)
                .map(|w| PreparedInterval::new(w[0], w[1] - w[0], prec))
                .collect(),
        }
    }

    #[inline]
    pub fn get(&self, sym: usize) -> &PreparedInterval {
        &self.syms[sym]
    }

    #[inline]
    pub fn prec(&self) -> u32 {
        self.prec
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.syms.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.syms.is_empty()
    }

    /// Gather the prepared symbols for a sequence into a reusable buffer
    /// (cleared first) — the allocation-free feeding path for
    /// [`crate::ans::EntropyCoder::encode_all_prepared`].
    pub fn gather_into(&self, syms: &[usize], out: &mut Vec<PreparedInterval>) {
        out.clear();
        out.extend(syms.iter().map(|&s| self.syms[s]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ans::RANS_L;
    use crate::util::rng::Rng;

    /// The reference (division) state transition from `Ans::push`.
    fn div_step(mut head: u64, start: u32, freq: u32, prec: u32) -> (u64, Vec<u32>) {
        let mut stream = Vec::new();
        let limit = (freq as u64) << (64 - prec);
        while head >= limit {
            stream.push(head as u32);
            head >>= 32;
        }
        head = ((head / freq as u64) << prec) | (head % freq as u64 + start as u64);
        (head, stream)
    }

    fn prep_step(mut head: u64, start: u32, freq: u32, prec: u32) -> (u64, Vec<u32>) {
        let mut stream = Vec::new();
        PreparedInterval::new(start, freq, prec).push_raw(&mut head, &mut stream);
        (head, stream)
    }

    #[test]
    fn prepared_step_matches_division_step_exhaustively() {
        // Every frequency at a mid-size precision, against heads covering
        // the renormalization boundaries and the extremes of the invariant
        // range [2³², 2⁶⁴). freq == 2^prec is excluded: such a symbol
        // carries zero information and `Ans::push`'s renormalization is
        // undefined for it (limit wraps to 0) — the quantizer never emits
        // it for alphabets of two or more symbols.
        let prec = 12u32;
        let mut via_rcp = 0u32;
        let mut via_div = 0u32;
        for freq in 1..(1u32 << prec) {
            if PreparedInterval::new(0, freq, prec).uses_reciprocal() {
                via_rcp += 1;
            } else {
                via_div += 1;
            }
            let start_max = (1u32 << prec) - freq;
            let limit = (freq as u64) << (64 - prec);
            for start in [0, start_max / 2, start_max] {
                for head in [
                    RANS_L,
                    RANS_L + 1,
                    limit.saturating_sub(1).max(RANS_L),
                    limit.max(RANS_L),
                    u64::MAX - 1,
                    u64::MAX,
                ] {
                    assert_eq!(
                        div_step(head, start, freq, prec),
                        prep_step(head, start, freq, prec),
                        "freq={freq} start={start} head={head:#x}"
                    );
                }
            }
        }
        // The sweep must exercise both the reciprocal path and the
        // p > ½ division-fallback path (the exactness-guard boundary).
        assert!(
            via_rcp > 0 && via_div > 0,
            "encode paths not both covered: rcp={via_rcp} div={via_div}"
        );
    }

    #[test]
    fn prepared_step_matches_division_step_random_wide() {
        // Random (prec, freq, start, head) across the full supported
        // precision range, including prec = 32.
        let mut rng = Rng::new(0x9E1D);
        for _ in 0..200_000 {
            let prec = 1 + rng.below(MAX_PREC as u64) as u32;
            let m = 1u64 << prec;
            // freq in [1, 2^prec) — full-range symbols are excluded (see
            // the exhaustive test above); prec = 1 only admits freq = 1.
            let fmax = (m - 1).max(1).min(u32::MAX as u64);
            let freq = (1 + rng.below(fmax)) as u32;
            let start = rng.below(m - freq as u64 + 1) as u32;
            // Heads from the full invariant range; bias toward boundaries.
            let head = match rng.below(4) {
                0 => RANS_L + rng.below(1 << 20),
                1 => u64::MAX - rng.below(1 << 20),
                _ => rng.next_u64() | RANS_L, // ≥ RANS_L
            };
            assert_eq!(
                div_step(head, start, freq, prec),
                prep_step(head, start, freq, prec),
                "prec={prec} freq={freq} start={start} head={head:#x}"
            );
        }
    }

    #[test]
    fn reciprocal_quotient_is_exact_within_renorm_range() {
        // For reciprocal symbols the quotient must equal x / freq for
        // every x below the renormalization threshold — the only values
        // `push_raw` ever feeds it. Sentinel (division-fallback) symbols
        // are covered by the step-equality tests.
        let prec = 32u32;
        let mut rng = Rng::new(0x51ED);
        let mut checked = 0u64;
        let check = |freq: u32, x: u64| {
            let p = PreparedInterval::new(0, freq, prec);
            if !p.uses_reciprocal() {
                return false;
            }
            debug_assert!(x < p.limit);
            assert_eq!(p.quotient(x), x / freq as u64, "freq={freq} x={x:#x}");
            true
        };
        for shift in 1..32u32 {
            for d in [-1i64, 0, 1] {
                let freq = ((1i64 << shift) + d) as u32;
                if freq < 2 {
                    continue;
                }
                let limit = (freq as u64) << (64 - prec);
                for x in [0, 1, freq as u64 - 1, freq as u64, limit - freq as u64, limit - 1] {
                    if check(freq, x) {
                        checked += 1;
                    }
                }
            }
        }
        for _ in 0..200_000 {
            let freq = (2 + rng.below((1u64 << 32) - 2)) as u32;
            let limit = (freq as u64) << (64 - prec);
            let x = rng.next_u64() % limit;
            // Random interior point, the worst case (top of the range),
            // and the quotient boundaries k·freq ± 1.
            let k = x / freq as u64;
            for probe in [x, limit - 1, k * freq as u64, (k * freq as u64).saturating_sub(1)] {
                if check(freq, probe) {
                    checked += 1;
                }
            }
        }
        assert!(checked > 400_000, "reciprocal path under-exercised: {checked}");
    }

    /// The batch constructor must equal per-symbol construction exactly
    /// (all fields), across random tables covering the `freq == 1`
    /// no-division path, the reciprocal path, and the p > ½ division-
    /// fallback sentinel — plus the full-mass no-op sentinel a
    /// single-symbol alphabet produces.
    #[test]
    fn new_batch_matches_per_symbol_construction() {
        let mut rng = Rng::new(0xBA7C4);
        let mut out = Vec::new();
        let (mut saw_rcp, mut saw_div, mut saw_one) = (false, false, false);
        for _ in 0..400 {
            let prec = 1 + rng.below(MAX_PREC as u64) as u32;
            let m = 1u64 << prec;
            // Random tiling of [0, 2^prec) into 1..=24 intervals. A
            // single-symbol tiling is the full-mass case, which only fits
            // `Interval::freq: u32` below prec 32.
            let k_min = if prec == MAX_PREC { 2u64 } else { 1 };
            let k = (k_min + rng.below(24.min(m) - k_min + 1)) as usize;
            let mut cuts: Vec<u64> = (0..k - 1).map(|_| 1 + rng.below(m - 1)).collect();
            cuts.sort_unstable();
            cuts.dedup();
            let mut ivs = Vec::new();
            let mut prev = 0u64;
            for &c in cuts.iter().chain(std::iter::once(&m)) {
                ivs.push(Interval {
                    start: prev as u32,
                    freq: (c - prev) as u32,
                });
                prev = c;
            }
            PreparedInterval::new_batch(&ivs, prec, &mut out);
            assert_eq!(out.len(), ivs.len());
            for (iv, got) in ivs.iter().zip(out.iter()) {
                let want = PreparedInterval::new(iv.start, iv.freq, prec);
                assert_eq!(*got, want, "prec={prec} iv={iv:?}");
                if got.is_full_mass() {
                    saw_one = true;
                } else if got.uses_reciprocal() {
                    saw_rcp = true;
                } else {
                    saw_div = true;
                }
            }
        }
        assert!(
            saw_rcp && saw_div && saw_one,
            "batch sweep must cover all three symbol kinds: \
             rcp={saw_rcp} div-fallback={saw_div} full-mass={saw_one}"
        );
    }

    /// The full-mass sentinel (`freq == 2^prec`, single-symbol alphabets)
    /// must be an exact no-op under the prepared push — byte-identical to
    /// `Ans::push`'s early return — at every precision it can occur at.
    #[test]
    fn full_mass_sentinel_is_exact_noop() {
        for prec in [1u32, 8, 16, 24, 31] {
            let p = PreparedInterval::new(0, (1u64 << prec) as u32, prec);
            assert!(p.is_full_mass());
            assert_eq!(p.prec(), prec);
            for head0 in [RANS_L, RANS_L + 12345, u64::MAX] {
                let mut head = head0;
                let mut stream = vec![7u32];
                p.push_raw(&mut head, &mut stream);
                assert_eq!(head, head0, "prec={prec}");
                assert_eq!(stream, vec![7u32], "prec={prec}");
            }
        }
    }

    /// The p > ½ exactness-guard fallback must survive the batched build:
    /// a frequency known to fail the Granlund–Montgomery bound keeps the
    /// division sentinel and still steps identically to the division path.
    #[test]
    fn division_fallback_sentinel_under_batched_build() {
        // At prec = 12, sweep p > ½ frequencies for one that falls back
        // (the exhaustive step test proves both kinds exist there).
        let prec = 12u32;
        let fallback = (1u32 << (prec - 1)..1u32 << prec)
            .find(|&f| !PreparedInterval::new(0, f, prec).uses_reciprocal())
            .expect("a p > 1/2 division-fallback frequency exists at prec 12");
        let ivs = [
            Interval {
                start: 0,
                freq: fallback,
            },
            Interval {
                start: fallback,
                freq: (1u32 << prec) - fallback,
            },
        ];
        let mut out = Vec::new();
        PreparedInterval::new_batch(&ivs, prec, &mut out);
        assert!(!out[0].uses_reciprocal() && !out[0].is_full_mass());
        for head in [RANS_L, RANS_L + 999, u64::MAX] {
            assert_eq!(
                div_step(head, 0, fallback, prec),
                prep_step(head, 0, fallback, prec),
                "fallback freq={fallback} head={head:#x}"
            );
        }
    }

    #[test]
    fn symbol_table_matches_per_symbol_preparation() {
        let prec = 10;
        let intervals = [
            Interval { start: 0, freq: 600 },
            Interval { start: 600, freq: 1 },
            Interval {
                start: 601,
                freq: 1024 - 601,
            },
        ];
        let t = SymbolTable::from_intervals(&intervals, prec);
        assert_eq!(t.len(), 3);
        assert_eq!(t.prec(), prec);
        assert!(!t.is_empty());
        let cdf = [0u32, 600, 601, 1024];
        let t2 = SymbolTable::from_cdf(&cdf, prec);
        for (s, iv) in intervals.iter().enumerate() {
            assert_eq!(*t.get(s), PreparedInterval::new(iv.start, iv.freq, prec));
            assert_eq!(t.get(s), t2.get(s));
        }
        let mut buf = vec![PreparedInterval::new(0, 1, 1); 7];
        t.gather_into(&[2, 0, 0, 1], &mut buf);
        assert_eq!(buf.len(), 4);
        assert_eq!(buf[0], *t.get(2));
        assert_eq!(buf[3], *t.get(1));
    }
}
