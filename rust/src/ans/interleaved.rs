//! Interleaved multi-lane rANS (Giesen 2014, "Interleaved entropy coders").
//!
//! Paper §4.2 points at parallel ANS as the route to a high-throughput
//! implementation. This module implements an N-lane interleaved coder: N
//! independent rANS heads share a single output stream, with a fixed
//! round-robin renormalization discipline so the decoder can reproduce the
//! word order. Lanes expose instruction-level parallelism on CPUs (and map
//! to SIMD/GPU threads in principle); `benches/ans.rs` measures the gain.
//!
//! Restrictions vs [`super::Ans`]: symbols are encoded in *batches* that are
//! striped across lanes; the whole batch sequence is encoded back-to-front
//! (the usual ANS stack discipline) and decoded front-to-back. There is no
//! clean-bit facility here — this coder targets the fully-observed fast
//! path (likelihood coding), not bits-back sampling.

use super::prepared::PreparedInterval;
use super::RANS_L;

/// An N-lane interleaved rANS encoder/decoder over a shared word stream.
/// Equality compares the full coder state (heads + stream), which the
/// property tests use to pin the prepared encode path to the division
/// path bit-for-bit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InterleavedAns<const N: usize> {
    heads: [u64; N],
    stream: Vec<u32>,
}

/// A symbol's quantized interval.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    pub start: u32,
    pub freq: u32,
}

impl<const N: usize> InterleavedAns<N> {
    pub fn new() -> Self {
        Self {
            heads: [RANS_L; N],
            stream: Vec::new(),
        }
    }

    /// Encode a slice of symbol intervals, striped across lanes:
    /// symbol `i` goes to lane `i % N`. Must be called with the **entire**
    /// sequence; encoding walks it back-to-front.
    pub fn encode(&mut self, intervals: &[Interval], prec: u32) {
        for (i, iv) in intervals.iter().enumerate().rev() {
            let lane = i % N;
            let limit = (iv.freq as u64) << (64 - prec);
            let head = &mut self.heads[lane];
            while *head >= limit {
                self.stream.push(*head as u32);
                *head >>= 32;
            }
            *head = ((*head / iv.freq as u64) << prec)
                | (*head % iv.freq as u64 + iv.start as u64);
        }
        // The decoder reads words in reverse push order.
    }

    /// Division-free variant of [`InterleavedAns::encode`] over prepared
    /// symbols — identical lane striping and renormalization schedule, so
    /// the output is byte-identical to the division path.
    pub fn encode_prepared(&mut self, prepared: &[PreparedInterval]) {
        for (i, p) in prepared.iter().enumerate().rev() {
            p.push_raw(&mut self.heads[i % N], &mut self.stream);
        }
    }

    /// Decode `n` symbols front-to-back. `lookup(lane_cf) -> (sym, interval)`.
    pub fn decode<S>(
        &mut self,
        n: usize,
        prec: u32,
        mut lookup: impl FnMut(u32) -> (S, Interval),
    ) -> Vec<S> {
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let lane = i % N;
            let head = &mut self.heads[lane];
            let cf = (*head & ((1u64 << prec) - 1)) as u32;
            let (sym, iv) = lookup(cf);
            debug_assert!(cf >= iv.start && cf < iv.start + iv.freq);
            *head = iv.freq as u64 * (*head >> prec) + cf as u64 - iv.start as u64;
            while *head < RANS_L {
                let w = self.stream.pop().expect("interleaved stream underflow");
                *head = (*head << 32) | w as u64;
            }
            out.push(sym);
        }
        out
    }

    pub fn bit_len(&self) -> u64 {
        64 * N as u64 + 32 * self.stream.len() as u64
    }

    pub fn stream_len(&self) -> usize {
        self.stream.len()
    }

    pub fn is_pristine(&self) -> bool {
        self.heads.iter().all(|&h| h == RANS_L) && self.stream.is_empty()
    }
}

impl<const N: usize> Default for InterleavedAns<N> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn dist(prec: u32) -> Vec<Interval> {
        // 16 symbols, geometric-ish.
        let k = 16usize;
        let total = 1u64 << prec;
        let raw: Vec<u64> = (0..k).map(|i| (i as u64 + 1) * (i as u64 + 1)).collect();
        let s: u64 = raw.iter().sum();
        let mut freqs: Vec<u32> = raw.iter().map(|&r| ((r * total) / s).max(1) as u32).collect();
        let fix = total as i64 - freqs.iter().map(|&f| f as i64).sum::<i64>();
        let last = freqs.len() - 1;
        freqs[last] = (freqs[last] as i64 + fix) as u32;
        let mut start = 0u32;
        freqs
            .into_iter()
            .map(|f| {
                let iv = Interval { start, freq: f };
                start += f;
                iv
            })
            .collect()
    }

    fn lookup(cf: u32, d: &[Interval]) -> usize {
        d.iter()
            .position(|iv| cf >= iv.start && cf < iv.start + iv.freq)
            .unwrap()
    }

    fn roundtrip<const N: usize>(n_syms: usize, seed: u64) {
        let prec = 14;
        let d = dist(prec);
        let mut rng = Rng::new(seed);
        let syms: Vec<usize> = (0..n_syms).map(|_| rng.below(16) as usize).collect();
        let ivs: Vec<Interval> = syms.iter().map(|&s| d[s]).collect();
        let mut coder = InterleavedAns::<N>::new();
        coder.encode(&ivs, prec);
        let got = coder.decode(n_syms, prec, |cf| {
            let s = lookup(cf, &d);
            (s, d[s])
        });
        assert_eq!(got, syms);
        assert!(coder.is_pristine());
    }

    #[test]
    fn two_lane_roundtrip() {
        roundtrip::<2>(10_000, 1);
    }

    #[test]
    fn four_lane_roundtrip() {
        roundtrip::<4>(9_999, 2); // non-multiple of lane count
    }

    #[test]
    fn one_lane_matches_plain_rate() {
        let prec = 14;
        let d = dist(prec);
        let mut rng = Rng::new(3);
        let n = 100_000;
        let syms: Vec<usize> = (0..n)
            .map(|_| {
                let cf = rng.below(1 << prec) as u32;
                lookup(cf, &d)
            })
            .collect();
        let ivs: Vec<Interval> = syms.iter().map(|&s| d[s]).collect();

        let mut il = InterleavedAns::<4>::new();
        il.encode(&ivs, prec);

        let mut plain = crate::ans::Ans::new(0);
        for iv in ivs.iter().rev() {
            plain.push(iv.start, iv.freq, prec);
        }
        // Interleaving costs only the extra heads (<= 3 * 64 bits here).
        let diff = il.bit_len() as i64 - plain.bit_len() as i64;
        assert!(diff.abs() <= 64 * 4, "interleaved overhead too large: {diff}");
    }

    #[test]
    fn prepared_encode_is_bit_identical() {
        let prec = 14;
        let d = dist(prec);
        let mut rng = Rng::new(11);
        let ivs: Vec<Interval> = (0..5001)
            .map(|_| d[rng.below(16) as usize])
            .collect();
        let prepared: Vec<PreparedInterval> = ivs
            .iter()
            .map(|iv| PreparedInterval::new(iv.start, iv.freq, prec))
            .collect();
        let mut a = InterleavedAns::<4>::new();
        a.encode(&ivs, prec);
        let mut b = InterleavedAns::<4>::new();
        b.encode_prepared(&prepared);
        assert_eq!(a, b, "prepared lanes must match the division path");
    }

    #[test]
    fn empty_input_ok() {
        let mut coder = InterleavedAns::<2>::new();
        coder.encode(&[], 10);
        let got: Vec<usize> = coder.decode(0, 10, |_| unreachable!());
        assert!(got.is_empty());
        assert!(coder.is_pristine());
    }
}
