//! [`EntropyCoder`] — the unified interface over the stack [`Ans`] coder
//! and the multi-lane [`InterleavedAns`] coder.
//!
//! Codecs (`crate::codecs`) and the BB-ANS likelihood path are written
//! once against this trait and run on either coder: the plain stack coder
//! for bits-back work (it alone has the clean-bit facility), the
//! interleaved coder for the fully-observed fast path where lanes expose
//! instruction-level parallelism (paper §4.2; `benches/ans.rs` measures
//! single-lane vs multi-lane throughput through this trait).
//!
//! # Contract
//!
//! * **Stream order.** `encode_all` receives symbol intervals in *stream
//!   order* (the order the decoder will produce them); implementations
//!   handle their own internal ordering (the stack coder pushes the slice
//!   back-to-front, the interleaved coder stripes lanes). `decode_all`
//!   invokes `lookup` once per position, in stream order.
//! * **Normalization invariant.** Between operations every head lies in
//!   `[2³², 2⁶⁴)`; a freshly constructed coder sits exactly at the lower
//!   bound. [`EntropyCoder::is_pristine`] reports that ground state, and
//!   a full encode→decode cycle must restore it.
//! * **LIFO discipline.** Whole-message encodes and decodes are inverses;
//!   interleaving *partial* encodes and decodes of unrelated data is only
//!   guaranteed for the stack coder (BB-ANS relies on it), not for the
//!   interleaved coder, whose batch striping fixes the schedule.
//! * **Shared precision.** All intervals of one `encode_all`/`decode_all`
//!   call quantize to the same `2^prec` total; `prec ≤` [`MAX_PREC`].

use super::interleaved::{InterleavedAns, Interval};
use super::prepared::PreparedInterval;
use super::{Ans, MAX_PREC};

/// A coder that maps sequences of quantized symbol intervals to bits.
pub trait EntropyCoder {
    /// Encode `intervals` (in stream order) at precision `prec`.
    fn encode_all(&mut self, intervals: &[Interval], prec: u32);

    /// Encode prepared (division-free) symbols in stream order — the hot
    /// path (`crate::ans::prepared`). Every element must be prepared at
    /// precision `prec`. Output is byte-identical to [`Self::encode_all`]
    /// on the corresponding intervals; the default implementation proves
    /// it by falling back to that path.
    fn encode_all_prepared(&mut self, prepared: &[PreparedInterval], prec: u32) {
        let ivs: Vec<Interval> = prepared.iter().map(|p| p.interval()).collect();
        self.encode_all(&ivs, prec);
    }

    /// Decode `n` symbols in stream order. `lookup` maps each position's
    /// cumulative value to `(symbol, interval)` and is called exactly once
    /// per position, in order — stateful closures may rely on that.
    fn decode_all<S>(
        &mut self,
        n: usize,
        prec: u32,
        lookup: impl FnMut(u32) -> (S, Interval),
    ) -> Vec<S>;

    /// Message length in bits if serialized right now.
    fn bit_len(&self) -> u64;

    /// Is the coder in its ground state (heads at the normalization lower
    /// bound, no stream words, no information)?
    fn is_pristine(&self) -> bool;
}

impl EntropyCoder for Ans {
    fn encode_all(&mut self, intervals: &[Interval], prec: u32) {
        debug_assert!(prec <= MAX_PREC);
        // Stack discipline: push back-to-front so pops yield stream order.
        for iv in intervals.iter().rev() {
            self.push(iv.start, iv.freq, prec);
        }
    }

    fn encode_all_prepared(&mut self, prepared: &[PreparedInterval], prec: u32) {
        debug_assert!(prec <= MAX_PREC);
        for p in prepared.iter().rev() {
            debug_assert_eq!(p.prec(), prec, "mixed-precision prepared batch");
            self.push_prepared(p);
        }
    }

    fn decode_all<S>(
        &mut self,
        n: usize,
        prec: u32,
        mut lookup: impl FnMut(u32) -> (S, Interval),
    ) -> Vec<S> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let cf = self.pop_cf(prec);
            let (sym, iv) = lookup(cf);
            self.update(iv.start, iv.freq, prec);
            out.push(sym);
        }
        out
    }

    fn bit_len(&self) -> u64 {
        Ans::bit_len(self)
    }

    fn is_pristine(&self) -> bool {
        self.is_empty()
    }
}

impl<const N: usize> EntropyCoder for InterleavedAns<N> {
    fn encode_all(&mut self, intervals: &[Interval], prec: u32) {
        InterleavedAns::encode(self, intervals, prec)
    }

    fn encode_all_prepared(&mut self, prepared: &[PreparedInterval], prec: u32) {
        debug_assert!(prec <= MAX_PREC);
        debug_assert!(prepared.iter().all(|p| p.prec() == prec));
        InterleavedAns::encode_prepared(self, prepared)
    }

    fn decode_all<S>(
        &mut self,
        n: usize,
        prec: u32,
        lookup: impl FnMut(u32) -> (S, Interval),
    ) -> Vec<S> {
        InterleavedAns::decode(self, n, prec, lookup)
    }

    fn bit_len(&self) -> u64 {
        InterleavedAns::bit_len(self)
    }

    fn is_pristine(&self) -> bool {
        InterleavedAns::is_pristine(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn geometric_intervals(prec: u32, k: usize) -> Vec<Interval> {
        let total = 1u64 << prec;
        let raw: Vec<u64> = (0..k).map(|i| 1u64 << (i % 30)).collect();
        let s: u64 = raw.iter().sum();
        let mut freqs: Vec<u32> = raw
            .iter()
            .map(|&r| ((r * (total - k as u64)) / s + 1) as u32)
            .collect();
        let fix = total as i64 - freqs.iter().map(|&f| f as i64).sum::<i64>();
        let last = freqs.len() - 1;
        freqs[last] = (freqs[last] as i64 + fix) as u32;
        let mut start = 0u32;
        freqs
            .into_iter()
            .map(|f| {
                let iv = Interval { start, freq: f };
                start += f;
                iv
            })
            .collect()
    }

    fn lookup(cf: u32, d: &[Interval]) -> usize {
        d.iter()
            .position(|iv| cf >= iv.start && cf < iv.start + iv.freq)
            .unwrap()
    }

    fn roundtrip_generic<C: EntropyCoder>(coder: &mut C, n: usize, seed: u64) {
        let prec = 14;
        let d = geometric_intervals(prec, 10);
        let mut rng = Rng::new(seed);
        let syms: Vec<usize> = (0..n).map(|_| rng.below(10) as usize).collect();
        let ivs: Vec<Interval> = syms.iter().map(|&s| d[s]).collect();
        assert!(coder.is_pristine());
        coder.encode_all(&ivs, prec);
        assert!(coder.bit_len() >= 64);
        let got = coder.decode_all(n, prec, |cf| {
            let s = lookup(cf, &d);
            (s, d[s])
        });
        assert_eq!(got, syms);
        assert!(coder.is_pristine(), "coder must return to ground state");
    }

    #[test]
    fn trait_roundtrip_stack_and_interleaved() {
        roundtrip_generic(&mut Ans::new(0), 5000, 1);
        roundtrip_generic(&mut InterleavedAns::<1>::new(), 5000, 2);
        roundtrip_generic(&mut InterleavedAns::<4>::new(), 4999, 3);
        roundtrip_generic(&mut InterleavedAns::<8>::new(), 5001, 4);
    }

    #[test]
    fn prepared_trait_path_matches_interval_path() {
        use crate::ans::SymbolTable;
        let prec = 14;
        let d = geometric_intervals(prec, 10);
        let syms: Vec<usize> = (0..3001).map(|i| (i * 13 + 5) % 10).collect();
        let ivs: Vec<Interval> = syms.iter().map(|&s| d[s]).collect();
        let table = SymbolTable::from_intervals(&d, prec);
        let mut prepared = Vec::new();
        table.gather_into(&syms, &mut prepared);

        let mut a = Ans::new(0);
        a.encode_all(&ivs, prec);
        let mut b = Ans::new(0);
        b.encode_all_prepared(&prepared, prec);
        assert_eq!(a.to_message(), b.to_message(), "stack coder bytes drifted");
        let got = b.decode_all(syms.len(), prec, |cf| {
            let s = lookup(cf, &d);
            (s, d[s])
        });
        assert_eq!(got, syms);
        assert!(b.is_pristine());

        let mut ia = InterleavedAns::<4>::new();
        ia.encode_all(&ivs, prec);
        let mut ib = InterleavedAns::<4>::new();
        ib.encode_all_prepared(&prepared, prec);
        assert_eq!(ia, ib, "interleaved coder state drifted");
    }

    #[test]
    fn stream_order_is_decode_order_for_both_coders() {
        // The same interval sequence must come back in the same order from
        // every implementation — that's what lets callers swap coders.
        let prec = 12;
        let d = geometric_intervals(prec, 6);
        let seq: Vec<usize> = (0..100).map(|i| (i * 7 + 3) % 6).collect();
        let ivs: Vec<Interval> = seq.iter().map(|&s| d[s]).collect();

        let mut a = Ans::new(0);
        a.encode_all(&ivs, prec);
        let from_stack = a.decode_all(seq.len(), prec, |cf| {
            let s = lookup(cf, &d);
            (s, d[s])
        });

        let mut il = InterleavedAns::<4>::new();
        il.encode_all(&ivs, prec);
        let from_lanes = il.decode_all(seq.len(), prec, |cf| {
            let s = lookup(cf, &d);
            (s, d[s])
        });

        assert_eq!(from_stack, seq);
        assert_eq!(from_lanes, seq);
    }

    #[test]
    fn rates_agree_up_to_head_overhead() {
        let prec = 14;
        let d = geometric_intervals(prec, 16);
        let mut rng = Rng::new(9);
        let ivs: Vec<Interval> = (0..50_000)
            .map(|_| d[lookup(rng.below(1 << prec) as u32, &d)])
            .collect();
        let mut a = Ans::new(0);
        a.encode_all(&ivs, prec);
        let mut il = InterleavedAns::<8>::new();
        il.encode_all(&ivs, prec);
        let diff = il.bit_len() as i64 - a.bit_len() as i64;
        // Interleaving pays only for the 7 extra 64-bit heads (±1 word of
        // renormalization slack per lane).
        assert!(diff.abs() <= 64 * 8 + 32 * 8, "head overhead too large: {diff}");
    }
}
