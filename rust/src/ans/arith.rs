//! Arithmetic (range) coding — the FIFO coder BB-ANS replaces.
//!
//! Paper §2.3: bits-back chaining *can* be done with AC (Frey 1997) but
//! needs a stack-like wrapper and, critically, a coder **flush between
//! every chaining step**, costing implementation-dependent bits per
//! image. This module implements a classic byte-oriented range coder
//! (Subbotin style) so `benches/ablations.rs` can measure that flush
//! overhead directly against ANS's zero-overhead chaining.
//!
//! The coder codes symbols as `(start, freq)` intervals out of `2^prec`,
//! the same quantized distributions the ANS codecs use, so rate
//! differences are purely coder-structural.

/// Range-coder encoder. FIFO: symbols decode in encode order.
#[derive(Debug)]
pub struct ArithEncoder {
    low: u64,
    range: u64,
    out: Vec<u8>,
}

const TOP: u64 = 1 << 24;

impl Default for ArithEncoder {
    fn default() -> Self {
        Self::new()
    }
}

impl ArithEncoder {
    pub fn new() -> Self {
        Self {
            low: 0,
            range: u32::MAX as u64,
            out: Vec::new(),
        }
    }

    #[inline]
    fn normalize(&mut self) {
        // Emit bytes while the top byte is settled (no carry possible) or
        // the range has shrunk below the renormalization threshold.
        while (self.low ^ (self.low + self.range)) < TOP || self.range < (1 << 16) {
            if (self.low ^ (self.low + self.range)) >= TOP {
                // Force range to the remaining span below the boundary.
                self.range = (!self.low & 0xffff) + 1;
            }
            self.out.push((self.low >> 24) as u8);
            self.low = (self.low << 8) & 0xffff_ffff;
            self.range = (self.range << 8).min(u32::MAX as u64 - self.low);
        }
    }

    /// Encode a symbol occupying `[start, start+freq)` of `2^prec`.
    pub fn encode(&mut self, start: u32, freq: u32, prec: u32) {
        debug_assert!(freq > 0);
        let total = 1u64 << prec;
        let r = self.range / total;
        self.low += r * start as u64;
        self.range = r * freq as u64;
        self.normalize();
    }

    /// Flush the coder so the stream is decodable; returns the finished
    /// bytes. This is the per-chaining-step cost the paper's §2.3 talks
    /// about: 4 bytes here.
    pub fn finish(mut self) -> Vec<u8> {
        for _ in 0..4 {
            self.out.push((self.low >> 24) as u8);
            self.low = (self.low << 8) & 0xffff_ffff;
        }
        self.out
    }

    /// Current length in bits if finished now.
    pub fn bit_len_with_flush(&self) -> usize {
        (self.out.len() + 4) * 8
    }
}

/// Range-coder decoder.
#[derive(Debug)]
pub struct ArithDecoder<'a> {
    low: u64,
    range: u64,
    code: u64,
    input: &'a [u8],
    pos: usize,
}

impl<'a> ArithDecoder<'a> {
    pub fn new(input: &'a [u8]) -> Self {
        let mut d = Self {
            low: 0,
            range: u32::MAX as u64,
            code: 0,
            input,
            pos: 0,
        };
        for _ in 0..4 {
            d.code = (d.code << 8) | d.next_byte() as u64;
        }
        d
    }

    #[inline]
    fn next_byte(&mut self) -> u8 {
        let b = self.input.get(self.pos).copied().unwrap_or(0);
        self.pos += 1;
        b
    }

    #[inline]
    fn normalize(&mut self) {
        while (self.low ^ (self.low + self.range)) < TOP || self.range < (1 << 16) {
            if (self.low ^ (self.low + self.range)) >= TOP {
                self.range = (!self.low & 0xffff) + 1;
            }
            self.code = ((self.code << 8) | self.next_byte() as u64) & 0xffff_ffff;
            self.low = (self.low << 8) & 0xffff_ffff;
            self.range = (self.range << 8).min(u32::MAX as u64 - self.low);
        }
    }

    /// Cumulative value of the next symbol (then call [`Self::consume`]).
    pub fn peek_cf(&self, prec: u32) -> u32 {
        let total = 1u64 << prec;
        let r = self.range / total;
        (((self.code - self.low) / r).min(total - 1)) as u32
    }

    pub fn consume(&mut self, start: u32, freq: u32, prec: u32) {
        let total = 1u64 << prec;
        let r = self.range / total;
        self.low += r * start as u64;
        self.range = r * freq as u64;
        self.normalize();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::quantize::QuantizedCdf;
    use crate::util::rng::Rng;

    fn dist(seed: u64, k: usize, prec: u32) -> QuantizedCdf {
        let mut rng = Rng::new(seed);
        let pmf: Vec<f64> = (0..k).map(|_| rng.f64() + 1e-6).collect();
        QuantizedCdf::from_pmf(&pmf, prec)
    }

    #[test]
    fn roundtrip_fifo_order() {
        let prec = 16;
        let q = dist(1, 40, prec);
        let mut rng = Rng::new(2);
        let syms: Vec<usize> = (0..20_000).map(|_| rng.below(40) as usize).collect();
        let mut enc = ArithEncoder::new();
        for &s in &syms {
            enc.encode(q.start(s), q.freq(s), prec);
        }
        let bytes = enc.finish();
        let mut dec = ArithDecoder::new(&bytes);
        for &s in &syms {
            // FIFO: first encoded, first decoded.
            let cf = dec.peek_cf(prec);
            let got = q.lookup(cf);
            assert_eq!(got, s);
            dec.consume(q.start(got), q.freq(got), prec);
        }
    }

    #[test]
    fn rate_near_entropy() {
        let prec = 14;
        let q = dist(3, 16, prec);
        let mut rng = Rng::new(4);
        let n = 100_000;
        let syms: Vec<usize> = (0..n)
            .map(|_| q.lookup(rng.below(1 << prec) as u32))
            .collect();
        let entropy = q.entropy();
        let mut enc = ArithEncoder::new();
        for &s in &syms {
            enc.encode(q.start(s), q.freq(s), prec);
        }
        let bits = enc.finish().len() as f64 * 8.0;
        let rate = bits / n as f64;
        assert!(
            (rate - entropy).abs() / entropy < 0.01,
            "rate {rate} vs entropy {entropy}"
        );
    }

    #[test]
    fn per_flush_overhead_is_constant_bytes() {
        // Encoding N segments with a flush each costs ~4 bytes extra per
        // segment vs one stream — the §2.3 chaining overhead.
        let prec = 14;
        let q = dist(5, 16, prec);
        let mut rng = Rng::new(6);
        let syms: Vec<usize> = (0..5000)
            .map(|_| q.lookup(rng.below(1 << prec) as u32))
            .collect();

        let mut one = ArithEncoder::new();
        for &s in &syms {
            one.encode(q.start(s), q.freq(s), prec);
        }
        let single = one.finish().len();

        let mut segmented = 0usize;
        for chunk in syms.chunks(100) {
            let mut enc = ArithEncoder::new();
            for &s in chunk {
                enc.encode(q.start(s), q.freq(s), prec);
            }
            segmented += enc.finish().len();
        }
        let n_segments = syms.len() / 100;
        let overhead_per_segment = (segmented - single) as f64 / n_segments as f64;
        assert!(
            (2.0..=6.0).contains(&overhead_per_segment),
            "expected a few bytes per flush, got {overhead_per_segment}"
        );
    }
}
