//! L3 coordinator: the serving system around the BB-ANS codec.
//!
//! * [`batcher`] — the model-worker thread + dynamic batcher: NN work from
//!   concurrent compression/decompression streams is batched into shared
//!   PJRT dispatches (paper §4.2's parallelization argument, realized);
//! * [`server`] — framed-TCP front end feeding the batcher;
//! * [`protocol`] — the wire format;
//! * [`metrics`] — counters + latency histograms exported as JSON.
//!
//! Built on std threads/channels only (tokio is unavailable offline, and
//! the workload — few long-lived connections, CPU-bound coding — doesn't
//! need an async reactor).

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use batcher::{ModelService, ServiceHandle, ServiceParams, SharedBackend};
pub use server::{Client, Server};
