//! L3 coordinator: the serving system around the BB-ANS codec.
//!
//! * [`batcher`] — the model-worker thread + dynamic batcher: NN work from
//!   concurrent compression/decompression streams is batched into shared
//!   PJRT dispatches (paper §4.2's parallelization argument, realized),
//!   behind a bounded admission queue with deadline-based flushing;
//! * [`executor`] — the phase executor the batch loops are generic over:
//!   serial (one exclusive backend) or pooled (persistent worker pool
//!   sharding NN rows and per-stream coder work);
//! * [`server`] — framed-TCP front end feeding the batcher;
//! * [`protocol`] — the wire format;
//! * [`metrics`] — counters + latency histograms exported as JSON.
//!
//! Built on std threads/channels only (tokio is unavailable offline, and
//! the workload — few long-lived connections, CPU-bound coding — doesn't
//! need an async reactor).
//!
//! The tier is built to contain faults, not just detect them: a backend
//! panic fails only its execution unit (the worker survives and the
//! supervisor quarantines repeat offenders), queued jobs past their TTL
//! are shed before any NN dispatch, health probes are answered
//! handle-side so they work while the service is sick, and servers drain
//! gracefully. See `batcher.rs` ("Fault containment"), the README's
//! "Serving failure model" table, and `tests/chaos.rs` for the seeded
//! campaigns that prove each blast radius.

pub mod batcher;
pub mod executor;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use batcher::{ModelService, ServiceHandle, ServiceParams, SharedBackend};
pub use protocol::{FetchedPage, HierSpec};
pub use server::{Client, PageRange, PageStore, RetryPolicy, Server};
