//! Phase executors for the coordinator's lock-step batch loops.
//!
//! PR 5 left the batcher with twin ~400-line encode/decode loops — a
//! serial pair for `Box<dyn Backend>` maps (PJRT handles are neither
//! `Send` nor `Sync`) and a `_fanout` pair for `Sync` backends that
//! spawned fresh scoped threads *per phase*. This module collapses that
//! split: one [`PhaseExecutor`] trait abstracts the two things a
//! lock-step round actually does —
//!
//! 1. batched NN dispatches ([`PhaseExecutor::nn_posterior`] /
//!    [`PhaseExecutor::nn_likelihood`]), and
//! 2. per-stream ANS work fanned across the active streams
//!    ([`PhaseExecutor::each_stream`]) —
//!
//! with a [`SerialExecutor`] that runs everything inline on the worker
//! thread and a [`PooledExecutor`] that shards NN rows and stream slabs
//! over a **persistent** [`PhasePool`] (threads spawned once per
//! service, parked between phases on a condvar — no per-phase spawn
//! cost, no per-round thread churn).
//!
//! Bit-identity contract: every NN dispatch is row-independent (row `r`
//! of the output depends only on row `r` of the input — pinned by
//! `sharded_batches_match_unsharded_bitwise`), every stream's coder
//! state is independent, and callers read results back in slice order.
//! So the executor choice and the pool width are unobservable in the
//! container bytes; `sync_service_bytes_match_serial_service` pins this
//! end to end.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::model::tensor::Matrix;
use crate::model::{row_shards, shard_matrix, Backend, PixelParams, PosteriorBatch};

/// One lock-step round's execution strategy: how NN dispatches run and
/// how per-stream ANS work is scheduled. Implementations must keep the
/// bit-identity contract in the module docs — callers assume the bytes
/// do not depend on which executor (or pool width) ran the round.
pub(crate) trait PhaseExecutor {
    /// One batched recognition-net dispatch over `xs` (`[B, pixels]`).
    fn nn_posterior(&self, xs: &Matrix) -> Result<PosteriorBatch>;

    /// One batched generative-net dispatch over `ys` (`[B, latent]`).
    fn nn_likelihood(&self, ys: &Matrix) -> Result<Vec<PixelParams>>;

    /// Run `f` over every stream of a phase. Implementations may reorder
    /// or parallelize the calls — stream states are independent and the
    /// caller reads results back in slice order, so the schedule never
    /// shows in the output.
    fn each_stream<T: Send>(&self, items: &mut [T], f: impl Fn(&mut T) + Sync);
}

/// Inline executor for thread-bound backends: every dispatch and every
/// stream runs on the calling (worker) thread.
pub(crate) struct SerialExecutor<'a> {
    pub backend: &'a dyn Backend,
}

impl PhaseExecutor for SerialExecutor<'_> {
    fn nn_posterior(&self, xs: &Matrix) -> Result<PosteriorBatch> {
        self.backend.encode_batch(xs)
    }

    fn nn_likelihood(&self, ys: &Matrix) -> Result<Vec<PixelParams>> {
        self.backend.decode_batch(ys)
    }

    fn each_stream<T: Send>(&self, items: &mut [T], f: impl Fn(&mut T) + Sync) {
        for it in items {
            f(it);
        }
    }
}

/// Pool-backed executor for `Sync` backends: NN dispatches are sharded
/// by row across the pool lanes and stitched back in shard order; stream
/// work is slabbed across lanes. Same observable behavior as
/// [`SerialExecutor`], by the module-level contract.
pub(crate) struct PooledExecutor<'a> {
    pub backend: &'a (dyn Backend + Send + Sync),
    pub pool: &'a PhasePool,
}

impl PhaseExecutor for PooledExecutor<'_> {
    fn nn_posterior(&self, xs: &Matrix) -> Result<PosteriorBatch> {
        let shards = row_shards(xs.rows, self.pool.lanes());
        if shards.len() <= 1 {
            return self.backend.encode_batch(xs);
        }
        let parts: Vec<Mutex<Option<Result<PosteriorBatch>>>> =
            shards.iter().map(|_| Mutex::new(None)).collect();
        self.pool.run(shards.len(), &|i| {
            let sub = shard_matrix(xs, &shards[i]);
            *parts[i].lock().expect("shard slot") = Some(self.backend.encode_batch(&sub));
        });
        let l = self.backend.meta().latent_dim;
        let mut mu = Vec::with_capacity(xs.rows * l);
        let mut sigma = Vec::with_capacity(xs.rows * l);
        for slot in parts {
            let p = slot.into_inner().expect("shard lock").expect("shard ran")?;
            mu.extend_from_slice(&p.mu.data);
            sigma.extend_from_slice(&p.sigma.data);
        }
        Ok(PosteriorBatch {
            mu: Matrix::new(xs.rows, l, mu),
            sigma: Matrix::new(xs.rows, l, sigma),
        })
    }

    fn nn_likelihood(&self, ys: &Matrix) -> Result<Vec<PixelParams>> {
        let shards = row_shards(ys.rows, self.pool.lanes());
        if shards.len() <= 1 {
            return self.backend.decode_batch(ys);
        }
        let parts: Vec<Mutex<Option<Result<Vec<PixelParams>>>>> =
            shards.iter().map(|_| Mutex::new(None)).collect();
        self.pool.run(shards.len(), &|i| {
            let sub = shard_matrix(ys, &shards[i]);
            *parts[i].lock().expect("shard slot") = Some(self.backend.decode_batch(&sub));
        });
        let mut out = Vec::with_capacity(ys.rows);
        for slot in parts {
            out.extend(slot.into_inner().expect("shard lock").expect("shard ran")?);
        }
        Ok(out)
    }

    fn each_stream<T: Send>(&self, items: &mut [T], f: impl Fn(&mut T) + Sync) {
        self.pool.each(items, f);
    }
}

/// A task published to the pool for one phase. The `'static` is a lie
/// told under supervision: [`PhasePool::run`] erases the caller's
/// lifetime and is responsible for clearing the slot (behind its
/// barrier) before the real borrow ends.
type Task = &'static (dyn Fn(usize) + Sync);

struct Slot {
    task: Option<Task>,
    n_jobs: usize,
    next: usize,
    done_jobs: usize,
    panic: Option<Box<dyn Any + Send>>,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Signaled when a new phase is published (or on shutdown).
    go: Condvar,
    /// Signaled when the last job of a phase completes.
    done: Condvar,
}

/// Persistent worker pool with phase barriers: `workers - 1` threads are
/// spawned once and parked on a condvar; each [`PhasePool::run`] wakes
/// them, dispenses job indices from a shared counter, and returns only
/// after all jobs finished (the barrier). The caller is the remaining
/// lane — it helps drain the job queue instead of blocking, so
/// `lanes() == workers` and a 1-worker pool has no threads at all.
///
/// A panic inside a job is caught on whichever lane ran it (workers stay
/// alive for the next phase), stashed, and re-raised on the caller after
/// the barrier — so a poisoned request round cannot wedge or kill the
/// service thread's pool.
pub(crate) struct PhasePool {
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
}

impl PhasePool {
    pub(crate) fn new(workers: usize) -> Self {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                task: None,
                n_jobs: 0,
                next: 0,
                done_jobs: 0,
                panic: None,
                shutdown: false,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
        });
        let threads = (1..workers.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("bbans-phase-{i}"))
                    .spawn(move || worker(shared))
                    .expect("spawn phase-pool worker")
            })
            .collect();
        Self { shared, threads }
    }

    /// Concurrency width: pool threads plus the calling lane.
    pub(crate) fn lanes(&self) -> usize {
        self.threads.len() + 1
    }

    /// Run `f(0..n_jobs)` across the lanes and barrier until every job
    /// has finished. Panics in `f` propagate to the caller; the pool
    /// stays usable. Not reentrant (a job must not call `run`).
    ///
    /// The re-raised panic is not the end of the line: the worker loop
    /// runs every execution unit under its own `catch_unwind` (see
    /// `batcher.rs`, "Fault containment"), so a phase-job panic fails
    /// that unit's requests and the worker keeps serving.
    pub(crate) fn run(&self, n_jobs: usize, f: &(dyn Fn(usize) + Sync)) {
        if self.threads.is_empty() || n_jobs <= 1 {
            for i in 0..n_jobs {
                f(i);
            }
            return;
        }
        // SAFETY: the erased borrow is published to workers only for the
        // duration of this call — the barrier below does not return
        // until `done_jobs == n_jobs`, and the lane that finishes the
        // last job clears the task slot before signaling, so no worker
        // can hold or re-dispense the pointer once `run` returns.
        let task = unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), Task>(f) };
        {
            let mut slot = self.shared.slot.lock().expect("phase-pool lock");
            debug_assert!(slot.task.is_none(), "PhasePool::run is not reentrant");
            slot.task = Some(task);
            slot.n_jobs = n_jobs;
            slot.next = 0;
            slot.done_jobs = 0;
            slot.panic = None;
        }
        self.shared.go.notify_all();

        // The caller is a lane too: help drain, then wait out the tail.
        let mut slot = self.shared.slot.lock().expect("phase-pool lock");
        loop {
            if slot.next < slot.n_jobs {
                let i = slot.next;
                slot.next += 1;
                drop(slot);
                let r = catch_unwind(AssertUnwindSafe(|| f(i)));
                slot = self.shared.slot.lock().expect("phase-pool lock");
                finish_job(&self.shared, &mut slot, r);
            } else if slot.done_jobs < slot.n_jobs {
                slot = self.shared.done.wait(slot).expect("phase-pool lock");
            } else {
                break;
            }
        }
        let payload = slot.panic.take();
        drop(slot);
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }

    /// Run `f` once per item, slabbing `items` near-evenly across the
    /// lanes (the same split the old per-phase `par_each` used, so slab
    /// shapes — and with them, nothing observable — are unchanged).
    pub(crate) fn each<T: Send>(&self, items: &mut [T], f: impl Fn(&mut T) + Sync) {
        let per = items.len().div_ceil(self.lanes()).max(1);
        if self.threads.is_empty() || items.len() <= 1 || per >= items.len() {
            for it in items {
                f(it);
            }
            return;
        }
        let slabs: Vec<Mutex<&mut [T]>> = items.chunks_mut(per).map(Mutex::new).collect();
        self.run(slabs.len(), &|i| {
            let mut slab = slabs[i].lock().expect("slab slot");
            for it in slab.iter_mut() {
                f(it);
            }
        });
    }
}

impl Drop for PhasePool {
    fn drop(&mut self) {
        self.shared.slot.lock().expect("phase-pool lock").shutdown = true;
        self.shared.go.notify_all();
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

/// Mark one job finished; the lane that completes the phase clears the
/// task slot (ending the erased borrow) and wakes the barrier.
fn finish_job(shared: &Shared, slot: &mut Slot, r: std::thread::Result<()>) {
    if let Err(payload) = r {
        if slot.panic.is_none() {
            slot.panic = Some(payload);
        }
    }
    slot.done_jobs += 1;
    if slot.done_jobs == slot.n_jobs {
        slot.task = None;
        shared.done.notify_all();
    }
}

fn worker(shared: Arc<Shared>) {
    let mut slot = shared.slot.lock().expect("phase-pool lock");
    loop {
        if slot.shutdown {
            return;
        }
        let job = match slot.task {
            Some(task) if slot.next < slot.n_jobs => {
                let i = slot.next;
                slot.next += 1;
                Some((task, i))
            }
            _ => None,
        };
        match job {
            Some((task, i)) => {
                drop(slot);
                let r = catch_unwind(AssertUnwindSafe(|| task(i)));
                slot = shared.slot.lock().expect("phase-pool lock");
                finish_job(&shared, &mut slot, r);
            }
            None => slot = shared.go.wait(slot).expect("phase-pool lock"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vae::NativeVae;
    use crate::model::{Likelihood, ModelMeta};
    use crate::util::rng::Rng;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_every_index_exactly_once_across_reuse() {
        let pool = PhasePool::new(4);
        for round in 0..3 {
            let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
            pool.run(hits.len(), &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "round {round} job {i}");
            }
        }
    }

    #[test]
    fn each_visits_every_item_at_every_width() {
        for workers in [1usize, 2, 3, 8] {
            let pool = PhasePool::new(workers);
            let mut items: Vec<u64> = (0..17).collect();
            pool.each(&mut items, |v| *v += 100);
            assert_eq!(items, (100..117).collect::<Vec<u64>>(), "workers={workers}");
        }
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let pool = PhasePool::new(3);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // The pool must stay usable for the next phase.
        let n = AtomicUsize::new(0);
        pool.run(8, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn pooled_nn_dispatches_match_serial_bitwise() {
        let meta = ModelMeta {
            name: "x".into(),
            pixels: 24,
            latent_dim: 5,
            hidden: 9,
            likelihood: Likelihood::Bernoulli,
            test_elbo_bpd: f64::NAN,
        };
        let vae = NativeVae::random(meta, 11);
        let mut rng = Rng::new(7);
        let xs = Matrix::new(13, 24, (0..13 * 24).map(|_| rng.f64() as f32).collect());
        let ys = Matrix::new(13, 5, (0..13 * 5).map(|_| rng.f64() as f32 - 0.5).collect());
        let serial = SerialExecutor { backend: &vae };
        let want_post = serial.nn_posterior(&xs).unwrap();
        let want_like = serial.nn_likelihood(&ys).unwrap();
        for workers in [1usize, 2, 5] {
            let pool = PhasePool::new(workers);
            let exec = PooledExecutor {
                backend: &vae,
                pool: &pool,
            };
            let got = exec.nn_posterior(&xs).unwrap();
            assert_eq!(got.mu.data, want_post.mu.data, "workers={workers}");
            assert_eq!(got.sigma.data, want_post.sigma.data, "workers={workers}");
            let got = exec.nn_likelihood(&ys).unwrap();
            assert_eq!(got.len(), want_like.len());
            for (g, w) in got.iter().zip(&want_like) {
                match (g, w) {
                    (PixelParams::Bernoulli(a), PixelParams::Bernoulli(b)) => assert_eq!(a, b),
                    other => panic!("unexpected params {other:?}"),
                }
            }
        }
    }
}
