//! Service metrics: atomic counters and log-bucketed latency histograms,
//! exported as JSON over the stats endpoint and as Prometheus text
//! (exposition format 0.0.4) over the metrics wire op / scrape listener.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::obs::prom::PromWriter;
use crate::util::json::Json;

/// Log₂-bucketed latency histogram: bucket i covers [2^i, 2^(i+1)) µs.
pub const BUCKETS: usize = 32;

#[derive(Debug, Default)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    n: AtomicU64,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.counts[bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    /// Approximate quantile from the bucket histogram: the **geometric
    /// midpoint** `2^i·√2` of the bucket `[2^i, 2^(i+1))` containing the
    /// quantile — the unbiased point estimate for log-spaced buckets.
    /// (The upper bound `2^(i+1)` this used to return overstates p50/p99
    /// by up to 2×.)
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let target = ((q * n as f64).ceil() as u64).max(1);
        let midpoint =
            |i: usize| Duration::from_secs_f64((1u64 << i) as f64 * std::f64::consts::SQRT_2 / 1e6);
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return midpoint(i);
            }
        }
        midpoint(BUCKETS - 1)
    }

    /// Relaxed snapshot of the per-bucket counts (for exposition).
    pub fn bucket_counts(&self) -> [u64; BUCKETS] {
        let mut out = [0u64; BUCKETS];
        for (o, c) in out.iter_mut().zip(self.counts.iter()) {
            *o = c.load(Ordering::Relaxed);
        }
        out
    }

    /// Sum of observed values, µs.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("mean_us", Json::Num(self.mean().as_micros() as f64)),
            (
                "p50_us",
                Json::Num(self.quantile(0.5).as_micros() as f64),
            ),
            (
                "p99_us",
                Json::Num(self.quantile(0.99).as_micros() as f64),
            ),
        ])
    }
}

/// All coordinator metrics.
#[derive(Debug)]
pub struct Metrics {
    /// Construction instant — the uptime reference stats/health report.
    started: Instant,
    pub requests: AtomicU64,
    pub images_encoded: AtomicU64,
    pub images_decoded: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    pub nn_calls: AtomicU64,
    pub nn_items: AtomicU64,
    pub errors: AtomicU64,
    /// Jobs refused at admission because the bounded queue was full.
    pub rejected: AtomicU64,
    /// Malformed frames seen by the server's connection handlers.
    pub protocol_errors: AtomicU64,
    /// Lock-step batch rounds the worker has run.
    pub rounds: AtomicU64,
    /// Execution units (a model's round group / one container decode)
    /// that panicked and were contained by the worker's supervisor.
    pub panics: AtomicU64,
    /// Jobs shed at round formation because their deadline passed while
    /// queued — no NN work was spent on them.
    pub expired: AtomicU64,
    /// Set once the model-worker thread has exited, on EVERY exit path
    /// (clean shutdown, channel drop, or an uncontained panic unwinding
    /// the thread) — the liveness bit health probes read. Stored
    /// inverted so the zero-initialized default means "alive".
    pub worker_dead: AtomicBool,
    /// Worker wakeup epoch: bumped every time the worker starts a round,
    /// so two spaced health probes can tell a live-but-idle worker from a
    /// wedged one under traffic.
    pub heartbeat: AtomicU64,
    /// Quarantined execution keys (model names / rebuilt-header keys):
    /// requests for them fast-fail instead of re-panicking forever.
    /// Cleared only by restarting the service.
    pub quarantined: Mutex<BTreeSet<String>>,
    /// Gauge: jobs admitted but not yet drained into a round.
    pub queue_depth: AtomicU64,
    pub batch_latency: Histogram,
    pub request_latency: Histogram,
    /// Admission-to-drain wait per job (the queueing half of latency).
    pub queue_wait: Histogram,
    /// Per-phase NN dispatch time inside a round.
    pub phase_nn: Histogram,
    /// Per-phase ANS (per-stream coder) time inside a round.
    pub phase_ans: Histogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            requests: AtomicU64::new(0),
            images_encoded: AtomicU64::new(0),
            images_decoded: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            nn_calls: AtomicU64::new(0),
            nn_items: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            protocol_errors: AtomicU64::new(0),
            rounds: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            worker_dead: AtomicBool::new(false),
            heartbeat: AtomicU64::new(0),
            quarantined: Mutex::new(BTreeSet::new()),
            queue_depth: AtomicU64::new(0),
            batch_latency: Histogram::new(),
            request_latency: Histogram::new(),
            queue_wait: Histogram::new(),
            phase_nn: Histogram::new(),
            phase_ans: Histogram::new(),
        }
    }

    /// Time since this metrics block (≈ the service) was created.
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    pub fn inc(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    /// Decrement a gauge (saturating in practice: pair every `dec` with
    /// an earlier `inc` on the same gauge).
    pub fn dec(gauge: &AtomicU64, by: u64) {
        gauge.fetch_sub(by, Ordering::Relaxed);
    }

    /// Add an execution key to the quarantine set. Idempotent; the set
    /// only ever grows (restart the service to clear it).
    pub fn quarantine(&self, key: &str) {
        self.quarantined
            .lock()
            .expect("quarantine lock poisoned")
            .insert(key.to_string());
    }

    pub fn is_quarantined(&self, key: &str) -> bool {
        self.quarantined
            .lock()
            .expect("quarantine lock poisoned")
            .contains(key)
    }

    /// Sorted copy of the quarantine set (for health/stats snapshots).
    pub fn quarantined_keys(&self) -> Vec<String> {
        self.quarantined
            .lock()
            .expect("quarantine lock poisoned")
            .iter()
            .cloned()
            .collect()
    }

    /// Mean images per NN dispatch — the batching win (1.0 = no batching).
    pub fn mean_batch_size(&self) -> f64 {
        let calls = self.nn_calls.load(Ordering::Relaxed);
        if calls == 0 {
            return 0.0;
        }
        self.nn_items.load(Ordering::Relaxed) as f64 / calls as f64
    }

    pub fn snapshot_json(&self) -> Json {
        Json::obj(vec![
            ("uptime_s", Json::Num(self.uptime().as_secs_f64())),
            ("version", Json::Str(env!("CARGO_PKG_VERSION").to_string())),
            (
                "kernel_id",
                Json::Str(crate::simd::kernel_name().to_string()),
            ),
            (
                "requests",
                Json::Num(self.requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "images_encoded",
                Json::Num(self.images_encoded.load(Ordering::Relaxed) as f64),
            ),
            (
                "images_decoded",
                Json::Num(self.images_decoded.load(Ordering::Relaxed) as f64),
            ),
            (
                "bytes_in",
                Json::Num(self.bytes_in.load(Ordering::Relaxed) as f64),
            ),
            (
                "bytes_out",
                Json::Num(self.bytes_out.load(Ordering::Relaxed) as f64),
            ),
            (
                "nn_calls",
                Json::Num(self.nn_calls.load(Ordering::Relaxed) as f64),
            ),
            (
                "nn_items",
                Json::Num(self.nn_items.load(Ordering::Relaxed) as f64),
            ),
            ("mean_batch_size", Json::Num(self.mean_batch_size())),
            (
                "errors",
                Json::Num(self.errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "rejected",
                Json::Num(self.rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "protocol_errors",
                Json::Num(self.protocol_errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "rounds",
                Json::Num(self.rounds.load(Ordering::Relaxed) as f64),
            ),
            (
                "panics",
                Json::Num(self.panics.load(Ordering::Relaxed) as f64),
            ),
            (
                "expired",
                Json::Num(self.expired.load(Ordering::Relaxed) as f64),
            ),
            (
                "worker_alive",
                Json::Bool(!self.worker_dead.load(Ordering::Relaxed)),
            ),
            (
                "heartbeat",
                Json::Num(self.heartbeat.load(Ordering::Relaxed) as f64),
            ),
            (
                "quarantined",
                Json::Arr(self.quarantined_keys().into_iter().map(Json::Str).collect()),
            ),
            (
                "queue_depth",
                Json::Num(self.queue_depth.load(Ordering::Relaxed) as f64),
            ),
            ("batch_latency", self.batch_latency.to_json()),
            ("request_latency", self.request_latency.to_json()),
            ("queue_wait", self.queue_wait.to_json()),
            ("phase_nn", self.phase_nn.to_json()),
            ("phase_ans", self.phase_ans.to_json()),
        ])
    }

    /// Render every metric as Prometheus exposition text (served by the
    /// `MetricsReq` wire op and the `serve --metrics-addr` scrape
    /// listener). Same fields as [`Self::snapshot_json`], in the
    /// conventional Prometheus shapes: `_total` counters, gauges, and
    /// cumulative `_bucket`/`_sum`/`_count` histogram series in µs.
    pub fn to_prometheus(&self) -> String {
        let mut w = PromWriter::new();
        w.info(
            "bbans_build_info",
            "Build identity of the serving process.",
            &[
                ("version", env!("CARGO_PKG_VERSION")),
                ("kernel", crate::simd::kernel_name()),
            ],
        );
        w.gauge(
            "bbans_uptime_seconds",
            "Seconds since the service started.",
            self.uptime().as_secs_f64(),
        );
        let counters: [(&str, &str, &AtomicU64); 12] = [
            ("bbans_requests_total", "Requests admitted.", &self.requests),
            (
                "bbans_images_encoded_total",
                "Images compressed.",
                &self.images_encoded,
            ),
            (
                "bbans_images_decoded_total",
                "Images decompressed.",
                &self.images_decoded,
            ),
            ("bbans_bytes_in_total", "Payload bytes received.", &self.bytes_in),
            ("bbans_bytes_out_total", "Payload bytes produced.", &self.bytes_out),
            ("bbans_nn_calls_total", "Batched NN dispatches.", &self.nn_calls),
            (
                "bbans_nn_items_total",
                "Images across all NN dispatches.",
                &self.nn_items,
            ),
            ("bbans_errors_total", "Failed jobs.", &self.errors),
            (
                "bbans_rejected_total",
                "Jobs refused at admission (queue full).",
                &self.rejected,
            ),
            (
                "bbans_protocol_errors_total",
                "Malformed frames seen by connection handlers.",
                &self.protocol_errors,
            ),
            ("bbans_rounds_total", "Lock-step batch rounds run.", &self.rounds),
            (
                "bbans_panics_total",
                "Execution units contained after a panic.",
                &self.panics,
            ),
        ];
        for (name, help, c) in counters {
            w.counter(name, help, c.load(Ordering::Relaxed));
        }
        w.counter(
            "bbans_expired_total",
            "Jobs shed at round formation past their deadline.",
            self.expired.load(Ordering::Relaxed),
        );
        w.counter(
            "bbans_heartbeat_total",
            "Worker wakeups (bumped when a round starts).",
            self.heartbeat.load(Ordering::Relaxed),
        );
        w.gauge(
            "bbans_worker_alive",
            "1 while the model-worker thread is running.",
            (!self.worker_dead.load(Ordering::Relaxed)) as u64 as f64,
        );
        w.gauge(
            "bbans_queue_depth",
            "Jobs admitted but not yet drained into a round.",
            self.queue_depth.load(Ordering::Relaxed) as f64,
        );
        w.gauge(
            "bbans_quarantined_keys",
            "Execution keys quarantined after repeated panics.",
            self.quarantined_keys().len() as f64,
        );
        w.gauge(
            "bbans_mean_batch_size",
            "Mean images per NN dispatch.",
            self.mean_batch_size(),
        );
        let hists: [(&str, &str, &Histogram); 5] = [
            (
                "bbans_batch_latency_us",
                "Wall time of one batch round, µs.",
                &self.batch_latency,
            ),
            (
                "bbans_request_latency_us",
                "Admission-to-reply request latency, µs.",
                &self.request_latency,
            ),
            (
                "bbans_queue_wait_us",
                "Admission-to-drain queue wait per job, µs.",
                &self.queue_wait,
            ),
            (
                "bbans_phase_nn_us",
                "Per-phase NN dispatch time inside a round, µs.",
                &self.phase_nn,
            ),
            (
                "bbans_phase_ans_us",
                "Per-phase ANS coder time inside a round, µs.",
                &self.phase_ans,
            ),
        ];
        for (name, help, h) in hists {
            w.log2_histogram(name, help, &h.bucket_counts(), h.sum_us(), h.count());
        }
        let t = crate::obs::tracer();
        w.counter(
            "bbans_trace_spans_recorded_total",
            "Spans recorded by the request tracer.",
            t.recorded(),
        );
        w.counter(
            "bbans_trace_spans_dropped_total",
            "Spans overwritten by trace-ring wraparound.",
            t.dropped(),
        );
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..20 {
                h.observe(Duration::from_micros(us));
            }
        }
        assert_eq!(h.count(), 100);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.mean() > Duration::from_micros(1000));
    }

    /// Regression (ISSUE 9 satellite): `quantile` must return a point
    /// *inside* the bucket holding the quantile — the geometric midpoint
    /// `2^i·√2` — not the bucket's upper bound `2^(i+1)`, which
    /// overstated p50/p99 by up to 2×.
    #[test]
    fn quantile_is_geometric_midpoint_within_bucket_bounds() {
        let h = Histogram::new();
        // All mass in bucket 10: [1024, 2048) µs.
        for _ in 0..100 {
            h.observe(Duration::from_micros(1500));
        }
        for q in [0.01, 0.5, 0.99] {
            let v = h.quantile(q).as_secs_f64() * 1e6;
            assert!(
                v >= 1024.0 && v < 2048.0,
                "q={q}: {v}µs escapes its bucket [1024, 2048)"
            );
            let mid = 1024.0 * std::f64::consts::SQRT_2;
            assert!((v - mid).abs() < 1.0, "q={q}: {v}µs is not the midpoint {mid}µs");
            // Strictly below the old upper-bound answer.
            assert!(v < 2048.0);
        }
        // Monotone in q across a multi-bucket distribution.
        let h = Histogram::new();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..20 {
                h.observe(Duration::from_micros(us));
            }
        }
        let mut last = Duration::ZERO;
        for q in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99] {
            let v = h.quantile(q);
            assert!(v >= last, "quantile must be monotone in q");
            last = v;
        }
        // p50 of this distribution sits in bucket [512, 1024).
        let p50 = h.quantile(0.5).as_secs_f64() * 1e6;
        assert!(p50 >= 512.0 && p50 < 1024.0, "p50 {p50}µs");
    }

    /// Concurrency hammer (ISSUE 9 satellite): N writer threads observe
    /// and bump counters while a reader snapshots — no observation may be
    /// lost or double-counted, and every snapshot must be internally
    /// sane (bucket sum ≤ count at all times, equal at quiescence).
    #[test]
    fn concurrent_hammer_conserves_totals() {
        const WRITERS: usize = 8;
        const PER_WRITER: u64 = 10_000;
        let m = std::sync::Arc::new(Metrics::new());
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let reader = {
            let m = m.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let total = WRITERS as u64 * PER_WRITER;
                let mut snaps = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    // Mid-flight snapshots must never overshoot the final
                    // totals (relaxed counters only ever add) and must
                    // render without panicking while writers hammer.
                    let bucket_sum: u64 = m.request_latency.bucket_counts().iter().sum();
                    let n = m.request_latency.count();
                    assert!(bucket_sum <= total, "bucket sum {bucket_sum} > {total}");
                    assert!(n <= total, "n {n} > {total}");
                    let _ = m.snapshot_json().to_string();
                    let _ = m.to_prometheus();
                    snaps += 1;
                }
                snaps
            })
        };
        let writers: Vec<_> = (0..WRITERS)
            .map(|t| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for i in 0..PER_WRITER {
                        // Sweep several buckets.
                        let us = 1 + ((t as u64 * 7919 + i) % 5000);
                        m.request_latency.observe(Duration::from_micros(us));
                        Metrics::inc(&m.requests, 1);
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        let snaps = reader.join().unwrap();
        assert!(snaps > 0, "reader never snapshotted");
        let total = WRITERS as u64 * PER_WRITER;
        assert_eq!(m.requests.load(Ordering::Relaxed), total);
        assert_eq!(m.request_latency.count(), total);
        assert_eq!(m.request_latency.bucket_counts().iter().sum::<u64>(), total);
        assert!(m.request_latency.sum_us() >= total); // every observe ≥ 1µs
    }

    /// Stats enrichment (ISSUE 9 satellite): uptime, crate version, and
    /// the active kernel id ride the snapshot and round-trip as JSON.
    #[test]
    fn snapshot_reports_uptime_version_and_kernel() {
        let m = Metrics::new();
        std::thread::sleep(Duration::from_millis(2));
        let text = m.snapshot_json().to_string();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert!(j.get("uptime_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            j.get("version").unwrap().as_str(),
            Some(env!("CARGO_PKG_VERSION"))
        );
        let kernel = j.get("kernel_id").unwrap().as_str().unwrap();
        assert!(
            ["avx2", "neon", "scalar"].contains(&kernel),
            "unexpected kernel id {kernel}"
        );
    }

    /// The Prometheus render exposes the same state as the JSON snapshot
    /// in `name{labels} value` exposition shape.
    #[test]
    fn prometheus_render_exposes_counters_and_histograms() {
        let m = Metrics::new();
        Metrics::inc(&m.requests, 7);
        m.request_latency.observe(Duration::from_micros(300));
        m.quarantine("bad-model");
        let text = m.to_prometheus();
        assert!(text.contains("bbans_requests_total 7\n"));
        assert!(text.contains("# TYPE bbans_request_latency_us histogram\n"));
        assert!(text.contains("bbans_request_latency_us_count 1\n"));
        assert!(text.contains("bbans_request_latency_us_bucket{le=\"+Inf\"} 1\n"));
        assert!(text.contains("bbans_quarantined_keys 1\n"));
        assert!(text.contains("bbans_worker_alive 1\n"));
        assert!(text.contains(&format!(
            "bbans_build_info{{version=\"{}\",kernel=\"{}\"}} 1\n",
            env!("CARGO_PKG_VERSION"),
            crate::simd::kernel_name()
        )));
        // Every sample line parses as `name{labels} value`.
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (head, value) = line.rsplit_once(' ').expect("sample line");
            assert!(value.parse::<f64>().is_ok() || value == "+Inf", "{line}");
            assert!(
                head.chars().next().unwrap().is_ascii_alphabetic(),
                "{line}"
            );
        }
    }

    #[test]
    fn metrics_snapshot_is_valid_json() {
        let m = Metrics::new();
        Metrics::inc(&m.requests, 3);
        Metrics::inc(&m.nn_calls, 2);
        Metrics::inc(&m.nn_items, 20);
        m.request_latency.observe(Duration::from_millis(5));
        Metrics::inc(&m.queue_depth, 5);
        Metrics::dec(&m.queue_depth, 3);
        m.queue_wait.observe(Duration::from_micros(40));
        let j = m.snapshot_json();
        assert_eq!(j.get("requests").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("queue_depth").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("rejected").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("protocol_errors").unwrap().as_u64(), Some(0));
        assert!((j.get("mean_batch_size").unwrap().as_f64().unwrap() - 10.0).abs() < 1e-9);
        // Round-trips through the serializer.
        let text = j.to_string();
        assert!(crate::util::json::Json::parse(&text).is_ok());
    }

    #[test]
    fn quarantine_set_and_liveness_surface_in_snapshot() {
        let m = Metrics::new();
        assert!(!m.is_quarantined("toy"));
        m.quarantine("toy");
        m.quarantine("toy"); // idempotent
        m.quarantine("hier:s7|h64|l0|[6, 3]");
        assert!(m.is_quarantined("toy"));
        assert_eq!(m.quarantined_keys().len(), 2);

        let j = m.snapshot_json();
        assert_eq!(j.get("worker_alive"), Some(&Json::Bool(true)));
        match j.get("quarantined") {
            Some(Json::Arr(keys)) => assert_eq!(keys.len(), 2),
            other => panic!("quarantined not an array: {other:?}"),
        }

        m.worker_dead.store(true, Ordering::Relaxed);
        let j = m.snapshot_json();
        assert_eq!(j.get("worker_alive"), Some(&Json::Bool(false)));
    }
}
