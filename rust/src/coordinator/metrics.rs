//! Service metrics: atomic counters and log-bucketed latency histograms,
//! exported as JSON over the stats endpoint.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Json;

/// Log₂-bucketed latency histogram: bucket i covers [2^i, 2^(i+1)) µs.
const BUCKETS: usize = 32;

#[derive(Debug, Default)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    n: AtomicU64,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.counts[bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    /// Approximate quantile from the bucket histogram (upper bound of the
    /// bucket containing the quantile).
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let target = (q * n as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(1u64 << BUCKETS)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("mean_us", Json::Num(self.mean().as_micros() as f64)),
            (
                "p50_us",
                Json::Num(self.quantile(0.5).as_micros() as f64),
            ),
            (
                "p99_us",
                Json::Num(self.quantile(0.99).as_micros() as f64),
            ),
        ])
    }
}

/// All coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub images_encoded: AtomicU64,
    pub images_decoded: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    pub nn_calls: AtomicU64,
    pub nn_items: AtomicU64,
    pub errors: AtomicU64,
    /// Jobs refused at admission because the bounded queue was full.
    pub rejected: AtomicU64,
    /// Malformed frames seen by the server's connection handlers.
    pub protocol_errors: AtomicU64,
    /// Lock-step batch rounds the worker has run.
    pub rounds: AtomicU64,
    /// Execution units (a model's round group / one container decode)
    /// that panicked and were contained by the worker's supervisor.
    pub panics: AtomicU64,
    /// Jobs shed at round formation because their deadline passed while
    /// queued — no NN work was spent on them.
    pub expired: AtomicU64,
    /// Set once the model-worker thread has exited, on EVERY exit path
    /// (clean shutdown, channel drop, or an uncontained panic unwinding
    /// the thread) — the liveness bit health probes read. Stored
    /// inverted so the zero-initialized default means "alive".
    pub worker_dead: AtomicBool,
    /// Worker wakeup epoch: bumped every time the worker starts a round,
    /// so two spaced health probes can tell a live-but-idle worker from a
    /// wedged one under traffic.
    pub heartbeat: AtomicU64,
    /// Quarantined execution keys (model names / rebuilt-header keys):
    /// requests for them fast-fail instead of re-panicking forever.
    /// Cleared only by restarting the service.
    pub quarantined: Mutex<BTreeSet<String>>,
    /// Gauge: jobs admitted but not yet drained into a round.
    pub queue_depth: AtomicU64,
    pub batch_latency: Histogram,
    pub request_latency: Histogram,
    /// Admission-to-drain wait per job (the queueing half of latency).
    pub queue_wait: Histogram,
    /// Per-phase NN dispatch time inside a round.
    pub phase_nn: Histogram,
    /// Per-phase ANS (per-stream coder) time inside a round.
    pub phase_ans: Histogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    /// Decrement a gauge (saturating in practice: pair every `dec` with
    /// an earlier `inc` on the same gauge).
    pub fn dec(gauge: &AtomicU64, by: u64) {
        gauge.fetch_sub(by, Ordering::Relaxed);
    }

    /// Add an execution key to the quarantine set. Idempotent; the set
    /// only ever grows (restart the service to clear it).
    pub fn quarantine(&self, key: &str) {
        self.quarantined
            .lock()
            .expect("quarantine lock poisoned")
            .insert(key.to_string());
    }

    pub fn is_quarantined(&self, key: &str) -> bool {
        self.quarantined
            .lock()
            .expect("quarantine lock poisoned")
            .contains(key)
    }

    /// Sorted copy of the quarantine set (for health/stats snapshots).
    pub fn quarantined_keys(&self) -> Vec<String> {
        self.quarantined
            .lock()
            .expect("quarantine lock poisoned")
            .iter()
            .cloned()
            .collect()
    }

    /// Mean images per NN dispatch — the batching win (1.0 = no batching).
    pub fn mean_batch_size(&self) -> f64 {
        let calls = self.nn_calls.load(Ordering::Relaxed);
        if calls == 0 {
            return 0.0;
        }
        self.nn_items.load(Ordering::Relaxed) as f64 / calls as f64
    }

    pub fn snapshot_json(&self) -> Json {
        Json::obj(vec![
            (
                "requests",
                Json::Num(self.requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "images_encoded",
                Json::Num(self.images_encoded.load(Ordering::Relaxed) as f64),
            ),
            (
                "images_decoded",
                Json::Num(self.images_decoded.load(Ordering::Relaxed) as f64),
            ),
            (
                "bytes_in",
                Json::Num(self.bytes_in.load(Ordering::Relaxed) as f64),
            ),
            (
                "bytes_out",
                Json::Num(self.bytes_out.load(Ordering::Relaxed) as f64),
            ),
            (
                "nn_calls",
                Json::Num(self.nn_calls.load(Ordering::Relaxed) as f64),
            ),
            (
                "nn_items",
                Json::Num(self.nn_items.load(Ordering::Relaxed) as f64),
            ),
            ("mean_batch_size", Json::Num(self.mean_batch_size())),
            (
                "errors",
                Json::Num(self.errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "rejected",
                Json::Num(self.rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "protocol_errors",
                Json::Num(self.protocol_errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "rounds",
                Json::Num(self.rounds.load(Ordering::Relaxed) as f64),
            ),
            (
                "panics",
                Json::Num(self.panics.load(Ordering::Relaxed) as f64),
            ),
            (
                "expired",
                Json::Num(self.expired.load(Ordering::Relaxed) as f64),
            ),
            (
                "worker_alive",
                Json::Bool(!self.worker_dead.load(Ordering::Relaxed)),
            ),
            (
                "heartbeat",
                Json::Num(self.heartbeat.load(Ordering::Relaxed) as f64),
            ),
            (
                "quarantined",
                Json::Arr(self.quarantined_keys().into_iter().map(Json::Str).collect()),
            ),
            (
                "queue_depth",
                Json::Num(self.queue_depth.load(Ordering::Relaxed) as f64),
            ),
            ("batch_latency", self.batch_latency.to_json()),
            ("request_latency", self.request_latency.to_json()),
            ("queue_wait", self.queue_wait.to_json()),
            ("phase_nn", self.phase_nn.to_json()),
            ("phase_ans", self.phase_ans.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..20 {
                h.observe(Duration::from_micros(us));
            }
        }
        assert_eq!(h.count(), 100);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.mean() > Duration::from_micros(1000));
    }

    #[test]
    fn metrics_snapshot_is_valid_json() {
        let m = Metrics::new();
        Metrics::inc(&m.requests, 3);
        Metrics::inc(&m.nn_calls, 2);
        Metrics::inc(&m.nn_items, 20);
        m.request_latency.observe(Duration::from_millis(5));
        Metrics::inc(&m.queue_depth, 5);
        Metrics::dec(&m.queue_depth, 3);
        m.queue_wait.observe(Duration::from_micros(40));
        let j = m.snapshot_json();
        assert_eq!(j.get("requests").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("queue_depth").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("rejected").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("protocol_errors").unwrap().as_u64(), Some(0));
        assert!((j.get("mean_batch_size").unwrap().as_f64().unwrap() - 10.0).abs() < 1e-9);
        // Round-trips through the serializer.
        let text = j.to_string();
        assert!(crate::util::json::Json::parse(&text).is_ok());
    }

    #[test]
    fn quarantine_set_and_liveness_surface_in_snapshot() {
        let m = Metrics::new();
        assert!(!m.is_quarantined("toy"));
        m.quarantine("toy");
        m.quarantine("toy"); // idempotent
        m.quarantine("hier:s7|h64|l0|[6, 3]");
        assert!(m.is_quarantined("toy"));
        assert_eq!(m.quarantined_keys().len(), 2);

        let j = m.snapshot_json();
        assert_eq!(j.get("worker_alive"), Some(&Json::Bool(true)));
        match j.get("quarantined") {
            Some(Json::Arr(keys)) => assert_eq!(keys.len(), 2),
            other => panic!("quarantined not an array: {other:?}"),
        }

        m.worker_dead.store(true, Ordering::Relaxed);
        let j = m.snapshot_json();
        assert_eq!(j.get("worker_alive"), Some(&Json::Bool(false)));
    }
}
