//! Wire protocol for the compression service: length-prefixed frames over
//! TCP (or any `Read`/`Write` pair — tests use in-memory buffers).
//!
//! Frame layout: `u32 LE total payload length | u8 frame type | payload`.
//!
//! | type | frame              | payload                                        |
//! |------|--------------------|------------------------------------------------|
//! | 0x01 | `CompressReq`      | model-name len u8, name, pixels u32, n u32, images |
//! | 0x02 | `DecompressReq`    | container bytes                                |
//! | 0x03 | `StatsReq`         | —                                              |
//! | 0x04 | `Shutdown`         | — (server: stop accepting, drain, exit)        |
//! | 0x05 | `CompressHierReq`  | hier spec (see below), pixels u32, n u32, images |
//! | 0x07 | `HealthReq`        | —                                              |
//! | 0x08 | `TraceReq`         | max traces u32                                 |
//! | 0x09 | `MetricsReq`       | —                                              |
//! | 0x0A | `FetchPagesReq`    | name len u8, name, from_page u32, max_pages u32 |
//! | 0x11 | `CompressReq`+TTL  | ttl_ms u32, then the 0x01 payload              |
//! | 0x12 | `DecompressReq`+TTL| ttl_ms u32, then the 0x02 payload              |
//! | 0x15 | `CompressHierReq`+TTL | ttl_ms u32, then the 0x05 payload           |
//! | 0x21 | `CompressReq`+trace | trace_id u64, then the 0x01 payload           |
//! | 0x22 | `DecompressReq`+trace | trace_id u64, then the 0x02 payload         |
//! | 0x25 | `CompressHierReq`+trace | trace_id u64, then the 0x05 payload       |
//! | 0x31 | `CompressReq`+both | ttl_ms u32, trace_id u64, then the 0x01 payload |
//! | 0x32 | `DecompressReq`+both | ttl_ms u32, trace_id u64, then the 0x02 payload |
//! | 0x35 | `CompressHierReq`+both | ttl_ms u32, trace_id u64, then the 0x05 payload |
//! | 0x81 | `CompressResp`     | container bytes                                |
//! | 0x82 | `DecompressResp`   | pixels u32, n u32, images                      |
//! | 0x83 | `StatsResp`        | JSON text                                      |
//! | 0x87 | `HealthResp`       | JSON text (liveness, quarantine, queue depth)  |
//! | 0x88 | `TraceResp`        | JSON trace snapshot (see `obs::trace`)         |
//! | 0x89 | `MetricsResp`      | Prometheus exposition text                     |
//! | 0x8A | `FetchPagesResp`   | n_pages u32, from_page u32, count u32, header, trailer, page frames (see below) |
//! | 0x7f | `Error`            | UTF-8 message                                  |
//!
//! The request type byte carries a **version-flag nibble**: `0x10` marks
//! a TTL prefix (`ttl_ms` u32), `0x20` a trace prefix (`trace_id` u64),
//! `0x30` both, in that order, ahead of the unchanged v1 payload. A
//! request with neither option set serializes byte-identically to the v1
//! frame (0x01/0x02/0x05), so old clients never emit — and old servers
//! never see — flagged bytes unless a TTL or trace id is actually set.
//!
//! Every multi-byte integer is little-endian. Image grids (`n` images of
//! `pixels` bytes each) are validated against the same untrusted-input
//! budget the BBC1/2/3 container headers use *before* any allocation is
//! sized from them — see [`read_image_grid`].

use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};

use crate::bbans::container::check_decode_budget;
use crate::bbans::hierarchy::Schedule;
use crate::model::Likelihood;

pub const MAX_FRAME: usize = 256 * 1024 * 1024;

/// Ceiling on the chunk count a hier request may ask for (matches the
/// most chunks any real dataset split would use; a chunk is ≥1 image so
/// the image budget bounds it anyway — this just fails fast).
const MAX_HIER_CHUNKS: u32 = 1 << 16;

/// Self-describing spec of a hierarchical (BBC3) model, as carried by
/// [`Frame::CompressHierReq`]: everything the service needs to rebuild
/// the seeded [`crate::model::hierarchy::HierVae`] and encode — the same
/// fields the BBC3 container header records, so the response container
/// is decodable by any decoder without side channels.
#[derive(Debug, Clone, PartialEq)]
pub struct HierSpec {
    pub schedule: Schedule,
    pub likelihood: Likelihood,
    /// Latent width per layer, bottom-up (`dims.len()` = layer count).
    pub dims: Vec<u32>,
    pub hidden: u32,
    /// Weight seed (nonzero; 0 is reserved for artifact-backed models).
    pub seed: u64,
    /// Independent BB-ANS chains to split the images into.
    pub chunks: u32,
}

/// One BBC4 page frame as carried by [`Frame::FetchPagesResp`]: the raw
/// frame bytes plus the server-side CRC echo from the trailer index, so
/// the client can verify the bytes it received independently of the
/// transport before splicing them into a partial local file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FetchedPage {
    /// Page index in the container's sequence.
    pub index: u32,
    /// CRC-32 the server's trailer index records for this frame.
    pub crc: u32,
    /// The raw self-delimiting page frame bytes, verbatim.
    pub bytes: Vec<u8>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Compress `images` (each `pixels` long) with `model`. With
    /// `ttl_ms: Some(t)` the job is shed server-side if still queued
    /// after `t` milliseconds; with `trace_id: Some(id)` the server
    /// records spans for this request under `id` (both are
    /// version-flagged encodings old clients never send).
    CompressReq {
        model: String,
        pixels: u32,
        images: Vec<Vec<u8>>,
        ttl_ms: Option<u32>,
        trace_id: Option<u64>,
    },
    /// A BB-ANS container blob.
    CompressResp { container: Vec<u8> },
    /// Decompress a container blob.
    DecompressReq {
        container: Vec<u8>,
        ttl_ms: Option<u32>,
        trace_id: Option<u64>,
    },
    DecompressResp { pixels: u32, images: Vec<Vec<u8>> },
    /// Compress `images` with a freshly seeded hierarchical model (BBC3).
    CompressHierReq {
        spec: HierSpec,
        pixels: u32,
        images: Vec<Vec<u8>>,
        ttl_ms: Option<u32>,
        trace_id: Option<u64>,
    },
    StatsReq,
    /// JSON metrics snapshot.
    StatsResp { json: String },
    /// Liveness probe: answered by the connection handler from shared
    /// state, NOT through the admission queue — it must work while the
    /// worker is dead or the queue is full.
    HealthReq,
    /// JSON health snapshot (worker liveness, quarantine set, queue
    /// depth, fault counters).
    HealthResp { json: String },
    /// Fetch up to `max` recent traces from the server's span ring.
    /// Answered by the connection handler, never queued.
    TraceReq { max: u32 },
    /// JSON trace snapshot (`obs::trace::Tracer::snapshot_json`).
    TraceResp { json: String },
    /// Fetch the metrics in Prometheus text exposition format. Answered
    /// by the connection handler, never queued.
    MetricsReq,
    /// Prometheus exposition text (`Metrics::to_prometheus`).
    MetricsResp { text: String },
    /// Pull up to `max_pages` page frames of the published container
    /// `name`, starting at `from_page` — the resumable transfer op: a
    /// client that lost its connection re-requests from its last intact
    /// page, so no page is ever sent twice. Answered by the connection
    /// handler from the page store, never queued.
    FetchPagesReq {
        name: String,
        from_page: u32,
        max_pages: u32,
        ttl_ms: Option<u32>,
        trace_id: Option<u64>,
    },
    /// The requested page range with per-page CRC echo. `header` is
    /// non-empty only when the range starts at page 0; `trailer` only
    /// when it reaches the last page — so concatenating the responses of
    /// a full fetch reproduces the container bytes exactly.
    FetchPagesResp {
        n_pages: u32,
        from_page: u32,
        header: Vec<u8>,
        trailer: Vec<u8>,
        pages: Vec<FetchedPage>,
    },
    Error { message: String },
    Shutdown,
}

/// Parse `n` images of `pixels` bytes each out of `body`, applying the
/// untrusted-input budget the container headers use. Rejects the
/// zero-pixel grid outright: `pixels == 0 && n > 0` used to satisfy the
/// `body.len() == n * pixels` check as `0 == 0` and let a 13-byte frame
/// demand 2^32 `Vec` allocations.
fn read_image_grid(pixels: u32, n: u32, body: &[u8], what: &str) -> Result<Vec<Vec<u8>>> {
    if pixels == 0 && n != 0 {
        bail!("{what} claims {n} zero-pixel images");
    }
    check_decode_budget(n as u64, pixels as u64).map_err(|e| anyhow!("{what}: {e}"))?;
    let px = pixels as usize;
    // Budget passed, so `n * px` cannot overflow usize (≤ 2^32).
    if body.len() != n as usize * px {
        bail!("{what} body size mismatch");
    }
    Ok((0..n as usize)
        .map(|i| body[i * px..(i + 1) * px].to_vec())
        .collect())
}

/// Split the version-flag prefixes off a flagged request payload: a
/// 4-byte TTL if `ty & 0x10`, then an 8-byte trace id if `ty & 0x20`,
/// then the untouched v1 payload.
fn split_flags<'a>(
    ty: u8,
    p: &'a [u8],
    what: &str,
) -> Result<(Option<u32>, Option<u64>, &'a [u8])> {
    let mut rest = p;
    let ttl_ms = if ty & 0x10 != 0 {
        if rest.len() < 4 {
            bail!("short {what} TTL prefix");
        }
        let t = u32::from_le_bytes(rest[0..4].try_into().unwrap());
        rest = &rest[4..];
        Some(t)
    } else {
        None
    };
    let trace_id = if ty & 0x20 != 0 {
        if rest.len() < 8 {
            bail!("short {what} trace prefix");
        }
        let t = u64::from_le_bytes(rest[0..8].try_into().unwrap());
        rest = &rest[8..];
        Some(t)
    } else {
        None
    };
    Ok((ttl_ms, trace_id, rest))
}

/// Parse the v1 `CompressReq` payload (shared by 0x01 and the flagged
/// 0x11/0x21/0x31 — same bytes, same validation).
fn parse_compress_req(p: &[u8], ttl_ms: Option<u32>, trace_id: Option<u64>) -> Result<Frame> {
    if p.is_empty() {
        bail!("short CompressReq");
    }
    let mlen = p[0] as usize;
    if p.len() < 1 + mlen + 8 {
        bail!("short CompressReq header");
    }
    let model = std::str::from_utf8(&p[1..1 + mlen])
        .context("model name")?
        .to_string();
    let pixels = u32::from_le_bytes(p[1 + mlen..5 + mlen].try_into().unwrap());
    let n = u32::from_le_bytes(p[5 + mlen..9 + mlen].try_into().unwrap());
    let images = read_image_grid(pixels, n, &p[9 + mlen..], "CompressReq")?;
    Ok(Frame::CompressReq {
        model,
        pixels,
        images,
        ttl_ms,
        trace_id,
    })
}

/// Parse the v1 `CompressHierReq` payload (shared by 0x05 and the
/// flagged 0x15/0x25/0x35).
fn parse_compress_hier_req(p: &[u8], ttl_ms: Option<u32>, trace_id: Option<u64>) -> Result<Frame> {
    // schedule u8 | likelihood u8 | layers u8 | chunks u32 |
    // hidden u32 | seed u64 | pixels u32 | n u32 = 27 bytes.
    if p.len() < 27 {
        bail!("short CompressHierReq header");
    }
    let schedule = Schedule::from_tag(p[0])?;
    let likelihood = Likelihood::from_tag(p[1])?;
    let layers = p[2] as usize;
    if !(1..=8).contains(&layers) {
        bail!("CompressHierReq layer count {layers} out of range 1..=8");
    }
    let chunks = u32::from_le_bytes(p[3..7].try_into().unwrap());
    if chunks == 0 || chunks > MAX_HIER_CHUNKS {
        bail!("CompressHierReq chunk count {chunks} out of range");
    }
    let hidden = u32::from_le_bytes(p[7..11].try_into().unwrap());
    if hidden == 0 || hidden > 1 << 20 {
        bail!("CompressHierReq hidden width {hidden} out of range");
    }
    let seed = u64::from_le_bytes(p[11..19].try_into().unwrap());
    if seed == 0 {
        bail!("CompressHierReq weight seed must be nonzero");
    }
    let pixels = u32::from_le_bytes(p[19..23].try_into().unwrap());
    let n = u32::from_le_bytes(p[23..27].try_into().unwrap());
    let dims_end = 27 + 4 * layers;
    if p.len() < dims_end {
        bail!("short CompressHierReq dims");
    }
    let dims: Vec<u32> = (0..layers)
        .map(|l| u32::from_le_bytes(p[27 + 4 * l..31 + 4 * l].try_into().unwrap()))
        .collect();
    if dims.iter().any(|&d| d == 0 || d > 1 << 16) {
        bail!("CompressHierReq layer dims must be in 1..=65536");
    }
    let images = read_image_grid(pixels, n, &p[dims_end..], "CompressHierReq")?;
    Ok(Frame::CompressHierReq {
        spec: HierSpec {
            schedule,
            likelihood,
            dims,
            hidden,
            seed,
            chunks,
        },
        pixels,
        images,
        ttl_ms,
        trace_id,
    })
}

/// Parse the v1 `FetchPagesReq` payload (shared by 0x0A and the flagged
/// 0x1A/0x2A/0x3A).
fn parse_fetch_pages_req(p: &[u8], ttl_ms: Option<u32>, trace_id: Option<u64>) -> Result<Frame> {
    if p.is_empty() {
        bail!("short FetchPagesReq");
    }
    let nlen = p[0] as usize;
    if p.len() != 1 + nlen + 8 {
        bail!("FetchPagesReq size mismatch");
    }
    let name = std::str::from_utf8(&p[1..1 + nlen])
        .context("fetch name")?
        .to_string();
    let from_page = u32::from_le_bytes(p[1 + nlen..5 + nlen].try_into().unwrap());
    let max_pages = u32::from_le_bytes(p[5 + nlen..9 + nlen].try_into().unwrap());
    if max_pages == 0 {
        bail!("FetchPagesReq max_pages must be nonzero");
    }
    Ok(Frame::FetchPagesReq {
        name,
        from_page,
        max_pages,
        ttl_ms,
        trace_id,
    })
}

/// Parse the `FetchPagesResp` payload. Every length field is validated
/// against the remaining payload before slicing — a crafted response
/// cannot demand allocations the frame does not actually carry.
fn parse_fetch_pages_resp(p: &[u8]) -> Result<Frame> {
    let mut at = 0usize;
    let mut take_u32 = |what: &str| -> Result<u32> {
        if p.len() - at < 4 {
            bail!("short FetchPagesResp ({what})");
        }
        let v = u32::from_le_bytes(p[at..at + 4].try_into().unwrap());
        at += 4;
        Ok(v)
    };
    let n_pages = take_u32("n_pages")?;
    let from_page = take_u32("from_page")?;
    let count = take_u32("count")?;
    let header_len = take_u32("header_len")? as usize;
    let trailer_len = take_u32("trailer_len")? as usize;
    if n_pages == 0 || n_pages > 1 << 20 {
        bail!("FetchPagesResp implausible page count {n_pages}");
    }
    if count as u64 > n_pages as u64 - from_page.min(n_pages) as u64 {
        bail!(
            "FetchPagesResp count {count} overruns pages [{from_page}, {n_pages})"
        );
    }
    let mut take = |n: usize, what: &str| -> Result<&[u8]> {
        if p.len() - at < n {
            bail!("short FetchPagesResp ({what})");
        }
        let s = &p[at..at + n];
        at += n;
        Ok(s)
    };
    let header = take(header_len, "header")?.to_vec();
    let trailer = take(trailer_len, "trailer")?.to_vec();
    let mut pages = Vec::with_capacity(count as usize);
    for k in 0..count {
        let fixed = take(12, "page entry")?;
        let index = u32::from_le_bytes(fixed[0..4].try_into().unwrap());
        let crc = u32::from_le_bytes(fixed[4..8].try_into().unwrap());
        let blen = u32::from_le_bytes(fixed[8..12].try_into().unwrap()) as usize;
        let bytes = take(blen, "page bytes")?.to_vec();
        if index != from_page + k {
            bail!(
                "FetchPagesResp page {k} claims index {index}, expected {}",
                from_page + k
            );
        }
        pages.push(FetchedPage { index, crc, bytes });
    }
    if at != p.len() {
        bail!("FetchPagesResp has {} trailing bytes", p.len() - at);
    }
    Ok(Frame::FetchPagesResp {
        n_pages,
        from_page,
        header,
        trailer,
        pages,
    })
}

/// Version-flag nibble for a request type byte: `0x10` if a TTL rides
/// along, `0x20` if a trace id does. Neither → the bare v1 byte.
fn flag_nibble(ttl_ms: &Option<u32>, trace_id: &Option<u64>) -> u8 {
    (if ttl_ms.is_some() { 0x10 } else { 0 }) | (if trace_id.is_some() { 0x20 } else { 0 })
}

impl Frame {
    fn type_byte(&self) -> u8 {
        match self {
            // Requests with a TTL and/or trace id take the version-
            // flagged 0x1x/0x2x/0x3x bytes; without either they stay
            // byte-identical to the v1 encoding.
            Frame::CompressReq { ttl_ms, trace_id, .. } => 0x01 | flag_nibble(ttl_ms, trace_id),
            Frame::DecompressReq { ttl_ms, trace_id, .. } => 0x02 | flag_nibble(ttl_ms, trace_id),
            Frame::StatsReq => 0x03,
            Frame::Shutdown => 0x04,
            Frame::CompressHierReq { ttl_ms, trace_id, .. } => {
                0x05 | flag_nibble(ttl_ms, trace_id)
            }
            Frame::HealthReq => 0x07,
            Frame::TraceReq { .. } => 0x08,
            Frame::MetricsReq => 0x09,
            Frame::FetchPagesReq { ttl_ms, trace_id, .. } => 0x0A | flag_nibble(ttl_ms, trace_id),
            Frame::CompressResp { .. } => 0x81,
            Frame::DecompressResp { .. } => 0x82,
            Frame::StatsResp { .. } => 0x83,
            Frame::HealthResp { .. } => 0x87,
            Frame::TraceResp { .. } => 0x88,
            Frame::MetricsResp { .. } => 0x89,
            Frame::FetchPagesResp { .. } => 0x8A,
            Frame::Error { .. } => 0x7f,
        }
    }

    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        let mut payload = Vec::new();
        // Flag prefixes ride ahead of the v1 payload: TTL first, then
        // trace id (same order `split_flags` strips them).
        let push_flags = |payload: &mut Vec<u8>, ttl_ms: &Option<u32>, trace_id: &Option<u64>| {
            if let Some(t) = ttl_ms {
                payload.extend_from_slice(&t.to_le_bytes());
            }
            if let Some(t) = trace_id {
                payload.extend_from_slice(&t.to_le_bytes());
            }
        };
        match self {
            Frame::CompressReq {
                model,
                pixels,
                images,
                ttl_ms,
                trace_id,
            } => {
                push_flags(&mut payload, ttl_ms, trace_id);
                payload.push(model.len() as u8);
                payload.extend_from_slice(model.as_bytes());
                payload.extend_from_slice(&pixels.to_le_bytes());
                payload.extend_from_slice(&(images.len() as u32).to_le_bytes());
                for img in images {
                    if img.len() != *pixels as usize {
                        bail!("image length mismatch");
                    }
                    payload.extend_from_slice(img);
                }
            }
            Frame::CompressResp { container } => payload.extend_from_slice(container),
            Frame::DecompressReq {
                container,
                ttl_ms,
                trace_id,
            } => {
                push_flags(&mut payload, ttl_ms, trace_id);
                payload.extend_from_slice(container);
            }
            Frame::DecompressResp { pixels, images } => {
                payload.extend_from_slice(&pixels.to_le_bytes());
                payload.extend_from_slice(&(images.len() as u32).to_le_bytes());
                for img in images {
                    payload.extend_from_slice(img);
                }
            }
            Frame::CompressHierReq {
                spec,
                pixels,
                images,
                ttl_ms,
                trace_id,
            } => {
                push_flags(&mut payload, ttl_ms, trace_id);
                payload.push(spec.schedule.tag());
                payload.push(spec.likelihood.tag());
                payload.push(spec.dims.len() as u8);
                payload.extend_from_slice(&spec.chunks.to_le_bytes());
                payload.extend_from_slice(&spec.hidden.to_le_bytes());
                payload.extend_from_slice(&spec.seed.to_le_bytes());
                payload.extend_from_slice(&pixels.to_le_bytes());
                payload.extend_from_slice(&(images.len() as u32).to_le_bytes());
                for &d in &spec.dims {
                    payload.extend_from_slice(&d.to_le_bytes());
                }
                for img in images {
                    if img.len() != *pixels as usize {
                        bail!("image length mismatch");
                    }
                    payload.extend_from_slice(img);
                }
            }
            Frame::StatsReq | Frame::Shutdown | Frame::HealthReq | Frame::MetricsReq => {}
            Frame::TraceReq { max } => payload.extend_from_slice(&max.to_le_bytes()),
            Frame::FetchPagesReq {
                name,
                from_page,
                max_pages,
                ttl_ms,
                trace_id,
            } => {
                push_flags(&mut payload, ttl_ms, trace_id);
                payload.push(name.len() as u8);
                payload.extend_from_slice(name.as_bytes());
                payload.extend_from_slice(&from_page.to_le_bytes());
                payload.extend_from_slice(&max_pages.to_le_bytes());
            }
            Frame::FetchPagesResp {
                n_pages,
                from_page,
                header,
                trailer,
                pages,
            } => {
                payload.extend_from_slice(&n_pages.to_le_bytes());
                payload.extend_from_slice(&from_page.to_le_bytes());
                payload.extend_from_slice(&(pages.len() as u32).to_le_bytes());
                payload.extend_from_slice(&(header.len() as u32).to_le_bytes());
                payload.extend_from_slice(&(trailer.len() as u32).to_le_bytes());
                payload.extend_from_slice(header);
                payload.extend_from_slice(trailer);
                for pg in pages {
                    payload.extend_from_slice(&pg.index.to_le_bytes());
                    payload.extend_from_slice(&pg.crc.to_le_bytes());
                    payload.extend_from_slice(&(pg.bytes.len() as u32).to_le_bytes());
                    payload.extend_from_slice(&pg.bytes);
                }
            }
            Frame::StatsResp { json } => payload.extend_from_slice(json.as_bytes()),
            Frame::HealthResp { json } => payload.extend_from_slice(json.as_bytes()),
            Frame::TraceResp { json } => payload.extend_from_slice(json.as_bytes()),
            Frame::MetricsResp { text } => payload.extend_from_slice(text.as_bytes()),
            Frame::Error { message } => payload.extend_from_slice(message.as_bytes()),
        }
        let total = payload.len() + 1;
        w.write_all(&(total as u32).to_le_bytes())?;
        w.write_all(&[self.type_byte()])?;
        w.write_all(&payload)?;
        w.flush()?;
        Ok(())
    }

    /// Parse one frame from `body` — the type byte plus payload, i.e. a
    /// frame minus its length prefix. The server reads the prefix itself
    /// (interruptibly, so shutdown can cut idle reads short);
    /// [`Frame::read_from`] wraps this for plain blocking readers.
    pub fn parse(body: &[u8]) -> Result<Frame> {
        let Some((&ty, p)) = body.split_first() else {
            bail!("empty frame");
        };
        Ok(match ty {
            0x01 => parse_compress_req(p, None, None)?,
            0x02 => Frame::DecompressReq {
                container: p.to_vec(),
                ttl_ms: None,
                trace_id: None,
            },
            0x03 => Frame::StatsReq,
            0x04 => Frame::Shutdown,
            0x05 => parse_compress_hier_req(p, None, None)?,
            0x07 => Frame::HealthReq,
            0x08 => {
                if p.len() != 4 {
                    bail!("TraceReq payload must be 4 bytes");
                }
                Frame::TraceReq {
                    max: u32::from_le_bytes(p[0..4].try_into().unwrap()),
                }
            }
            0x09 => Frame::MetricsReq,
            0x0A => parse_fetch_pages_req(p, None, None)?,
            // The flagged request encodings: optional ttl_ms u32 and/or
            // trace_id u64, then the v1 payload, parsed by the same
            // validators.
            0x11 | 0x21 | 0x31 => {
                let (ttl, trace, rest) = split_flags(ty, p, "CompressReq")?;
                parse_compress_req(rest, ttl, trace)?
            }
            0x12 | 0x22 | 0x32 => {
                let (ttl, trace, rest) = split_flags(ty, p, "DecompressReq")?;
                Frame::DecompressReq {
                    container: rest.to_vec(),
                    ttl_ms: ttl,
                    trace_id: trace,
                }
            }
            0x15 | 0x25 | 0x35 => {
                let (ttl, trace, rest) = split_flags(ty, p, "CompressHierReq")?;
                parse_compress_hier_req(rest, ttl, trace)?
            }
            0x1A | 0x2A | 0x3A => {
                let (ttl, trace, rest) = split_flags(ty, p, "FetchPagesReq")?;
                parse_fetch_pages_req(rest, ttl, trace)?
            }
            0x81 => Frame::CompressResp {
                container: p.to_vec(),
            },
            0x82 => {
                // Same grid validation as 0x01 — this direction had the
                // identical zero-pixel hole.
                if p.len() < 8 {
                    bail!("short DecompressResp");
                }
                let pixels = u32::from_le_bytes(p[0..4].try_into().unwrap());
                let n = u32::from_le_bytes(p[4..8].try_into().unwrap());
                let images = read_image_grid(pixels, n, &p[8..], "DecompressResp")?;
                Frame::DecompressResp { pixels, images }
            }
            0x83 => Frame::StatsResp {
                json: String::from_utf8(p.to_vec()).context("stats json")?,
            },
            0x87 => Frame::HealthResp {
                json: String::from_utf8(p.to_vec()).context("health json")?,
            },
            0x88 => Frame::TraceResp {
                json: String::from_utf8(p.to_vec()).context("trace json")?,
            },
            0x89 => Frame::MetricsResp {
                text: String::from_utf8(p.to_vec()).context("metrics text")?,
            },
            0x8A => parse_fetch_pages_resp(p)?,
            0x7f => Frame::Error {
                message: String::from_utf8_lossy(p).to_string(),
            },
            other => bail!("unknown frame type {other:#x}"),
        })
    }

    /// Request-side TTL, for any frame kind that can carry one.
    pub fn ttl_ms(&self) -> Option<u32> {
        match self {
            Frame::CompressReq { ttl_ms, .. }
            | Frame::DecompressReq { ttl_ms, .. }
            | Frame::CompressHierReq { ttl_ms, .. }
            | Frame::FetchPagesReq { ttl_ms, .. } => *ttl_ms,
            _ => None,
        }
    }

    /// Request-side trace id, for any frame kind that can carry one.
    pub fn trace_id(&self) -> Option<u64> {
        match self {
            Frame::CompressReq { trace_id, .. }
            | Frame::DecompressReq { trace_id, .. }
            | Frame::CompressHierReq { trace_id, .. }
            | Frame::FetchPagesReq { trace_id, .. } => *trace_id,
            _ => None,
        }
    }

    pub fn read_from(r: &mut impl Read) -> Result<Frame> {
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4).context("frame length")?;
        let total = u32::from_le_bytes(len4) as usize;
        if total == 0 || total > MAX_FRAME {
            bail!("bad frame length {total}");
        }
        let mut buf = vec![0u8; total];
        r.read_exact(&mut buf).context("frame body")?;
        Frame::parse(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let mut r = &buf[..];
        let g = Frame::read_from(&mut r).unwrap();
        assert_eq!(f, g);
    }

    fn hier_frame() -> Frame {
        Frame::CompressHierReq {
            spec: HierSpec {
                schedule: Schedule::BitSwap,
                likelihood: Likelihood::Bernoulli,
                dims: vec![6, 4],
                hidden: 10,
                seed: 99,
                chunks: 3,
            },
            pixels: 4,
            images: vec![vec![0, 1, 1, 0], vec![1, 0, 0, 1]],
            ttl_ms: None,
            trace_id: None,
        }
    }

    #[test]
    fn all_frames_roundtrip() {
        roundtrip(Frame::CompressReq {
            model: "bin".into(),
            pixels: 4,
            images: vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]],
            ttl_ms: None,
            trace_id: None,
        });
        roundtrip(Frame::CompressResp {
            container: vec![9, 9, 9],
        });
        roundtrip(Frame::DecompressReq {
            container: vec![1, 2],
            ttl_ms: None,
            trace_id: None,
        });
        roundtrip(Frame::DecompressResp {
            pixels: 2,
            images: vec![vec![0, 1]],
        });
        roundtrip(hier_frame());
        roundtrip(Frame::StatsReq);
        roundtrip(Frame::StatsResp {
            json: "{\"x\":1}".into(),
        });
        roundtrip(Frame::HealthReq);
        roundtrip(Frame::HealthResp {
            json: "{\"alive\":true}".into(),
        });
        roundtrip(Frame::TraceReq { max: 16 });
        roundtrip(Frame::TraceResp {
            json: "{\"traces\":[]}".into(),
        });
        roundtrip(Frame::MetricsReq);
        roundtrip(Frame::MetricsResp {
            text: "bbans_requests_total 0\n".into(),
        });
        roundtrip(Frame::Error {
            message: "nope".into(),
        });
        roundtrip(Frame::Shutdown);
    }

    /// TTL'd requests round-trip through the 0x1x encodings; requests
    /// without a TTL stay BYTE-identical to the v1 frames (the version
    /// flag is the type byte, nothing else moves).
    #[test]
    fn ttl_requests_roundtrip_and_v1_bytes_unchanged() {
        roundtrip(Frame::CompressReq {
            model: "bin".into(),
            pixels: 4,
            images: vec![vec![1, 2, 3, 4]],
            ttl_ms: Some(1500),
            trace_id: None,
        });
        roundtrip(Frame::DecompressReq {
            container: vec![1, 2, 3],
            ttl_ms: Some(0),
            trace_id: None,
        });
        let mut ttl_hier = hier_frame();
        if let Frame::CompressHierReq { ttl_ms, .. } = &mut ttl_hier {
            *ttl_ms = Some(250);
        }
        roundtrip(ttl_hier.clone());
        assert_eq!(ttl_hier.ttl_ms(), Some(250));

        // A TTL-less frame encodes with the legacy type byte and exactly
        // the legacy payload: old servers parse it unchanged.
        let mut v1 = Vec::new();
        Frame::DecompressReq {
            container: vec![7, 8, 9],
            ttl_ms: None,
            trace_id: None,
        }
        .write_to(&mut v1)
        .unwrap();
        assert_eq!(v1[4], 0x02, "TTL-less request must keep the v1 type byte");
        let mut v2 = Vec::new();
        Frame::DecompressReq {
            container: vec![7, 8, 9],
            ttl_ms: Some(42),
            trace_id: None,
        }
        .write_to(&mut v2)
        .unwrap();
        assert_eq!(v2[4], 0x12);
        assert_eq!(&v2[5..9], &42u32.to_le_bytes());
        assert_eq!(&v2[9..], &v1[5..], "v2 payload = ttl prefix + v1 payload");

        // Truncated TTL prefixes error cleanly.
        for ty in [0x11u8, 0x12, 0x15] {
            assert!(Frame::parse(&[ty, 1, 2]).is_err(), "ty={ty:#x}");
        }
    }

    /// Traced requests take the 0x2x (trace-only) and 0x3x (TTL+trace)
    /// flag bytes; the prefix order is TTL then trace id, and the v1
    /// payload bytes after the prefixes never move.
    #[test]
    fn traced_requests_roundtrip_and_pin_prefix_layout() {
        roundtrip(Frame::CompressReq {
            model: "bin".into(),
            pixels: 4,
            images: vec![vec![1, 2, 3, 4]],
            ttl_ms: None,
            trace_id: Some(0xDEAD_BEEF_1234_5678),
        });
        roundtrip(Frame::CompressHierReq {
            spec: match hier_frame() {
                Frame::CompressHierReq { spec, .. } => spec,
                _ => unreachable!(),
            },
            pixels: 4,
            images: vec![vec![0, 1, 1, 0]],
            ttl_ms: Some(100),
            trace_id: Some(7),
        });

        let mut v1 = Vec::new();
        Frame::DecompressReq {
            container: vec![7, 8, 9],
            ttl_ms: None,
            trace_id: None,
        }
        .write_to(&mut v1)
        .unwrap();

        // Trace-only: 0x22, trace_id u64, then the v1 payload.
        let mut traced = Vec::new();
        Frame::DecompressReq {
            container: vec![7, 8, 9],
            ttl_ms: None,
            trace_id: Some(0xABCD),
        }
        .write_to(&mut traced)
        .unwrap();
        assert_eq!(traced[4], 0x22);
        assert_eq!(&traced[5..13], &0xABCDu64.to_le_bytes());
        assert_eq!(&traced[13..], &v1[5..], "trace payload = trace id + v1 payload");

        // Both flags: 0x32, ttl u32 first, trace u64 second.
        let mut both = Vec::new();
        Frame::DecompressReq {
            container: vec![7, 8, 9],
            ttl_ms: Some(42),
            trace_id: Some(0xABCD),
        }
        .write_to(&mut both)
        .unwrap();
        assert_eq!(both[4], 0x32);
        assert_eq!(&both[5..9], &42u32.to_le_bytes());
        assert_eq!(&both[9..17], &0xABCDu64.to_le_bytes());
        assert_eq!(&both[17..], &v1[5..]);
        let parsed = Frame::parse(&both[4..]).unwrap();
        assert_eq!(parsed.ttl_ms(), Some(42));
        assert_eq!(parsed.trace_id(), Some(0xABCD));

        // Truncated trace prefixes error cleanly on every flagged type.
        for ty in [0x21u8, 0x22, 0x25, 0x31, 0x32, 0x35] {
            assert!(Frame::parse(&[ty, 1, 2, 3]).is_err(), "ty={ty:#x}");
        }
    }

    /// FetchPages ops round-trip, including the version-flagged request
    /// encodings, and malformed responses error cleanly.
    #[test]
    fn fetch_pages_ops_roundtrip_and_validate() {
        roundtrip(Frame::FetchPagesReq {
            name: "dataset.bbc4".into(),
            from_page: 3,
            max_pages: 8,
            ttl_ms: None,
            trace_id: None,
        });
        roundtrip(Frame::FetchPagesReq {
            name: "d".into(),
            from_page: 0,
            max_pages: 1,
            ttl_ms: Some(250),
            trace_id: Some(0xFE7C),
        });
        roundtrip(Frame::FetchPagesResp {
            n_pages: 4,
            from_page: 1,
            header: vec![],
            trailer: vec![9, 9],
            pages: vec![
                FetchedPage {
                    index: 1,
                    crc: 0xAABB,
                    bytes: vec![1, 2, 3],
                },
                FetchedPage {
                    index: 2,
                    crc: 0xCCDD,
                    bytes: vec![],
                },
            ],
        });

        // Plain request keeps the v1 type byte; flagged takes 0x3A.
        let mut plain = Vec::new();
        Frame::FetchPagesReq {
            name: "x".into(),
            from_page: 0,
            max_pages: 2,
            ttl_ms: None,
            trace_id: None,
        }
        .write_to(&mut plain)
        .unwrap();
        assert_eq!(plain[4], 0x0A);
        let mut flagged = Vec::new();
        Frame::FetchPagesReq {
            name: "x".into(),
            from_page: 0,
            max_pages: 2,
            ttl_ms: Some(7),
            trace_id: Some(8),
        }
        .write_to(&mut flagged)
        .unwrap();
        assert_eq!(flagged[4], 0x3A);
        assert_eq!(&flagged[17..], &plain[5..], "flag prefixes then v1 payload");

        // max_pages == 0 and short/oversized payloads are rejected.
        assert!(Frame::parse(&raw_frame(0x0A, b"\x01x\x00\x00\x00\x00\x00\x00\x00\x00")[4..])
            .is_err());
        assert!(Frame::parse(&raw_frame(0x0A, &[])[4..]).is_err());

        // A crafted response whose count overruns the page range, or
        // whose length fields overrun the payload, errors without
        // allocating.
        let mut p = Vec::new();
        p.extend_from_slice(&4u32.to_le_bytes()); // n_pages
        p.extend_from_slice(&2u32.to_le_bytes()); // from_page
        p.extend_from_slice(&3u32.to_le_bytes()); // count > 4 - 2
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&0u32.to_le_bytes());
        let err = Frame::parse(&raw_frame(0x8A, &p)[4..]).unwrap_err();
        assert!(err.to_string().contains("overruns"), "{err}");
        let mut p = Vec::new();
        p.extend_from_slice(&4u32.to_le_bytes());
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&u32::MAX.to_le_bytes()); // header_len lies
        p.extend_from_slice(&0u32.to_le_bytes());
        assert!(Frame::parse(&raw_frame(0x8A, &p)[4..]).is_err());

        // Every truncation of a valid response errors cleanly.
        let mut buf = Vec::new();
        Frame::FetchPagesResp {
            n_pages: 2,
            from_page: 0,
            header: vec![5, 6, 7],
            trailer: vec![],
            pages: vec![FetchedPage {
                index: 0,
                crc: 1,
                bytes: vec![8, 9],
            }],
        }
        .write_to(&mut buf)
        .unwrap();
        for cut in 5..buf.len() {
            assert!(Frame::parse(&buf[4..cut]).is_err(), "cut={cut}");
        }
    }

    /// TraceReq/MetricsReq are handler-served ops with fixed payloads.
    #[test]
    fn trace_and_metrics_ops_pin_their_bytes() {
        let mut buf = Vec::new();
        Frame::TraceReq { max: 9 }.write_to(&mut buf).unwrap();
        assert_eq!(buf[4], 0x08);
        assert_eq!(&buf[5..], &9u32.to_le_bytes());
        // Wrong-size TraceReq payloads are rejected.
        assert!(Frame::parse(&[0x08u8, 1, 2]).is_err());
        assert!(Frame::parse(&[0x08u8, 1, 2, 3, 4, 5]).is_err());

        let mut buf = Vec::new();
        Frame::MetricsReq.write_to(&mut buf).unwrap();
        assert_eq!(buf, vec![1, 0, 0, 0, 0x09]);
    }

    #[test]
    fn rejects_malformed() {
        // Zero length.
        let mut r: &[u8] = &[0, 0, 0, 0];
        assert!(Frame::read_from(&mut r).is_err());
        // Truncated body.
        let mut buf = Vec::new();
        Frame::StatsReq.write_to(&mut buf).unwrap();
        let mut r = &buf[..buf.len() - 1];
        let _ = Frame::read_from(&mut r); // must not panic
        // Unknown type.
        let mut r: &[u8] = &[1, 0, 0, 0, 0x55];
        assert!(Frame::read_from(&mut r).is_err());
        // Size-mismatched CompressReq.
        let mut bad = Vec::new();
        Frame::CompressReq {
            model: "m".into(),
            pixels: 4,
            images: vec![vec![0; 4]],
            ttl_ms: None,
            trace_id: None,
        }
        .write_to(&mut bad)
        .unwrap();
        let n = bad.len();
        bad[n - 5] ^= 1; // tamper with count
        let mut r = &bad[..];
        assert!(Frame::read_from(&mut r).is_err());
    }

    /// Hand-build a frame around a raw payload (type byte included by the
    /// caller) so tests can express grids `write_to` refuses to emit.
    fn raw_frame(ty: u8, payload: &[u8]) -> Vec<u8> {
        let mut frame = Vec::with_capacity(5 + payload.len());
        frame.extend_from_slice(&((payload.len() + 1) as u32).to_le_bytes());
        frame.push(ty);
        frame.extend_from_slice(payload);
        frame
    }

    /// Regression: `CompressReq { pixels: 0, n: u32::MAX }` used to pass
    /// the `body.len() == n * px` check as `0 == 0` and allocate 2^32
    /// empty `Vec`s. The same hole existed in `DecompressResp`.
    #[test]
    fn rejects_zero_pixel_image_flood() {
        let mut p = vec![3u8];
        p.extend_from_slice(b"toy");
        p.extend_from_slice(&0u32.to_le_bytes()); // pixels = 0
        p.extend_from_slice(&u32::MAX.to_le_bytes()); // n = 2^32 - 1
        let mut r = &raw_frame(0x01, &p)[..];
        let err = Frame::read_from(&mut r).unwrap_err();
        assert!(err.to_string().contains("zero-pixel"), "{err}");

        let mut p = Vec::new();
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut r = &raw_frame(0x82, &p)[..];
        let err = Frame::read_from(&mut r).unwrap_err();
        assert!(err.to_string().contains("zero-pixel"), "{err}");
    }

    /// Image grids are held to the container untrusted-input budget —
    /// an implausible `n`/`pixels` product errors before any sizing.
    #[test]
    fn rejects_budget_busting_image_grids() {
        // n beyond MAX_IMAGES at 1 pixel each.
        let mut p = vec![1u8, b'm'];
        p.extend_from_slice(&1u32.to_le_bytes());
        p.extend_from_slice(&((1u32 << 24) + 1).to_le_bytes());
        let mut r = &raw_frame(0x01, &p)[..];
        let err = Frame::read_from(&mut r).unwrap_err();
        assert!(err.to_string().contains("implausible"), "{err}");

        // n * pixels beyond the total-pixel budget.
        let mut p = vec![1u8, b'm'];
        p.extend_from_slice(&(1u32 << 20).to_le_bytes());
        p.extend_from_slice(&(1u32 << 16).to_le_bytes());
        let mut r = &raw_frame(0x01, &p)[..];
        let err = Frame::read_from(&mut r).unwrap_err();
        assert!(err.to_string().contains("pixels"), "{err}");
    }

    /// Adversarial sweep: random frames, every truncation of a valid
    /// frame, and an oversized length prefix must all return `Err` (or a
    /// harmless parse) without panicking or over-allocating.
    #[test]
    fn fuzzed_frames_never_panic() {
        let mut rng = Rng::new(0xF0_22);
        for _ in 0..2000 {
            let len = rng.below(64) as usize + 1;
            let mut frame = (len as u32).to_le_bytes().to_vec();
            for _ in 0..len {
                frame.push(rng.below(256) as u8);
            }
            let mut r = &frame[..];
            let _ = Frame::read_from(&mut r); // Ok or Err, never panic
        }

        // Every truncation of a valid multi-section frame errors cleanly.
        let mut buf = Vec::new();
        hier_frame().write_to(&mut buf).unwrap();
        for cut in 0..buf.len() {
            let mut r = &buf[..cut];
            assert!(Frame::read_from(&mut r).is_err(), "cut={cut}");
        }

        // Oversized length prefix is rejected before allocating.
        let mut r: &[u8] = &[0xff, 0xff, 0xff, 0xff, 0x01];
        assert!(Frame::read_from(&mut r).is_err());

        // Hier header validations: bad tags and zero fields all error.
        let good = {
            let mut buf = Vec::new();
            hier_frame().write_to(&mut buf).unwrap();
            buf[4..].to_vec() // type byte + payload
        };
        // (offset, value): bad schedule tag, bad likelihood tag, layer
        // count 0, layer count > 8.
        for (off, val) in [(1usize, 9u8), (2, 9), (3, 0), (3, 9)] {
            let mut b = good.clone();
            b[off] = val;
            assert!(Frame::parse(&b).is_err(), "off={off} val={val}");
        }
    }
}
