//! Wire protocol for the compression service: length-prefixed frames over
//! TCP (or any `Read`/`Write` pair — tests use in-memory buffers).
//!
//! Frame layout: `u32 LE total payload length | u8 frame type | payload`.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

pub const MAX_FRAME: usize = 256 * 1024 * 1024;

#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Compress `images` (each `pixels` long) with `model`.
    CompressReq {
        model: String,
        pixels: u32,
        images: Vec<Vec<u8>>,
    },
    /// A BB-ANS container blob.
    CompressResp { container: Vec<u8> },
    /// Decompress a container blob.
    DecompressReq { container: Vec<u8> },
    DecompressResp { pixels: u32, images: Vec<Vec<u8>> },
    StatsReq,
    /// JSON metrics snapshot.
    StatsResp { json: String },
    Error { message: String },
    Shutdown,
}

impl Frame {
    fn type_byte(&self) -> u8 {
        match self {
            Frame::CompressReq { .. } => 0x01,
            Frame::DecompressReq { .. } => 0x02,
            Frame::StatsReq => 0x03,
            Frame::Shutdown => 0x04,
            Frame::CompressResp { .. } => 0x81,
            Frame::DecompressResp { .. } => 0x82,
            Frame::StatsResp { .. } => 0x83,
            Frame::Error { .. } => 0x7f,
        }
    }

    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        let mut payload = Vec::new();
        match self {
            Frame::CompressReq {
                model,
                pixels,
                images,
            } => {
                payload.push(model.len() as u8);
                payload.extend_from_slice(model.as_bytes());
                payload.extend_from_slice(&pixels.to_le_bytes());
                payload.extend_from_slice(&(images.len() as u32).to_le_bytes());
                for img in images {
                    if img.len() != *pixels as usize {
                        bail!("image length mismatch");
                    }
                    payload.extend_from_slice(img);
                }
            }
            Frame::CompressResp { container } => payload.extend_from_slice(container),
            Frame::DecompressReq { container } => payload.extend_from_slice(container),
            Frame::DecompressResp { pixels, images } => {
                payload.extend_from_slice(&pixels.to_le_bytes());
                payload.extend_from_slice(&(images.len() as u32).to_le_bytes());
                for img in images {
                    payload.extend_from_slice(img);
                }
            }
            Frame::StatsReq | Frame::Shutdown => {}
            Frame::StatsResp { json } => payload.extend_from_slice(json.as_bytes()),
            Frame::Error { message } => payload.extend_from_slice(message.as_bytes()),
        }
        let total = payload.len() + 1;
        w.write_all(&(total as u32).to_le_bytes())?;
        w.write_all(&[self.type_byte()])?;
        w.write_all(&payload)?;
        w.flush()?;
        Ok(())
    }

    pub fn read_from(r: &mut impl Read) -> Result<Frame> {
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4).context("frame length")?;
        let total = u32::from_le_bytes(len4) as usize;
        if total == 0 || total > MAX_FRAME {
            bail!("bad frame length {total}");
        }
        let mut buf = vec![0u8; total];
        r.read_exact(&mut buf).context("frame body")?;
        let ty = buf[0];
        let p = &buf[1..];
        Ok(match ty {
            0x01 => {
                if p.is_empty() {
                    bail!("short CompressReq");
                }
                let mlen = p[0] as usize;
                if p.len() < 1 + mlen + 8 {
                    bail!("short CompressReq header");
                }
                let model = std::str::from_utf8(&p[1..1 + mlen])
                    .context("model name")?
                    .to_string();
                let pixels =
                    u32::from_le_bytes(p[1 + mlen..5 + mlen].try_into().unwrap());
                let n = u32::from_le_bytes(p[5 + mlen..9 + mlen].try_into().unwrap()) as usize;
                let body = &p[9 + mlen..];
                let px = pixels as usize;
                if body.len() != n * px {
                    bail!("CompressReq body size mismatch");
                }
                let images = (0..n).map(|i| body[i * px..(i + 1) * px].to_vec()).collect();
                Frame::CompressReq {
                    model,
                    pixels,
                    images,
                }
            }
            0x02 => Frame::DecompressReq {
                container: p.to_vec(),
            },
            0x03 => Frame::StatsReq,
            0x04 => Frame::Shutdown,
            0x81 => Frame::CompressResp {
                container: p.to_vec(),
            },
            0x82 => {
                if p.len() < 8 {
                    bail!("short DecompressResp");
                }
                let pixels = u32::from_le_bytes(p[0..4].try_into().unwrap());
                let n = u32::from_le_bytes(p[4..8].try_into().unwrap()) as usize;
                let body = &p[8..];
                let px = pixels as usize;
                if body.len() != n * px {
                    bail!("DecompressResp body size mismatch");
                }
                let images = (0..n).map(|i| body[i * px..(i + 1) * px].to_vec()).collect();
                Frame::DecompressResp { pixels, images }
            }
            0x83 => Frame::StatsResp {
                json: String::from_utf8(p.to_vec()).context("stats json")?,
            },
            0x7f => Frame::Error {
                message: String::from_utf8_lossy(p).to_string(),
            },
            other => bail!("unknown frame type {other:#x}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let mut r = &buf[..];
        let g = Frame::read_from(&mut r).unwrap();
        assert_eq!(f, g);
    }

    #[test]
    fn all_frames_roundtrip() {
        roundtrip(Frame::CompressReq {
            model: "bin".into(),
            pixels: 4,
            images: vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]],
        });
        roundtrip(Frame::CompressResp {
            container: vec![9, 9, 9],
        });
        roundtrip(Frame::DecompressReq {
            container: vec![1, 2],
        });
        roundtrip(Frame::DecompressResp {
            pixels: 2,
            images: vec![vec![0, 1]],
        });
        roundtrip(Frame::StatsReq);
        roundtrip(Frame::StatsResp {
            json: "{\"x\":1}".into(),
        });
        roundtrip(Frame::Error {
            message: "nope".into(),
        });
        roundtrip(Frame::Shutdown);
    }

    #[test]
    fn rejects_malformed() {
        // Zero length.
        let mut r: &[u8] = &[0, 0, 0, 0];
        assert!(Frame::read_from(&mut r).is_err());
        // Truncated body.
        let mut buf = Vec::new();
        Frame::StatsReq.write_to(&mut buf).unwrap();
        let mut r = &buf[..buf.len() - 1];
        let _ = Frame::read_from(&mut r); // must not panic
        // Unknown type.
        let mut r: &[u8] = &[1, 0, 0, 0, 0x55];
        assert!(Frame::read_from(&mut r).is_err());
        // Size-mismatched CompressReq.
        let mut bad = Vec::new();
        Frame::CompressReq {
            model: "m".into(),
            pixels: 4,
            images: vec![vec![0; 4]],
        }
        .write_to(&mut bad)
        .unwrap();
        let n = bad.len();
        bad[n - 5] ^= 1; // tamper with count
        let mut r = &bad[..];
        assert!(Frame::read_from(&mut r).is_err());
    }
}
