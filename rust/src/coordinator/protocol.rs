//! Wire protocol for the compression service: length-prefixed frames over
//! TCP (or any `Read`/`Write` pair — tests use in-memory buffers).
//!
//! Frame layout: `u32 LE total payload length | u8 frame type | payload`.
//!
//! | type | frame              | payload                                        |
//! |------|--------------------|------------------------------------------------|
//! | 0x01 | `CompressReq`      | model-name len u8, name, pixels u32, n u32, images |
//! | 0x02 | `DecompressReq`    | container bytes                                |
//! | 0x03 | `StatsReq`         | —                                              |
//! | 0x04 | `Shutdown`         | —                                              |
//! | 0x05 | `CompressHierReq`  | hier spec (see below), pixels u32, n u32, images |
//! | 0x81 | `CompressResp`     | container bytes                                |
//! | 0x82 | `DecompressResp`   | pixels u32, n u32, images                      |
//! | 0x83 | `StatsResp`        | JSON text                                      |
//! | 0x7f | `Error`            | UTF-8 message                                  |
//!
//! Every multi-byte integer is little-endian. Image grids (`n` images of
//! `pixels` bytes each) are validated against the same untrusted-input
//! budget the BBC1/2/3 container headers use *before* any allocation is
//! sized from them — see [`read_image_grid`].

use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};

use crate::bbans::container::check_decode_budget;
use crate::bbans::hierarchy::Schedule;
use crate::model::Likelihood;

pub const MAX_FRAME: usize = 256 * 1024 * 1024;

/// Ceiling on the chunk count a hier request may ask for (matches the
/// most chunks any real dataset split would use; a chunk is ≥1 image so
/// the image budget bounds it anyway — this just fails fast).
const MAX_HIER_CHUNKS: u32 = 1 << 16;

/// Self-describing spec of a hierarchical (BBC3) model, as carried by
/// [`Frame::CompressHierReq`]: everything the service needs to rebuild
/// the seeded [`crate::model::hierarchy::HierVae`] and encode — the same
/// fields the BBC3 container header records, so the response container
/// is decodable by any decoder without side channels.
#[derive(Debug, Clone, PartialEq)]
pub struct HierSpec {
    pub schedule: Schedule,
    pub likelihood: Likelihood,
    /// Latent width per layer, bottom-up (`dims.len()` = layer count).
    pub dims: Vec<u32>,
    pub hidden: u32,
    /// Weight seed (nonzero; 0 is reserved for artifact-backed models).
    pub seed: u64,
    /// Independent BB-ANS chains to split the images into.
    pub chunks: u32,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Compress `images` (each `pixels` long) with `model`.
    CompressReq {
        model: String,
        pixels: u32,
        images: Vec<Vec<u8>>,
    },
    /// A BB-ANS container blob.
    CompressResp { container: Vec<u8> },
    /// Decompress a container blob.
    DecompressReq { container: Vec<u8> },
    DecompressResp { pixels: u32, images: Vec<Vec<u8>> },
    /// Compress `images` with a freshly seeded hierarchical model (BBC3).
    CompressHierReq {
        spec: HierSpec,
        pixels: u32,
        images: Vec<Vec<u8>>,
    },
    StatsReq,
    /// JSON metrics snapshot.
    StatsResp { json: String },
    Error { message: String },
    Shutdown,
}

/// Parse `n` images of `pixels` bytes each out of `body`, applying the
/// untrusted-input budget the container headers use. Rejects the
/// zero-pixel grid outright: `pixels == 0 && n > 0` used to satisfy the
/// `body.len() == n * pixels` check as `0 == 0` and let a 13-byte frame
/// demand 2^32 `Vec` allocations.
fn read_image_grid(pixels: u32, n: u32, body: &[u8], what: &str) -> Result<Vec<Vec<u8>>> {
    if pixels == 0 && n != 0 {
        bail!("{what} claims {n} zero-pixel images");
    }
    check_decode_budget(n as u64, pixels as u64).map_err(|e| anyhow!("{what}: {e}"))?;
    let px = pixels as usize;
    // Budget passed, so `n * px` cannot overflow usize (≤ 2^32).
    if body.len() != n as usize * px {
        bail!("{what} body size mismatch");
    }
    Ok((0..n as usize)
        .map(|i| body[i * px..(i + 1) * px].to_vec())
        .collect())
}

impl Frame {
    fn type_byte(&self) -> u8 {
        match self {
            Frame::CompressReq { .. } => 0x01,
            Frame::DecompressReq { .. } => 0x02,
            Frame::StatsReq => 0x03,
            Frame::Shutdown => 0x04,
            Frame::CompressHierReq { .. } => 0x05,
            Frame::CompressResp { .. } => 0x81,
            Frame::DecompressResp { .. } => 0x82,
            Frame::StatsResp { .. } => 0x83,
            Frame::Error { .. } => 0x7f,
        }
    }

    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        let mut payload = Vec::new();
        match self {
            Frame::CompressReq {
                model,
                pixels,
                images,
            } => {
                payload.push(model.len() as u8);
                payload.extend_from_slice(model.as_bytes());
                payload.extend_from_slice(&pixels.to_le_bytes());
                payload.extend_from_slice(&(images.len() as u32).to_le_bytes());
                for img in images {
                    if img.len() != *pixels as usize {
                        bail!("image length mismatch");
                    }
                    payload.extend_from_slice(img);
                }
            }
            Frame::CompressResp { container } => payload.extend_from_slice(container),
            Frame::DecompressReq { container } => payload.extend_from_slice(container),
            Frame::DecompressResp { pixels, images } => {
                payload.extend_from_slice(&pixels.to_le_bytes());
                payload.extend_from_slice(&(images.len() as u32).to_le_bytes());
                for img in images {
                    payload.extend_from_slice(img);
                }
            }
            Frame::CompressHierReq {
                spec,
                pixels,
                images,
            } => {
                payload.push(spec.schedule.tag());
                payload.push(spec.likelihood.tag());
                payload.push(spec.dims.len() as u8);
                payload.extend_from_slice(&spec.chunks.to_le_bytes());
                payload.extend_from_slice(&spec.hidden.to_le_bytes());
                payload.extend_from_slice(&spec.seed.to_le_bytes());
                payload.extend_from_slice(&pixels.to_le_bytes());
                payload.extend_from_slice(&(images.len() as u32).to_le_bytes());
                for &d in &spec.dims {
                    payload.extend_from_slice(&d.to_le_bytes());
                }
                for img in images {
                    if img.len() != *pixels as usize {
                        bail!("image length mismatch");
                    }
                    payload.extend_from_slice(img);
                }
            }
            Frame::StatsReq | Frame::Shutdown => {}
            Frame::StatsResp { json } => payload.extend_from_slice(json.as_bytes()),
            Frame::Error { message } => payload.extend_from_slice(message.as_bytes()),
        }
        let total = payload.len() + 1;
        w.write_all(&(total as u32).to_le_bytes())?;
        w.write_all(&[self.type_byte()])?;
        w.write_all(&payload)?;
        w.flush()?;
        Ok(())
    }

    /// Parse one frame from `body` — the type byte plus payload, i.e. a
    /// frame minus its length prefix. The server reads the prefix itself
    /// (interruptibly, so shutdown can cut idle reads short);
    /// [`Frame::read_from`] wraps this for plain blocking readers.
    pub fn parse(body: &[u8]) -> Result<Frame> {
        let Some((&ty, p)) = body.split_first() else {
            bail!("empty frame");
        };
        Ok(match ty {
            0x01 => {
                if p.is_empty() {
                    bail!("short CompressReq");
                }
                let mlen = p[0] as usize;
                if p.len() < 1 + mlen + 8 {
                    bail!("short CompressReq header");
                }
                let model = std::str::from_utf8(&p[1..1 + mlen])
                    .context("model name")?
                    .to_string();
                let pixels = u32::from_le_bytes(p[1 + mlen..5 + mlen].try_into().unwrap());
                let n = u32::from_le_bytes(p[5 + mlen..9 + mlen].try_into().unwrap());
                let images = read_image_grid(pixels, n, &p[9 + mlen..], "CompressReq")?;
                Frame::CompressReq {
                    model,
                    pixels,
                    images,
                }
            }
            0x02 => Frame::DecompressReq {
                container: p.to_vec(),
            },
            0x03 => Frame::StatsReq,
            0x04 => Frame::Shutdown,
            0x05 => {
                // schedule u8 | likelihood u8 | layers u8 | chunks u32 |
                // hidden u32 | seed u64 | pixels u32 | n u32 = 27 bytes.
                if p.len() < 27 {
                    bail!("short CompressHierReq header");
                }
                let schedule = Schedule::from_tag(p[0])?;
                let likelihood = Likelihood::from_tag(p[1])?;
                let layers = p[2] as usize;
                if !(1..=8).contains(&layers) {
                    bail!("CompressHierReq layer count {layers} out of range 1..=8");
                }
                let chunks = u32::from_le_bytes(p[3..7].try_into().unwrap());
                if chunks == 0 || chunks > MAX_HIER_CHUNKS {
                    bail!("CompressHierReq chunk count {chunks} out of range");
                }
                let hidden = u32::from_le_bytes(p[7..11].try_into().unwrap());
                if hidden == 0 || hidden > 1 << 20 {
                    bail!("CompressHierReq hidden width {hidden} out of range");
                }
                let seed = u64::from_le_bytes(p[11..19].try_into().unwrap());
                if seed == 0 {
                    bail!("CompressHierReq weight seed must be nonzero");
                }
                let pixels = u32::from_le_bytes(p[19..23].try_into().unwrap());
                let n = u32::from_le_bytes(p[23..27].try_into().unwrap());
                let dims_end = 27 + 4 * layers;
                if p.len() < dims_end {
                    bail!("short CompressHierReq dims");
                }
                let dims: Vec<u32> = (0..layers)
                    .map(|l| u32::from_le_bytes(p[27 + 4 * l..31 + 4 * l].try_into().unwrap()))
                    .collect();
                if dims.iter().any(|&d| d == 0 || d > 1 << 16) {
                    bail!("CompressHierReq layer dims must be in 1..=65536");
                }
                let images = read_image_grid(pixels, n, &p[dims_end..], "CompressHierReq")?;
                Frame::CompressHierReq {
                    spec: HierSpec {
                        schedule,
                        likelihood,
                        dims,
                        hidden,
                        seed,
                        chunks,
                    },
                    pixels,
                    images,
                }
            }
            0x81 => Frame::CompressResp {
                container: p.to_vec(),
            },
            0x82 => {
                // Same grid validation as 0x01 — this direction had the
                // identical zero-pixel hole.
                if p.len() < 8 {
                    bail!("short DecompressResp");
                }
                let pixels = u32::from_le_bytes(p[0..4].try_into().unwrap());
                let n = u32::from_le_bytes(p[4..8].try_into().unwrap());
                let images = read_image_grid(pixels, n, &p[8..], "DecompressResp")?;
                Frame::DecompressResp { pixels, images }
            }
            0x83 => Frame::StatsResp {
                json: String::from_utf8(p.to_vec()).context("stats json")?,
            },
            0x7f => Frame::Error {
                message: String::from_utf8_lossy(p).to_string(),
            },
            other => bail!("unknown frame type {other:#x}"),
        })
    }

    pub fn read_from(r: &mut impl Read) -> Result<Frame> {
        let mut len4 = [0u8; 4];
        r.read_exact(&mut len4).context("frame length")?;
        let total = u32::from_le_bytes(len4) as usize;
        if total == 0 || total > MAX_FRAME {
            bail!("bad frame length {total}");
        }
        let mut buf = vec![0u8; total];
        r.read_exact(&mut buf).context("frame body")?;
        Frame::parse(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let mut r = &buf[..];
        let g = Frame::read_from(&mut r).unwrap();
        assert_eq!(f, g);
    }

    fn hier_frame() -> Frame {
        Frame::CompressHierReq {
            spec: HierSpec {
                schedule: Schedule::BitSwap,
                likelihood: Likelihood::Bernoulli,
                dims: vec![6, 4],
                hidden: 10,
                seed: 99,
                chunks: 3,
            },
            pixels: 4,
            images: vec![vec![0, 1, 1, 0], vec![1, 0, 0, 1]],
        }
    }

    #[test]
    fn all_frames_roundtrip() {
        roundtrip(Frame::CompressReq {
            model: "bin".into(),
            pixels: 4,
            images: vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]],
        });
        roundtrip(Frame::CompressResp {
            container: vec![9, 9, 9],
        });
        roundtrip(Frame::DecompressReq {
            container: vec![1, 2],
        });
        roundtrip(Frame::DecompressResp {
            pixels: 2,
            images: vec![vec![0, 1]],
        });
        roundtrip(hier_frame());
        roundtrip(Frame::StatsReq);
        roundtrip(Frame::StatsResp {
            json: "{\"x\":1}".into(),
        });
        roundtrip(Frame::Error {
            message: "nope".into(),
        });
        roundtrip(Frame::Shutdown);
    }

    #[test]
    fn rejects_malformed() {
        // Zero length.
        let mut r: &[u8] = &[0, 0, 0, 0];
        assert!(Frame::read_from(&mut r).is_err());
        // Truncated body.
        let mut buf = Vec::new();
        Frame::StatsReq.write_to(&mut buf).unwrap();
        let mut r = &buf[..buf.len() - 1];
        let _ = Frame::read_from(&mut r); // must not panic
        // Unknown type.
        let mut r: &[u8] = &[1, 0, 0, 0, 0x55];
        assert!(Frame::read_from(&mut r).is_err());
        // Size-mismatched CompressReq.
        let mut bad = Vec::new();
        Frame::CompressReq {
            model: "m".into(),
            pixels: 4,
            images: vec![vec![0; 4]],
        }
        .write_to(&mut bad)
        .unwrap();
        let n = bad.len();
        bad[n - 5] ^= 1; // tamper with count
        let mut r = &bad[..];
        assert!(Frame::read_from(&mut r).is_err());
    }

    /// Hand-build a frame around a raw payload (type byte included by the
    /// caller) so tests can express grids `write_to` refuses to emit.
    fn raw_frame(ty: u8, payload: &[u8]) -> Vec<u8> {
        let mut frame = Vec::with_capacity(5 + payload.len());
        frame.extend_from_slice(&((payload.len() + 1) as u32).to_le_bytes());
        frame.push(ty);
        frame.extend_from_slice(payload);
        frame
    }

    /// Regression: `CompressReq { pixels: 0, n: u32::MAX }` used to pass
    /// the `body.len() == n * px` check as `0 == 0` and allocate 2^32
    /// empty `Vec`s. The same hole existed in `DecompressResp`.
    #[test]
    fn rejects_zero_pixel_image_flood() {
        let mut p = vec![3u8];
        p.extend_from_slice(b"toy");
        p.extend_from_slice(&0u32.to_le_bytes()); // pixels = 0
        p.extend_from_slice(&u32::MAX.to_le_bytes()); // n = 2^32 - 1
        let mut r = &raw_frame(0x01, &p)[..];
        let err = Frame::read_from(&mut r).unwrap_err();
        assert!(err.to_string().contains("zero-pixel"), "{err}");

        let mut p = Vec::new();
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut r = &raw_frame(0x82, &p)[..];
        let err = Frame::read_from(&mut r).unwrap_err();
        assert!(err.to_string().contains("zero-pixel"), "{err}");
    }

    /// Image grids are held to the container untrusted-input budget —
    /// an implausible `n`/`pixels` product errors before any sizing.
    #[test]
    fn rejects_budget_busting_image_grids() {
        // n beyond MAX_IMAGES at 1 pixel each.
        let mut p = vec![1u8, b'm'];
        p.extend_from_slice(&1u32.to_le_bytes());
        p.extend_from_slice(&((1u32 << 24) + 1).to_le_bytes());
        let mut r = &raw_frame(0x01, &p)[..];
        let err = Frame::read_from(&mut r).unwrap_err();
        assert!(err.to_string().contains("implausible"), "{err}");

        // n * pixels beyond the total-pixel budget.
        let mut p = vec![1u8, b'm'];
        p.extend_from_slice(&(1u32 << 20).to_le_bytes());
        p.extend_from_slice(&(1u32 << 16).to_le_bytes());
        let mut r = &raw_frame(0x01, &p)[..];
        let err = Frame::read_from(&mut r).unwrap_err();
        assert!(err.to_string().contains("pixels"), "{err}");
    }

    /// Adversarial sweep: random frames, every truncation of a valid
    /// frame, and an oversized length prefix must all return `Err` (or a
    /// harmless parse) without panicking or over-allocating.
    #[test]
    fn fuzzed_frames_never_panic() {
        let mut rng = Rng::new(0xF0_22);
        for _ in 0..2000 {
            let len = rng.below(64) as usize + 1;
            let mut frame = (len as u32).to_le_bytes().to_vec();
            for _ in 0..len {
                frame.push(rng.below(256) as u8);
            }
            let mut r = &frame[..];
            let _ = Frame::read_from(&mut r); // Ok or Err, never panic
        }

        // Every truncation of a valid multi-section frame errors cleanly.
        let mut buf = Vec::new();
        hier_frame().write_to(&mut buf).unwrap();
        for cut in 0..buf.len() {
            let mut r = &buf[..cut];
            assert!(Frame::read_from(&mut r).is_err(), "cut={cut}");
        }

        // Oversized length prefix is rejected before allocating.
        let mut r: &[u8] = &[0xff, 0xff, 0xff, 0xff, 0x01];
        assert!(Frame::read_from(&mut r).is_err());

        // Hier header validations: bad tags and zero fields all error.
        let good = {
            let mut buf = Vec::new();
            hier_frame().write_to(&mut buf).unwrap();
            buf[4..].to_vec() // type byte + payload
        };
        // (offset, value): bad schedule tag, bad likelihood tag, layer
        // count 0, layer count > 8.
        for (off, val) in [(1usize, 9u8), (2, 9), (3, 0), (3, 9)] {
            let mut b = good.clone();
            b[off] = val;
            assert!(Frame::parse(&b).is_err(), "off={off} val={val}");
        }
    }
}
