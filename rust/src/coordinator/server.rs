//! Framed-TCP compression server and client.
//!
//! One thread per connection (requests are large and long-lived; the
//! interesting concurrency is inside the model worker's batcher, not the
//! socket layer). All connections feed the shared [`ServiceHandle`], so
//! concurrent clients' NN work batches together.
//!
//! Connection reads use a short [`READ_TIMEOUT`] so every handler notices
//! the server's stop flag promptly even against an idle peer; that lets
//! [`Server::stop`] join connection threads instead of leaking them.
//! Handlers also distinguish a clean EOF at a frame boundary (normal
//! close) from a malformed or truncated frame, which is answered with
//! [`Frame::Error`], counted in `protocol_errors`, and followed by a
//! close — framing is unrecoverable once the byte stream desyncs.
//!
//! ## Stopping: abrupt vs graceful
//!
//! [`Server::stop`] raises the stop flag every handler polls, so
//! in-flight requests are abandoned at the next read boundary. For a
//! clean rollout use [`Server::drain`]: it closes the accept loop first,
//! lets connected peers finish their in-flight exchanges up to a
//! deadline, and only then raises the stop flag. A peer can also request
//! a drain over the wire ([`Frame::Shutdown`]); the server records it and
//! the serve loop (see `main.rs`) observes [`Server::drain_requested`].
//!
//! ## Observability
//!
//! When the global [`crate::obs::tracer`] is enabled, every work request
//! gets a trace id (the client's, via the version-flagged wire encoding,
//! or a server-assigned one) and the handler records `"request"` and
//! `"reply"` spans around the batcher's admission→nn→ans spans.
//! [`Frame::TraceReq`] and [`Frame::MetricsReq`] are answered handle-side,
//! never queued — like health probes, they must work while the worker is
//! wedged. [`Server::start_with_metrics`] can additionally bind a plain
//! HTTP/1.0 scrape listener that serves the Prometheus text exposition,
//! so a stock Prometheus scraper needs no framed-protocol client.

use std::io::{self, BufReader, BufWriter, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::batcher::ServiceHandle;
use super::metrics::Metrics;
use super::protocol::{FetchedPage, Frame, HierSpec, MAX_FRAME};
use crate::bbans::bbc4::Bbc4StreamReader;
use crate::util::rng::Rng;

/// Poll granularity for connection reads: how long a blocked read waits
/// before re-checking the stop flag.
const READ_TIMEOUT: Duration = Duration::from_millis(50);

/// A directory of published BBC4 containers servable page-at-a-time over
/// the wire ([`Frame::FetchPagesReq`]). Each fetch opens the file through
/// the bounded-memory [`Bbc4StreamReader`], so serving a page never
/// materializes the container; the per-page CRC echo comes from the
/// file's own trailer index. The dispatch counter lets chaos tests prove
/// a resumed transfer re-sends no page.
pub struct PageStore {
    dir: PathBuf,
    pages_served: AtomicU64,
}

impl PageStore {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self {
            dir: dir.into(),
            pages_served: AtomicU64::new(0),
        }
    }

    /// Total page frames dispatched over the wire since construction.
    pub fn pages_served(&self) -> u64 {
        self.pages_served.load(Ordering::SeqCst)
    }

    /// Answer one fetch: pages `[from_page, from_page + max_pages)`
    /// clamped to the container, with the header riding along when the
    /// range starts at page 0 and the trailer when it reaches the end.
    fn fetch(&self, name: &str, from_page: u32, max_pages: u32) -> Result<Frame> {
        // The name is an untrusted path component: no separators, no
        // dotfiles, no parent traversal.
        if name.is_empty() || name.contains(['/', '\\']) || name.starts_with('.') {
            bail!("invalid container name {name:?}");
        }
        let path = self.dir.join(name);
        let file = std::fs::File::open(&path).with_context(|| format!("open {}", path.display()))?;
        let mut rdr = Bbc4StreamReader::open(BufReader::new(file))
            .with_context(|| format!("{} is not a servable BBC4", path.display()))?;
        let n_pages = rdr.n_pages();
        if from_page >= n_pages {
            bail!("from_page {from_page} out of range 0..{n_pages}");
        }
        let end = (from_page as u64 + max_pages as u64).min(n_pages as u64) as u32;
        let header = if from_page == 0 {
            rdr.header_raw()?
        } else {
            Vec::new()
        };
        let mut pages = Vec::with_capacity((end - from_page) as usize);
        for i in from_page..end {
            let (bytes, crc) = rdr.raw_frame(i as usize)?;
            self.pages_served.fetch_add(1, Ordering::SeqCst);
            pages.push(FetchedPage { index: i, crc, bytes });
        }
        let trailer = if end == n_pages {
            rdr.trailer_raw().to_vec()
        } else {
            Vec::new()
        };
        Ok(Frame::FetchPagesResp {
            n_pages,
            from_page,
            header,
            trailer,
            pages,
        })
    }
}

/// A running server (owns the acceptor and all connection threads).
pub struct Server {
    pub addr: SocketAddr,
    /// Where the Prometheus scrape listener is bound, when one was
    /// requested via [`Server::start_with_metrics`].
    pub metrics_addr: Option<SocketAddr>,
    stop: Arc<AtomicBool>,
    /// Close only the accept loop (drain phase 1); existing connections
    /// keep serving until `stop` is raised or their peers hang up.
    accept_stop: Arc<AtomicBool>,
    /// Raised by a connection handler when a peer sends the wire drain
    /// op ([`Frame::Shutdown`]); the serve loop polls it.
    drain_flag: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    /// The scrape listener thread, joined on shutdown like the acceptor.
    metrics_thread: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Bind and serve in background threads.
    pub fn start(bind: &str, service: ServiceHandle) -> Result<Server> {
        Self::start_with_metrics(bind, service, None)
    }

    /// [`Server::start`] plus an optional plain-HTTP Prometheus scrape
    /// listener on `metrics_bind`. The listener speaks just enough
    /// HTTP/1.0 for `curl`/Prometheus: it reads and discards the request,
    /// then answers every connection with the current exposition text.
    pub fn start_with_metrics(
        bind: &str,
        service: ServiceHandle,
        metrics_bind: Option<&str>,
    ) -> Result<Server> {
        Self::start_with_store(bind, service, metrics_bind, None)
    }

    /// [`Server::start_with_metrics`] plus an optional [`PageStore`]: with
    /// one attached, the server answers [`Frame::FetchPagesReq`] from its
    /// directory (handler-side, never queued — a wedged worker cannot
    /// block a transfer resume).
    pub fn start_with_store(
        bind: &str,
        service: ServiceHandle,
        metrics_bind: Option<&str>,
        store: Option<Arc<PageStore>>,
    ) -> Result<Server> {
        let listener = TcpListener::bind(bind).with_context(|| format!("bind {bind}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::new(AtomicBool::new(false));
        let drain_flag = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let metrics = service.metrics.clone();
        let stop2 = stop.clone();
        let accept_stop2 = accept_stop.clone();
        let drain2 = drain_flag.clone();
        let conns2 = conns.clone();
        let acceptor = std::thread::Builder::new()
            .name("bbans-acceptor".into())
            .spawn(move || {
                // Nonblocking accept loop so `stop` is honoured promptly.
                listener.set_nonblocking(true).ok();
                while !stop2.load(Ordering::Relaxed) && !accept_stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let svc = service.clone();
                            let conn_stop = stop2.clone();
                            let conn_drain = drain2.clone();
                            let conn_store = store.clone();
                            let handle = std::thread::spawn(move || {
                                let _ = handle_conn(stream, svc, conn_stop, conn_drain, conn_store);
                            });
                            let mut guard = conns2.lock().expect("conns lock");
                            // Reap finished handlers so the vec stays
                            // bounded under connection churn.
                            guard.retain(|h| !h.is_finished());
                            guard.push(handle);
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        let (metrics_addr, metrics_thread) = match metrics_bind {
            Some(mb) => {
                let (a, h) = start_metrics_listener(mb, metrics, stop.clone())?;
                (Some(a), Some(h))
            }
            None => (None, None),
        };
        Ok(Server {
            addr,
            metrics_addr,
            stop,
            accept_stop,
            drain_flag,
            acceptor: Some(acceptor),
            metrics_thread,
            conns,
        })
    }

    /// Whether a peer has requested a drain over the wire
    /// ([`Frame::Shutdown`]). The serve loop polls this to decide when to
    /// call [`Server::drain`].
    pub fn drain_requested(&self) -> bool {
        self.drain_flag.load(Ordering::Relaxed)
    }

    /// Stop accepting, then join the acceptor and every connection
    /// thread. Handlers poll the stop flag between reads, so this returns
    /// once in-flight requests drain — no threads are leaked.
    pub fn stop(mut self) {
        self.shutdown_impl();
    }

    /// Graceful drain: close the accept loop, give connected peers up to
    /// `timeout` to finish their exchanges and hang up, then raise the
    /// stop flag and join everything. Returns `true` if every connection
    /// closed on its own within the deadline (a clean drain) and `false`
    /// if the deadline forced the stop flag on stragglers.
    pub fn drain(mut self, timeout: Duration) -> bool {
        self.accept_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let deadline = Instant::now() + timeout;
        let clean = loop {
            let all_done = self
                .conns
                .lock()
                .expect("conns lock")
                .iter()
                .all(|h| h.is_finished());
            if all_done {
                break true;
            }
            if Instant::now() >= deadline {
                break false;
            }
            std::thread::sleep(Duration::from_millis(5));
        };
        // Joins promptly either way: handlers are finished (clean) or
        // will observe the stop flag at their next read poll.
        self.shutdown_impl();
        clean
    }

    fn shutdown_impl(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        if let Some(h) = self.metrics_thread.take() {
            let _ = h.join();
        }
        let handles = std::mem::take(&mut *self.conns.lock().expect("conns lock"));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Bind the Prometheus scrape listener: a nonblocking accept loop that
/// answers every connection with one `HTTP/1.0 200` response carrying
/// the current [`Metrics::to_prometheus`] text. The request line and
/// headers are read (bounded) and discarded — every path scrapes.
fn start_metrics_listener(
    bind: &str,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) -> Result<(SocketAddr, JoinHandle<()>)> {
    let listener = TcpListener::bind(bind).with_context(|| format!("bind metrics {bind}"))?;
    let addr = listener.local_addr()?;
    let handle = std::thread::Builder::new()
        .name("bbans-metrics".into())
        .spawn(move || {
            listener.set_nonblocking(true).ok();
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let _ = serve_scrape(stream, &metrics);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        })?;
    Ok((addr, handle))
}

/// One scrape exchange: drain the HTTP request until the blank line (or
/// a short timeout — a bare TCP probe with no request also gets the
/// body), then write the exposition and close.
fn serve_scrape(mut stream: TcpStream, metrics: &Metrics) -> io::Result<()> {
    use std::io::Write;
    stream.set_read_timeout(Some(Duration::from_millis(250))).ok();
    let mut req = Vec::new();
    let mut chunk = [0u8; 512];
    // Bounded read: stop at end-of-headers, EOF, timeout, or 8 KiB.
    while req.len() < 8192 && !req.windows(4).any(|w| w == b"\r\n\r\n") {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => req.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_read_timeout(&e) => break,
            Err(e) => return Err(e),
        }
    }
    let body = metrics.to_prometheus();
    let head = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// How a completed full read ended.
enum Status {
    Done,
    /// Clean close before the first byte of the buffer.
    Eof,
    /// The server's stop flag was raised while waiting.
    Stopped,
}

/// Outcome of one framed read.
enum ReadOutcome {
    Frame(Frame),
    Eof,
    Stopped,
}

/// `WouldBlock` on Unix, `TimedOut` on Windows: both mean the read timer
/// fired with no data.
fn is_read_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Fill `buf` completely, polling `stop` whenever the read times out. A
/// close before any byte arrives is `Status::Eof`; a close mid-buffer is
/// an `UnexpectedEof` error (the peer truncated whatever it was sending).
fn read_full(r: &mut impl Read, buf: &mut [u8], stop: &AtomicBool) -> io::Result<Status> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(Status::Eof);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("peer closed after {filled} of {} bytes", buf.len()),
                ));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_read_timeout(&e) => {
                if stop.load(Ordering::Relaxed) {
                    return Ok(Status::Stopped);
                }
            }
            Err(e) => return Err(e),
        }
    }
    Ok(Status::Done)
}

/// Read one length-prefixed frame, honouring `stop`. EOF at a frame
/// boundary is a clean close; anywhere else it is a protocol error.
fn read_frame(r: &mut impl Read, stop: &AtomicBool) -> Result<ReadOutcome> {
    let mut len4 = [0u8; 4];
    match read_full(r, &mut len4, stop).context("frame length")? {
        Status::Eof => return Ok(ReadOutcome::Eof),
        Status::Stopped => return Ok(ReadOutcome::Stopped),
        Status::Done => {}
    }
    let total = u32::from_le_bytes(len4) as usize;
    if total == 0 || total > MAX_FRAME {
        bail!("bad frame length {total}");
    }
    let mut buf = vec![0u8; total];
    match read_full(r, &mut buf, stop).context("frame body")? {
        Status::Eof => bail!("connection closed mid-frame"),
        Status::Stopped => return Ok(ReadOutcome::Stopped),
        Status::Done => {}
    }
    Ok(ReadOutcome::Frame(Frame::parse(&buf)?))
}

/// Wire TTL (milliseconds) to the batcher's per-job deadline form.
fn ttl_duration(ttl_ms: Option<u32>) -> Option<Duration> {
    ttl_ms.map(|t| Duration::from_millis(t as u64))
}

fn handle_conn(
    stream: TcpStream,
    svc: ServiceHandle,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    store: Option<Arc<PageStore>>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Short read timeout: the handler polls the stop flag between reads,
    // so `Server::stop` can join this thread even while the peer idles.
    stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let frame = match read_frame(&mut reader, &stop) {
            Ok(ReadOutcome::Frame(f)) => f,
            Ok(ReadOutcome::Eof) | Ok(ReadOutcome::Stopped) => return Ok(()),
            Err(e) => {
                // Malformed or truncated frame: tell the peer why, count
                // it, and drop the connection.
                Metrics::inc(&svc.metrics.protocol_errors, 1);
                let reply = Frame::Error {
                    message: format!("protocol error: {e:#}"),
                };
                let _ = reply.write_to(&mut writer);
                return Ok(());
            }
        };
        // Work requests trace under the client's id (version-flagged wire
        // encoding) or, when tracing is on, a server-assigned one. 0 means
        // untraced — every record() under it is a no-op.
        let tracer = crate::obs::tracer();
        let is_work = matches!(
            frame,
            Frame::CompressReq { .. } | Frame::CompressHierReq { .. } | Frame::DecompressReq { .. }
        );
        let trace = frame.trace_id().unwrap_or_else(|| {
            if is_work && tracer.enabled() {
                tracer.next_trace_id()
            } else {
                0
            }
        });
        let t_req = Instant::now();
        let resp = match frame {
            Frame::CompressReq {
                model,
                images,
                ttl_ms,
                ..
            } => match svc.compress_opts(&model, images, ttl_duration(ttl_ms), trace) {
                Ok(container) => Frame::CompressResp { container },
                Err(e) => Frame::Error {
                    message: format!("{e:#}"),
                },
            },
            Frame::CompressHierReq {
                spec,
                images,
                ttl_ms,
                ..
            } => match svc.compress_hier_opts(spec, images, ttl_duration(ttl_ms), trace) {
                Ok(container) => Frame::CompressResp { container },
                Err(e) => Frame::Error {
                    message: format!("{e:#}"),
                },
            },
            Frame::DecompressReq {
                container, ttl_ms, ..
            } => match svc.decompress_opts(container, ttl_duration(ttl_ms), trace) {
                Ok(images) => Frame::DecompressResp {
                    pixels: images.first().map(|i| i.len() as u32).unwrap_or(0),
                    images,
                },
                Err(e) => Frame::Error {
                    message: format!("{e:#}"),
                },
            },
            Frame::TraceReq { max } => Frame::TraceResp {
                json: tracer.snapshot_json(max as usize).to_string(),
            },
            Frame::MetricsReq => Frame::MetricsResp {
                text: svc.metrics.to_prometheus(),
            },
            Frame::StatsReq => match svc.stats_json() {
                Ok(json) => Frame::StatsResp { json },
                Err(e) => Frame::Error {
                    message: format!("{e:#}"),
                },
            },
            Frame::HealthReq => Frame::HealthResp {
                json: svc.health_json(),
            },
            Frame::FetchPagesReq {
                name,
                from_page,
                max_pages,
                ..
            } => match &store {
                // Handler-served like health/trace: a transfer resume
                // must work while the worker is wedged.
                Some(ps) => match ps.fetch(&name, from_page, max_pages) {
                    Ok(resp) => resp,
                    Err(e) => Frame::Error {
                        message: format!("{e:#}"),
                    },
                },
                None => Frame::Error {
                    message: "no page store configured".into(),
                },
            },
            Frame::Shutdown => {
                // Wire drain request: record it for the serve loop and
                // close this connection. Whether (and how fast) the
                // process exits is the serve loop's policy.
                drain.store(true, Ordering::Relaxed);
                return Ok(());
            }
            other => Frame::Error {
                message: format!("unexpected frame {other:?}"),
            },
        };
        // Reply-size hint for the reply span (payload bytes, not frame
        // overhead — the interesting number for bandwidth accounting).
        let reply_bytes = match &resp {
            Frame::CompressResp { container } => container.len() as u64,
            Frame::DecompressResp { images, .. } => {
                images.iter().map(|i| i.len() as u64).sum()
            }
            _ => 0,
        };
        let t_reply = Instant::now();
        resp.write_to(&mut writer)?;
        if trace != 0 {
            tracer.record(trace, "reply", t_reply, t_reply.elapsed(), reply_bytes);
            tracer.record(trace, "request", t_req, t_req.elapsed(), 1);
            // Terminal flush: the trace is scrape-complete once the reply
            // is on the wire.
            tracer.flush();
        }
    }
}

/// Dial the first responsive address under the policy's connect timeout
/// and apply the policy's socket options.
fn dial(addrs: &[SocketAddr], policy: &RetryPolicy) -> Result<TcpStream> {
    let mut last: Option<io::Error> = None;
    for a in addrs {
        match TcpStream::connect_timeout(a, policy.connect_timeout) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                s.set_read_timeout(policy.read_timeout).ok();
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
    }
    let e = last
        .unwrap_or_else(|| io::Error::new(io::ErrorKind::NotConnected, "no addresses to dial"));
    Err(anyhow::Error::new(e).context("connect"))
}

/// Retry/backoff knobs for [`Client`]'s transient-failure handling.
///
/// Transient means the request may succeed if simply tried again: an
/// admission rejection from the server's bounded queue ("service
/// overloaded"), a connection reset/refusal, or a read timeout. Anything
/// else — a protocol error, a codec failure, an unknown model — is
/// returned immediately; retrying cannot fix it.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Retry attempts after the first try (0 = fail fast).
    pub max_retries: u32,
    /// Backoff before retry `k` (0-based) is `base_delay << k`, capped at
    /// [`Self::max_delay`], then jittered to 50–100% of that value so a
    /// burst of rejected clients does not re-converge on the same instant.
    pub base_delay: Duration,
    pub max_delay: Duration,
    /// Timeout for establishing the TCP connection.
    pub connect_timeout: Duration,
    /// Socket read timeout while awaiting a response (`None` = block
    /// until the peer answers or closes).
    pub read_timeout: Option<Duration>,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_retries: 4,
            base_delay: Duration::from_millis(50),
            max_delay: Duration::from_secs(2),
            connect_timeout: Duration::from_secs(5),
            read_timeout: Some(Duration::from_secs(120)),
        }
    }
}

impl RetryPolicy {
    /// Fail-fast policy: no retries, no read timeout — the pre-policy
    /// client behaviour (what [`Client::connect`] uses).
    pub fn none() -> Self {
        Self {
            max_retries: 0,
            base_delay: Duration::ZERO,
            max_delay: Duration::ZERO,
            connect_timeout: Duration::from_secs(5),
            read_timeout: None,
        }
    }

    /// Jittered exponential backoff before 0-based retry `attempt`.
    fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let exp = self
            .base_delay
            .saturating_mul(1u32 << attempt.min(16))
            .min(self.max_delay);
        exp.mul_f64(0.5 + 0.5 * rng.f64())
    }
}

/// Io error kinds that signal a transient transport failure (the peer or
/// network hiccuped; the byte stream is dead but a fresh connection may
/// work).
fn is_transient_io(kind: io::ErrorKind) -> bool {
    matches!(
        kind,
        io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::ConnectionRefused
            | io::ErrorKind::BrokenPipe
            | io::ErrorKind::UnexpectedEof
            | io::ErrorKind::NotConnected
            | io::ErrorKind::TimedOut
            | io::ErrorKind::WouldBlock
    )
}

/// Whether any cause in `e`'s chain is a transient io error.
fn has_transient_io(e: &anyhow::Error) -> bool {
    e.chain().any(|c| {
        c.downcast_ref::<io::Error>()
            .is_some_and(|io_err| is_transient_io(io_err.kind()))
    })
}

/// How one request attempt failed, for the retry loop.
enum CallError {
    /// Worth retrying after backoff; `reconnect` says whether the
    /// connection byte stream is suspect and must be re-established.
    Transient { error: anyhow::Error, reconnect: bool },
    Fatal(anyhow::Error),
}

/// One verified page range pulled by [`Client::fetch_pages`]: the
/// server's [`Frame::FetchPagesResp`] after every page frame passed the
/// client-side CRC re-check against the per-page echo.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PageRange {
    /// Total pages the remote container holds.
    pub n_pages: u32,
    /// First page in this range.
    pub from_page: u32,
    /// Raw header bytes (non-empty only when `from_page == 0`).
    pub header: Vec<u8>,
    /// Raw trailer-index bytes (non-empty only when the range reaches
    /// the last page).
    pub trailer: Vec<u8>,
    /// Verified page frames, consecutive from `from_page`.
    pub pages: Vec<FetchedPage>,
}

/// Blocking client for the framed protocol, with bounded retry and
/// jittered exponential backoff for transient failures.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Resolved server addresses, kept so a retry can re-dial.
    addrs: Vec<SocketAddr>,
    policy: RetryPolicy,
    rng: Rng,
    /// Transport re-dials since connect (observability probes assert 0:
    /// a probe must ride the connection of the request it follows).
    reconnects: u64,
}

impl Client {
    /// Connect fail-fast (no retries, no read timeout).
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        Self::connect_with(addr, RetryPolicy::none())
    }

    /// Connect under `policy`: the dial honours
    /// [`RetryPolicy::connect_timeout`] and transient connect failures
    /// are retried with backoff like any other request.
    pub fn connect_with(addr: impl std::net::ToSocketAddrs, policy: RetryPolicy) -> Result<Client> {
        let addrs: Vec<SocketAddr> = addr
            .to_socket_addrs()
            .context("resolve server address")?
            .collect();
        if addrs.is_empty() {
            bail!("server address resolved to nothing");
        }
        // Seed the jitter from wall clock + pid: backoff spread needs
        // distinctness across client processes, not reproducibility.
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x9E37_79B9);
        let mut rng = Rng::new(nanos ^ ((std::process::id() as u64) << 32));
        let mut attempt = 0u32;
        let stream = loop {
            match dial(&addrs, &policy) {
                Ok(s) => break s,
                Err(e) => {
                    if attempt >= policy.max_retries || !has_transient_io(&e) {
                        return Err(e);
                    }
                    std::thread::sleep(policy.backoff(attempt, &mut rng));
                    attempt += 1;
                }
            }
        };
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            addrs,
            policy,
            rng,
            reconnects: 0,
        })
    }

    /// Replace the connection after a transport-level failure (the old
    /// byte stream may be dead or desynchronized mid-frame).
    fn reconnect(&mut self) -> Result<()> {
        let stream = dial(&self.addrs, &self.policy)?;
        self.reader = BufReader::new(stream.try_clone()?);
        self.writer = BufWriter::new(stream);
        self.reconnects += 1;
        Ok(())
    }

    /// How many times the transport was re-dialed since connect. Stays 0
    /// while every exchange reuses the original connection — the property
    /// the `--trace`/`--metrics` probe path asserts.
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    /// One request/response exchange on the current connection.
    fn call_once(&mut self, req: &Frame) -> std::result::Result<Frame, CallError> {
        if let Err(e) = req.write_to(&mut self.writer) {
            let reconnect = has_transient_io(&e);
            return Err(if reconnect {
                CallError::Transient { error: e, reconnect: true }
            } else {
                CallError::Fatal(e)
            });
        }
        let resp = match Frame::read_from(&mut self.reader) {
            Ok(f) => f,
            Err(e) => {
                let reconnect = has_transient_io(&e);
                return Err(if reconnect {
                    CallError::Transient { error: e, reconnect: true }
                } else {
                    CallError::Fatal(e)
                });
            }
        };
        if let Frame::Error { message } = &resp {
            let error = anyhow::anyhow!("server error: {message}");
            // An admission rejection leaves the connection at a clean
            // frame boundary — retry on the same connection; anything
            // else the server reports is not fixed by retrying.
            return Err(if message.contains("overloaded") {
                CallError::Transient { error, reconnect: false }
            } else {
                CallError::Fatal(error)
            });
        }
        Ok(resp)
    }

    fn call(&mut self, req: Frame) -> Result<Frame> {
        let mut attempt = 0u32;
        loop {
            match self.call_once(&req) {
                Ok(resp) => return Ok(resp),
                Err(CallError::Fatal(e)) => return Err(e),
                Err(CallError::Transient { error, reconnect }) => {
                    if attempt >= self.policy.max_retries {
                        return Err(error.context(format!(
                            "request failed after {} attempt(s)",
                            attempt + 1
                        )));
                    }
                    std::thread::sleep(self.policy.backoff(attempt, &mut self.rng));
                    attempt += 1;
                    if reconnect {
                        // A failed re-dial is itself transient: charge an
                        // attempt and keep backing off.
                        if let Err(e) = self.reconnect() {
                            if attempt > self.policy.max_retries || !has_transient_io(&e) {
                                return Err(e.context("reconnect for retry"));
                            }
                        }
                    }
                }
            }
        }
    }

    pub fn compress(&mut self, model: &str, pixels: u32, images: Vec<Vec<u8>>) -> Result<Vec<u8>> {
        self.compress_with_ttl(model, pixels, images, None)
    }

    /// [`Client::compress`] with a server-side queue TTL: if the request
    /// is still queued on the server when `ttl_ms` elapses it is shed
    /// with a "deadline exceeded" error instead of burning NN time.
    /// Sends the version-flagged v2 encoding; omit the TTL to stay
    /// byte-compatible with pre-TTL servers.
    pub fn compress_with_ttl(
        &mut self,
        model: &str,
        pixels: u32,
        images: Vec<Vec<u8>>,
        ttl_ms: Option<u32>,
    ) -> Result<Vec<u8>> {
        self.compress_with_opts(model, pixels, images, ttl_ms, None)
    }

    /// [`Client::compress_with_ttl`] plus a trace id: the server records
    /// this request's lifecycle spans under `trace_id`, retrievable with
    /// [`Client::trace`]. Both options ride the version-flagged wire
    /// encoding; with neither set the request bytes are v1-identical.
    pub fn compress_with_opts(
        &mut self,
        model: &str,
        pixels: u32,
        images: Vec<Vec<u8>>,
        ttl_ms: Option<u32>,
        trace_id: Option<u64>,
    ) -> Result<Vec<u8>> {
        match self.call(Frame::CompressReq {
            model: model.to_string(),
            pixels,
            images,
            ttl_ms,
            trace_id,
        })? {
            Frame::CompressResp { container } => Ok(container),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Compress with a freshly seeded hierarchical (BBC3) model described
    /// entirely by `spec` — no pre-registered model name needed.
    pub fn compress_hier(
        &mut self,
        spec: HierSpec,
        pixels: u32,
        images: Vec<Vec<u8>>,
    ) -> Result<Vec<u8>> {
        self.compress_hier_with_ttl(spec, pixels, images, None)
    }

    /// [`Client::compress_hier`] with a server-side queue TTL.
    pub fn compress_hier_with_ttl(
        &mut self,
        spec: HierSpec,
        pixels: u32,
        images: Vec<Vec<u8>>,
        ttl_ms: Option<u32>,
    ) -> Result<Vec<u8>> {
        self.compress_hier_with_opts(spec, pixels, images, ttl_ms, None)
    }

    /// [`Client::compress_hier_with_ttl`] plus a trace id (see
    /// [`Client::compress_with_opts`]).
    pub fn compress_hier_with_opts(
        &mut self,
        spec: HierSpec,
        pixels: u32,
        images: Vec<Vec<u8>>,
        ttl_ms: Option<u32>,
        trace_id: Option<u64>,
    ) -> Result<Vec<u8>> {
        match self.call(Frame::CompressHierReq {
            spec,
            pixels,
            images,
            ttl_ms,
            trace_id,
        })? {
            Frame::CompressResp { container } => Ok(container),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    pub fn decompress(&mut self, container: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        self.decompress_with_ttl(container, None)
    }

    /// [`Client::decompress`] with a server-side queue TTL.
    pub fn decompress_with_ttl(
        &mut self,
        container: Vec<u8>,
        ttl_ms: Option<u32>,
    ) -> Result<Vec<Vec<u8>>> {
        self.decompress_with_opts(container, ttl_ms, None)
    }

    /// [`Client::decompress_with_ttl`] plus a trace id (see
    /// [`Client::compress_with_opts`]).
    pub fn decompress_with_opts(
        &mut self,
        container: Vec<u8>,
        ttl_ms: Option<u32>,
        trace_id: Option<u64>,
    ) -> Result<Vec<Vec<u8>>> {
        match self.call(Frame::DecompressReq {
            container,
            ttl_ms,
            trace_id,
        })? {
            Frame::DecompressResp { images, .. } => Ok(images),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<String> {
        match self.call(Frame::StatsReq)? {
            Frame::StatsResp { json } => Ok(json),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Health probe: worker liveness, queue depth, quarantine set, and
    /// fault counters as a JSON string. Served handle-side, so it
    /// answers even when the admission queue is full or the worker died.
    pub fn health(&mut self) -> Result<String> {
        match self.call(Frame::HealthReq)? {
            Frame::HealthResp { json } => Ok(json),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Fetch up to `max` recent traces from the server's span ring as a
    /// JSON snapshot (see `obs::trace::Tracer::snapshot_json` for the
    /// schema). Served handle-side, never queued.
    pub fn trace(&mut self, max: u32) -> Result<String> {
        match self.call(Frame::TraceReq { max })? {
            Frame::TraceResp { json } => Ok(json),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Fetch the server's metrics in Prometheus text exposition format.
    /// Served handle-side, never queued.
    pub fn metrics_text(&mut self) -> Result<String> {
        match self.call(Frame::MetricsReq)? {
            Frame::MetricsResp { text } => Ok(text),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Pull up to `max_pages` page frames of the published container
    /// `name` starting at `from_page`, and verify every frame against
    /// the server's per-page CRC echo before returning it. Rides the
    /// normal retry loop, so a dropped connection re-dials and the caller
    /// simply re-requests from its last intact page — the server never
    /// re-sends pages before `from_page`.
    pub fn fetch_pages(
        &mut self,
        name: &str,
        from_page: u32,
        max_pages: u32,
    ) -> Result<PageRange> {
        let resp = self.call(Frame::FetchPagesReq {
            name: name.to_string(),
            from_page,
            max_pages,
            ttl_ms: None,
            trace_id: None,
        })?;
        let Frame::FetchPagesResp {
            n_pages,
            from_page: got_from,
            header,
            trailer,
            pages,
        } = resp
        else {
            anyhow::bail!("unexpected response {resp:?}");
        };
        if got_from != from_page {
            anyhow::bail!("server answered from page {got_from}, asked for {from_page}");
        }
        for pg in &pages {
            // Trust nothing about the transport: re-read the frame from
            // its own bytes and hold it to the CRC echo.
            match crate::format::read_frame(&pg.bytes, 0) {
                crate::format::FrameRead::Ok { frame, next }
                    if next == pg.bytes.len()
                        && frame.index == pg.index
                        && frame.crc() == pg.crc => {}
                _ => anyhow::bail!(
                    "page {} arrived corrupt (CRC echo mismatch); refetch from page {}",
                    pg.index,
                    pg.index
                ),
            }
        }
        Ok(PageRange {
            n_pages,
            from_page,
            header,
            trailer,
            pages,
        })
    }

    /// Ask the server to drain: it stops accepting new connections,
    /// finishes in-flight requests, and exits its serve loop. Fire and
    /// forget — the server closes this connection without a response.
    pub fn shutdown_server(&mut self) -> Result<()> {
        Frame::Shutdown.write_to(&mut self.writer)?;
        Ok(())
    }
}
