//! Framed-TCP compression server and client.
//!
//! One thread per connection (requests are large and long-lived; the
//! interesting concurrency is inside the model worker's batcher, not the
//! socket layer). All connections feed the shared [`ServiceHandle`], so
//! concurrent clients' NN work batches together.

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::batcher::ServiceHandle;
use super::protocol::Frame;

/// A running server (owns the acceptor thread).
pub struct Server {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind and serve in background threads.
    pub fn start(bind: &str, service: ServiceHandle) -> Result<Server> {
        let listener = TcpListener::bind(bind).with_context(|| format!("bind {bind}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let acceptor = std::thread::Builder::new()
            .name("bbans-acceptor".into())
            .spawn(move || {
                // Nonblocking accept loop so `stop` is honoured promptly.
                // Connection threads are detached: they exit when the peer
                // closes (joining them here would deadlock `stop()` against
                // clients that keep their connection open).
                listener.set_nonblocking(true).ok();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let svc = service.clone();
                            std::thread::spawn(move || {
                                let _ = handle_conn(stream, svc);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server {
            addr,
            stop,
            acceptor: Some(acceptor),
        })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, svc: ServiceHandle) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let frame = match Frame::read_from(&mut reader) {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer closed
        };
        let resp = match frame {
            Frame::CompressReq { model, images, .. } => match svc.compress(&model, images) {
                Ok(container) => Frame::CompressResp { container },
                Err(e) => Frame::Error {
                    message: format!("{e:#}"),
                },
            },
            Frame::DecompressReq { container } => match svc.decompress(container) {
                Ok(images) => Frame::DecompressResp {
                    pixels: images.first().map(|i| i.len() as u32).unwrap_or(0),
                    images,
                },
                Err(e) => Frame::Error {
                    message: format!("{e:#}"),
                },
            },
            Frame::StatsReq => match svc.stats_json() {
                Ok(json) => Frame::StatsResp { json },
                Err(e) => Frame::Error {
                    message: format!("{e:#}"),
                },
            },
            Frame::Shutdown => return Ok(()),
            other => Frame::Error {
                message: format!("unexpected frame {other:?}"),
            },
        };
        resp.write_to(&mut writer)?;
    }
}

/// Blocking client for the framed protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    fn call(&mut self, req: Frame) -> Result<Frame> {
        req.write_to(&mut self.writer)?;
        let resp = Frame::read_from(&mut self.reader)?;
        if let Frame::Error { message } = &resp {
            anyhow::bail!("server error: {message}");
        }
        Ok(resp)
    }

    pub fn compress(&mut self, model: &str, pixels: u32, images: Vec<Vec<u8>>) -> Result<Vec<u8>> {
        match self.call(Frame::CompressReq {
            model: model.to_string(),
            pixels,
            images,
        })? {
            Frame::CompressResp { container } => Ok(container),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    pub fn decompress(&mut self, container: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        match self.call(Frame::DecompressReq { container })? {
            Frame::DecompressResp { images, .. } => Ok(images),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<String> {
        match self.call(Frame::StatsReq)? {
            Frame::StatsResp { json } => Ok(json),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }
}
