//! Framed-TCP compression server and client.
//!
//! One thread per connection (requests are large and long-lived; the
//! interesting concurrency is inside the model worker's batcher, not the
//! socket layer). All connections feed the shared [`ServiceHandle`], so
//! concurrent clients' NN work batches together.
//!
//! Connection reads use a short [`READ_TIMEOUT`] so every handler notices
//! the server's stop flag promptly even against an idle peer; that lets
//! [`Server::stop`] join connection threads instead of leaking them.
//! Handlers also distinguish a clean EOF at a frame boundary (normal
//! close) from a malformed or truncated frame, which is answered with
//! [`Frame::Error`], counted in `protocol_errors`, and followed by a
//! close — framing is unrecoverable once the byte stream desyncs.

use std::io::{self, BufReader, BufWriter, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::batcher::ServiceHandle;
use super::metrics::Metrics;
use super::protocol::{Frame, HierSpec, MAX_FRAME};

/// Poll granularity for connection reads: how long a blocked read waits
/// before re-checking the stop flag.
const READ_TIMEOUT: Duration = Duration::from_millis(50);

/// A running server (owns the acceptor and all connection threads).
pub struct Server {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Bind and serve in background threads.
    pub fn start(bind: &str, service: ServiceHandle) -> Result<Server> {
        let listener = TcpListener::bind(bind).with_context(|| format!("bind {bind}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let stop2 = stop.clone();
        let conns2 = conns.clone();
        let acceptor = std::thread::Builder::new()
            .name("bbans-acceptor".into())
            .spawn(move || {
                // Nonblocking accept loop so `stop` is honoured promptly.
                listener.set_nonblocking(true).ok();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let svc = service.clone();
                            let conn_stop = stop2.clone();
                            let handle = std::thread::spawn(move || {
                                let _ = handle_conn(stream, svc, conn_stop);
                            });
                            let mut guard = conns2.lock().expect("conns lock");
                            // Reap finished handlers so the vec stays
                            // bounded under connection churn.
                            guard.retain(|h| !h.is_finished());
                            guard.push(handle);
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server {
            addr,
            stop,
            acceptor: Some(acceptor),
            conns,
        })
    }

    /// Stop accepting, then join the acceptor and every connection
    /// thread. Handlers poll the stop flag between reads, so this returns
    /// once in-flight requests drain — no threads are leaked.
    pub fn stop(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let handles = std::mem::take(&mut *self.conns.lock().expect("conns lock"));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// How a completed full read ended.
enum Status {
    Done,
    /// Clean close before the first byte of the buffer.
    Eof,
    /// The server's stop flag was raised while waiting.
    Stopped,
}

/// Outcome of one framed read.
enum ReadOutcome {
    Frame(Frame),
    Eof,
    Stopped,
}

/// `WouldBlock` on Unix, `TimedOut` on Windows: both mean the read timer
/// fired with no data.
fn is_read_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Fill `buf` completely, polling `stop` whenever the read times out. A
/// close before any byte arrives is `Status::Eof`; a close mid-buffer is
/// an `UnexpectedEof` error (the peer truncated whatever it was sending).
fn read_full(r: &mut impl Read, buf: &mut [u8], stop: &AtomicBool) -> io::Result<Status> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(Status::Eof);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("peer closed after {filled} of {} bytes", buf.len()),
                ));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_read_timeout(&e) => {
                if stop.load(Ordering::Relaxed) {
                    return Ok(Status::Stopped);
                }
            }
            Err(e) => return Err(e),
        }
    }
    Ok(Status::Done)
}

/// Read one length-prefixed frame, honouring `stop`. EOF at a frame
/// boundary is a clean close; anywhere else it is a protocol error.
fn read_frame(r: &mut impl Read, stop: &AtomicBool) -> Result<ReadOutcome> {
    let mut len4 = [0u8; 4];
    match read_full(r, &mut len4, stop).context("frame length")? {
        Status::Eof => return Ok(ReadOutcome::Eof),
        Status::Stopped => return Ok(ReadOutcome::Stopped),
        Status::Done => {}
    }
    let total = u32::from_le_bytes(len4) as usize;
    if total == 0 || total > MAX_FRAME {
        bail!("bad frame length {total}");
    }
    let mut buf = vec![0u8; total];
    match read_full(r, &mut buf, stop).context("frame body")? {
        Status::Eof => bail!("connection closed mid-frame"),
        Status::Stopped => return Ok(ReadOutcome::Stopped),
        Status::Done => {}
    }
    Ok(ReadOutcome::Frame(Frame::parse(&buf)?))
}

fn handle_conn(stream: TcpStream, svc: ServiceHandle, stop: Arc<AtomicBool>) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Short read timeout: the handler polls the stop flag between reads,
    // so `Server::stop` can join this thread even while the peer idles.
    stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let frame = match read_frame(&mut reader, &stop) {
            Ok(ReadOutcome::Frame(f)) => f,
            Ok(ReadOutcome::Eof) | Ok(ReadOutcome::Stopped) => return Ok(()),
            Err(e) => {
                // Malformed or truncated frame: tell the peer why, count
                // it, and drop the connection.
                Metrics::inc(&svc.metrics.protocol_errors, 1);
                let reply = Frame::Error {
                    message: format!("protocol error: {e:#}"),
                };
                let _ = reply.write_to(&mut writer);
                return Ok(());
            }
        };
        let resp = match frame {
            Frame::CompressReq { model, images, .. } => match svc.compress(&model, images) {
                Ok(container) => Frame::CompressResp { container },
                Err(e) => Frame::Error {
                    message: format!("{e:#}"),
                },
            },
            Frame::CompressHierReq { spec, images, .. } => match svc.compress_hier(spec, images) {
                Ok(container) => Frame::CompressResp { container },
                Err(e) => Frame::Error {
                    message: format!("{e:#}"),
                },
            },
            Frame::DecompressReq { container } => match svc.decompress(container) {
                Ok(images) => Frame::DecompressResp {
                    pixels: images.first().map(|i| i.len() as u32).unwrap_or(0),
                    images,
                },
                Err(e) => Frame::Error {
                    message: format!("{e:#}"),
                },
            },
            Frame::StatsReq => match svc.stats_json() {
                Ok(json) => Frame::StatsResp { json },
                Err(e) => Frame::Error {
                    message: format!("{e:#}"),
                },
            },
            Frame::Shutdown => return Ok(()),
            other => Frame::Error {
                message: format!("unexpected frame {other:?}"),
            },
        };
        resp.write_to(&mut writer)?;
    }
}

/// Blocking client for the framed protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    fn call(&mut self, req: Frame) -> Result<Frame> {
        req.write_to(&mut self.writer)?;
        let resp = Frame::read_from(&mut self.reader)?;
        if let Frame::Error { message } = &resp {
            anyhow::bail!("server error: {message}");
        }
        Ok(resp)
    }

    pub fn compress(&mut self, model: &str, pixels: u32, images: Vec<Vec<u8>>) -> Result<Vec<u8>> {
        match self.call(Frame::CompressReq {
            model: model.to_string(),
            pixels,
            images,
        })? {
            Frame::CompressResp { container } => Ok(container),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Compress with a freshly seeded hierarchical (BBC3) model described
    /// entirely by `spec` — no pre-registered model name needed.
    pub fn compress_hier(
        &mut self,
        spec: HierSpec,
        pixels: u32,
        images: Vec<Vec<u8>>,
    ) -> Result<Vec<u8>> {
        match self.call(Frame::CompressHierReq {
            spec,
            pixels,
            images,
        })? {
            Frame::CompressResp { container } => Ok(container),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    pub fn decompress(&mut self, container: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        match self.call(Frame::DecompressReq { container })? {
            Frame::DecompressResp { images, .. } => Ok(images),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<String> {
        match self.call(Frame::StatsReq)? {
            Frame::StatsResp { json } => Ok(json),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }
}
